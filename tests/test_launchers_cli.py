"""Launcher CLI smoke tests: the train/serve entrypoints run end-to-end
on reduced configs (subprocess — the real user-facing path)."""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m"] + args, env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_cli_smoke(tmp_path):
    p = _run(["repro.launch.train", "--arch", "internlm2-1.8b", "--smoke",
              "--steps", "6", "--batch", "2", "--seq", "64",
              "--ckpt-dir", str(tmp_path), "--save-every", "3"])
    assert p.returncode == 0, p.stderr[-2000:]
    assert "loss" in p.stdout
    assert any(d.startswith("step_") for d in os.listdir(tmp_path))


@pytest.mark.slow
def test_serve_cli_smoke_with_a3():
    p = _run(["repro.launch.serve", "--arch", "phi4-mini-3.8b", "--smoke",
              "--requests", "2", "--prompt-len", "12", "--max-new", "4",
              "--max-len", "64", "--a3", "conservative"])
    assert p.returncode == 0, p.stderr[-2000:]
    assert "requests=2/2" in p.stdout


@pytest.mark.slow
def test_serve_cli_checkpoint_then_restore(tmp_path):
    """--l2-bytes / --checkpoint-dir / --restore: a run checkpoints at
    exit, and a second invocation restores the durable state (served
    results, trie, L2 tier) instead of starting cold."""
    ck = str(tmp_path / "ckpt")
    p = _run(["repro.launch.serve", "--arch", "phi4-mini-3.8b", "--smoke",
              "--requests", "2", "--prompt-len", "12", "--max-new", "4",
              "--max-len", "64", "--cache-pages", "8", "--page-size", "8",
              "--l2-bytes", str(1 << 24), "--checkpoint-dir", ck])
    assert p.returncode == 0, p.stderr[-2000:]
    assert "requests=2/2" in p.stdout
    assert "checkpointed engine" in p.stdout
    assert os.path.isdir(ck)
    p2 = _run(["repro.launch.serve", "--arch", "phi4-mini-3.8b", "--smoke",
               "--requests", "1", "--prompt-len", "12", "--max-new", "4",
               "--max-len", "64",
               "--checkpoint-dir", ck, "--restore"])
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "restored engine" in p2.stdout
    assert "requests=1/1" in p2.stdout


@pytest.mark.slow
def test_dryrun_cli_list():
    p = _run(["repro.launch.dryrun", "--list"], timeout=300)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "grok-1-314b" in p.stdout and "long_500k" in p.stdout

@pytest.mark.slow
def test_serve_cli_telemetry_artifacts(tmp_path):
    """--stats-json (versioned v2 schema + config echo + metrics dump),
    --metrics-json, and --trace-out all land as valid JSON from one
    telemetered A^3 run."""
    import json
    stats = str(tmp_path / "stats.json")
    metrics = str(tmp_path / "metrics.json")
    trace = str(tmp_path / "trace.json")
    p = _run(["repro.launch.serve", "--arch", "phi4-mini-3.8b", "--smoke",
              "--requests", "2", "--prompt-len", "12", "--max-new", "4",
              "--max-len", "64", "--a3", "conservative",
              "--decode-block", "2", "--telemetry-every", "1",
              "--stats-json", stats, "--metrics-json", metrics,
              "--trace-out", trace])
    assert p.returncode == 0, p.stderr[-2000:]
    assert "requests=2/2" in p.stdout
    with open(stats) as f:
        snap = json.load(f)
    assert snap["schema"] == "a3-serve-stats/v2"
    assert snap["config"]["a3"] == "conservative"
    assert snap["config"]["serve"]["telemetry"] is True
    assert snap["stats"]["finished"] == 2
    # --metrics-json implies --telemetry, so the dump is present twice
    assert snap["metrics"]["schema"] == "a3-serve-metrics/v1"
    with open(metrics) as f:
        m = json.load(f)
    assert m["counters"]["serve_a3_probe_dispatches"] >= 1
    assert m["counters"]["serve_finished"] == 2
    with open(trace) as f:
        tr = json.load(f)
    assert tr["otherData"]["schema"] == "a3-serve-trace/v1"
    assert any(e["name"] == "terminal" for e in tr["traceEvents"])
