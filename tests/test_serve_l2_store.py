"""Host-RAM L2 page-tier conformance: spilling a prefix page to the
checksummed host store and promoting it back must be invisible to the
tokens — an L2 hit is a *copy*, a corrupt blob is a *cold prefill*,
never an approximation.

Coverage:

* blob format round trip (``serialize_tree``/``deserialize_tree``):
  nested dicts, mixed dtypes, empty/None leaves; every corruption mode
  (truncation, bad magic, flipped byte, trailing bytes) raises
  :class:`IntegrityError`,
* :class:`PageStore` semantics: byte-budget LRU eviction, oversized
  blob rejection, lazy verified ``get`` (corrupt blob dropped +
  counted, key gone), promotion ``pop``,
* spill -> promote warm == cold, token for token, across the mixer
  kinds (attention / RG-LRU hybrid / xLSTM) and the A^3 path (sorted
  key leaf snapshots survive the L2 round trip),
* graceful degradation: a corrupted blob degrades that node to cold
  prefill with ZERO token divergence, counted in
  ``stats["l2_integrity_drops"]``, and leaks nothing (refs at 0, full
  pool drainable, no blob left for freed nodes),
* the 8-device sharded path: promotion's pool-insert dispatch
  (``insert_page_fn``) lowers and runs under
  ``--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from helpers import check, run_with_devices

from repro.config import A3Config, AttentionKind, BlockKind, ModelConfig
from repro.models import decoder as dec
from repro.serve.engine import ServeEngine
from repro.serve.page_store import IntegrityError, PageStore, \
    deserialize_tree, serialize_tree

TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                   dtype="float32")
TINY_RG = ModelConfig("tiny-rg", "hybrid", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, head_dim=16,
                      attention_kind=AttentionKind.SLIDING, window_size=24,
                      block_pattern=(BlockKind.RGLRU, BlockKind.RGLRU,
                                     BlockKind.ATTENTION),
                      act="gelu", dtype="float32")
TINY_XL = ModelConfig("tiny-xl", "ssm", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=256,
                      head_dim=16,
                      block_pattern=(BlockKind.MLSTM, BlockKind.MLSTM,
                                     BlockKind.SLSTM),
                      dtype="float32")
MAX_LEN = 96
MAX_NEW = 6
PAGE = 8
L2_BIG = 1 << 24


@pytest.fixture(scope="module")
def all_params():
    return {
        "tiny": dec.init_params(jax.random.PRNGKey(0), TINY),
        "tiny-rg": dec.init_params(jax.random.PRNGKey(1), TINY_RG),
        "tiny-xl": dec.init_params(jax.random.PRNGKey(2), TINY_XL),
    }


def _reference_generate(params, cfg, prompt, max_new=MAX_NEW,
                        a3=A3Config()):
    use_a3 = a3.mode.value != "off"
    lg, cache = dec.prefill(params, cfg, jnp.asarray(prompt, jnp.int32)[None],
                            max_len=MAX_LEN, a3=use_a3)
    cur, pos, out = int(jnp.argmax(lg[0])), len(prompt), []
    out.append(cur)
    for _ in range(max_new - 1):
        lg, cache = dec.decode_step(params, cfg, cache,
                                    jnp.asarray([cur], jnp.int32),
                                    jnp.int32(pos), a3=a3)
        cur = int(jnp.argmax(lg[0]))
        out.append(cur)
        pos += 1
    return out


def _shared_prefix_prompts(vocab, *, shared_len=24, n=3, seed=7):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, size=shared_len)
    return [np.concatenate([shared,
                            rng.integers(0, vocab, size=4 + 3 * i)])
            for i in range(n)]


# ---------------------------------------------------------------------------
# blob format
# ---------------------------------------------------------------------------

def test_store_blob_roundtrip_nested_mixed_dtypes():
    tree = {"page": {"kv0": np.arange(24, dtype=np.float32).reshape(2, 3, 4),
                     "scale": np.ones((2, 1), np.float32),
                     "q": (np.arange(6, dtype=np.int8).reshape(2, 3))},
            "meta": {"snap_valid": np.uint8(1)},
            "snap": {},                         # empty dict -> absent
            "sk": None}                         # None leaf -> absent
    blob = serialize_tree(tree)
    out = deserialize_tree(blob)
    assert set(out) == {"page", "meta"}
    for k in ("kv0", "scale", "q"):
        np.testing.assert_array_equal(out["page"][k], tree["page"][k])
        assert out["page"][k].dtype == np.asarray(tree["page"][k]).dtype
    np.testing.assert_array_equal(out["meta"]["snap_valid"], 1)
    # deterministic bytes: same tree -> same blob (checkpoint dedup
    # and the cross-host wire format both rely on this)
    assert serialize_tree(tree) == blob


def test_store_blob_jax_leaves_transfer_to_host():
    tree = {"x": jnp.arange(8, dtype=jnp.float32)}
    out = deserialize_tree(serialize_tree(tree))
    assert isinstance(out["x"], np.ndarray)
    np.testing.assert_array_equal(out["x"],
                                  np.arange(8, dtype=np.float32))


def test_store_blob_roundtrips_bfloat16_leaves():
    """ml_dtypes extension dtypes: their numpy typestr is an opaque
    void ("|V2"), so the manifest must carry the registered NAME —
    a bf16 engine cache (every non-tiny arch) checkpoints through
    this path."""
    x = jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4) / 7
    out = deserialize_tree(serialize_tree({"x": x}))
    assert out["x"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(out["x"], np.asarray(x))
    back = jnp.asarray(out["x"])            # restore path re-devices it
    assert back.dtype == jnp.bfloat16
    assert bool(jnp.all(back == x))


def test_store_blob_verification_catches_every_corruption_mode():
    blob = serialize_tree({"a": np.arange(10, dtype=np.float32)})
    with pytest.raises(IntegrityError):         # truncated header
        deserialize_tree(blob[:4])
    with pytest.raises(IntegrityError):         # truncated payload
        deserialize_tree(blob[:-3])
    with pytest.raises(IntegrityError):         # bad magic
        deserialize_tree(b"XXXX" + blob[4:])
    with pytest.raises(IntegrityError):         # flipped payload byte
        deserialize_tree(blob[:-1] + bytes([blob[-1] ^ 0xFF]))
    with pytest.raises(IntegrityError):         # flipped manifest byte
        i = 20
        deserialize_tree(blob[:i] + bytes([blob[i] ^ 0xFF]) + blob[i + 1:])
    with pytest.raises(IntegrityError):         # trailing bytes
        deserialize_tree(blob + b"\x00")


# ---------------------------------------------------------------------------
# PageStore semantics
# ---------------------------------------------------------------------------

def test_store_lru_eviction_under_byte_budget():
    stats = {}
    one = len(serialize_tree({"x": np.zeros(16, np.float32)}))
    st = PageStore(max_bytes=3 * one, stats=stats)
    for i in range(3):
        assert st.put((i,), {"x": np.full(16, i, np.float32)})
    assert len(st) == 3 and st.bytes_used == 3 * one
    st.get((0,))                    # touch: (1,) becomes LRU
    assert st.put((9,), {"x": np.zeros(16, np.float32)})
    assert (1,) not in st and (0,) in st
    assert stats["l2_evictions"] == 1
    # a blob bigger than the whole budget is rejected, not stored
    assert not st.put((7,), {"x": np.zeros(1024, np.float32)})
    assert (7,) not in st
    st.pop((0,))                    # promotion removes the blob
    assert (0,) not in st
    with pytest.raises(ValueError):
        PageStore(max_bytes=0)


def test_store_corrupt_blob_dropped_and_counted_on_get():
    stats = {}
    st = PageStore(max_bytes=1 << 20, stats=stats)
    st.put((1, 2, 3), {"x": np.arange(4, dtype=np.float32)})
    assert st.corrupt((1, 2, 3))
    assert st.get((1, 2, 3)) is None
    assert (1, 2, 3) not in st      # dropped at read time
    assert stats["l2_integrity_drops"] == 1
    assert stats["l2_hits"] == 0
    assert st.get((9,)) is None     # plain miss: not an integrity drop
    assert stats["l2_integrity_drops"] == 1
    assert not st.corrupt((9,))


# ---------------------------------------------------------------------------
# spill -> promote warm == cold across mixer kinds (and A^3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [TINY, TINY_RG, TINY_XL],
                         ids=["attention", "rglru", "xlstm"])
def test_l2_spill_promote_matches_cold_across_kinds(all_params, cfg):
    params = all_params[cfg.name]
    prompts = _shared_prefix_prompts(cfg.vocab_size)
    refs = [_reference_generate(params, cfg, p) for p in prompts]
    eng = ServeEngine(params, cfg, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8, page_size=PAGE, cache_pages=32,
                      l2_bytes=L2_BIG)
    u0 = eng.submit(prompts[0], MAX_NEW)
    eng.run_to_completion()
    assert eng.result(u0) == refs[0]
    # force-demote the whole trie to L2, then re-admit: the shared
    # prefix must come back through verified promotion
    assert eng._pc.spill(10 ** 6) > 0
    assert len(eng._pc.l2) > 0
    for p, r in zip(prompts[1:], refs[1:]):
        u = eng.submit(p, MAX_NEW)
        eng.run_to_completion()
        assert eng.result(u) == r
    assert eng.stats["l2_hits"] > 0
    assert eng.stats["l2_integrity_drops"] == 0
    assert eng.stats["prefix_tokens_reused"] > 0
    assert eng._pc.referenced_nodes == 0


def test_l2_spill_promote_matches_cold_a3(all_params):
    a3 = A3Config.conservative()
    params = all_params["tiny"]
    prompts = _shared_prefix_prompts(TINY.vocab_size, shared_len=32)
    refs = [_reference_generate(params, TINY, p, a3=a3) for p in prompts]
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN, a3=a3,
                      prefill_chunk=8, page_size=PAGE, cache_pages=32,
                      l2_bytes=L2_BIG)
    u0 = eng.submit(prompts[0], MAX_NEW)
    eng.run_to_completion()
    assert eng.result(u0) == refs[0]
    assert eng._pc.spill(10 ** 6) > 0
    for p, r in zip(prompts[1:], refs[1:]):
        u = eng.submit(p, MAX_NEW)
        eng.run_to_completion()
        assert eng.result(u) == r
    assert eng.stats["l2_hits"] > 0
    assert eng.stats["l2_integrity_drops"] == 0


def test_l2_int8_pool_survives_round_trip(all_params):
    """int8 KV pool: quantized pages + per-page scales demote/promote
    as one blob and the warm path still matches cold."""
    params = all_params["tiny"]
    prompts = _shared_prefix_prompts(TINY.vocab_size)
    refs = [_reference_generate(params, TINY, p) for p in prompts]
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8, page_size=PAGE, cache_pages=32,
                      kv_quant="int8", l2_bytes=L2_BIG)
    cold = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                       prefill_chunk=8, kv_quant="int8")
    cold_toks = []
    for p in prompts:
        u = cold.submit(p, MAX_NEW)
        cold.run_to_completion()
        cold_toks.append(cold.result(u))
    u0 = eng.submit(prompts[0], MAX_NEW)
    eng.run_to_completion()
    assert eng._pc.spill(10 ** 6) > 0
    for p, ct in zip(prompts[1:], cold_toks[1:]):
        u = eng.submit(p, MAX_NEW)
        eng.run_to_completion()
        assert eng.result(u) == ct
    assert eng.stats["l2_hits"] > 0
    assert eng.stats["l2_integrity_drops"] == 0


# ---------------------------------------------------------------------------
# graceful degradation + leak audit
# ---------------------------------------------------------------------------

def test_l2_corrupt_blob_degrades_to_cold_prefill_no_divergence(all_params):
    params = all_params["tiny"]
    prompts = _shared_prefix_prompts(TINY.vocab_size)
    refs = [_reference_generate(params, TINY, p) for p in prompts]
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8, page_size=PAGE, cache_pages=32,
                      l2_bytes=L2_BIG)
    u0 = eng.submit(prompts[0], MAX_NEW)
    eng.run_to_completion()
    eng._pc.spill(10 ** 6)
    for k in list(eng._pc.l2.keys()):
        assert eng._pc.l2.corrupt(k)
    for p, r in zip(prompts[1:], refs[1:]):
        u = eng.submit(p, MAX_NEW)
        eng.run_to_completion()
        assert eng.result(u) == r           # cold prefill, same tokens
    assert eng.stats["l2_integrity_drops"] >= 1
    assert eng.stats["l2_hits"] == 0
    # nothing leaked: refs at baseline, FULL pool drainable, and no
    # blob survives for a node that was dropped
    pc = eng._pc
    assert pc.referenced_nodes == 0
    got = [pc._alloc_page() for _ in range(pc.capacity)]
    assert sorted(got) == list(range(pc.capacity))
    assert len(pc) == 0


def test_l2_budget_eviction_loses_entries_not_correctness(all_params):
    """A tiny L2 byte budget: blobs get LRU-evicted from the store,
    later admissions just cold-prefill — tokens never change."""
    params = all_params["tiny"]
    prompts = _shared_prefix_prompts(TINY.vocab_size)
    refs = [_reference_generate(params, TINY, p) for p in prompts]
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8, page_size=PAGE, cache_pages=32,
                      l2_bytes=1 << 14)     # a handful of blobs at most
    uids = [eng.submit(p, MAX_NEW) for p in prompts]
    eng.run_to_completion()
    spilled = eng._pc.spill(10 ** 6)
    assert spilled > 0
    # the store can never exceed its budget
    assert eng._pc.l2.bytes_used <= eng._pc.l2.max_bytes
    for p, r in zip(prompts, refs):
        u = eng.submit(p, MAX_NEW)
        eng.run_to_completion()
        assert eng.result(u) == r
    for u, r in zip(uids, refs):
        assert eng.result(u) == r


# ---------------------------------------------------------------------------
# sharded (8 host devices): the promotion insert dispatch lowers
# ---------------------------------------------------------------------------

def test_l2_store_promotion_on_8_devices():
    out = check(run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.config import ModelConfig
from repro.models import decoder as dec
from repro.serve.engine import ServeEngine

assert jax.device_count() == 8
TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                   dtype="float32")
params = dec.init_params(jax.random.PRNGKey(0), TINY)
rng = np.random.default_rng(7)
shared = rng.integers(0, 256, size=24)
prompts = [np.concatenate([shared, rng.integers(0, 256, size=4 + 3 * i)])
           for i in range(2)]
cold = ServeEngine(params, TINY, slots=1, max_len=96, prefill_chunk=8)
cold_toks = []
for p in prompts:
    u = cold.submit(p, 6)
    cold.run_to_completion()
    cold_toks.append(cold.result(u))
eng = ServeEngine(params, TINY, slots=1, max_len=96, prefill_chunk=8,
                  page_size=8, cache_pages=32, l2_bytes=1 << 24)
u0 = eng.submit(prompts[0], 6)
eng.run_to_completion()
assert eng.result(u0) == cold_toks[0]
assert eng._pc.spill(10 ** 6) > 0
u1 = eng.submit(prompts[1], 6)
eng.run_to_completion()
assert eng.result(u1) == cold_toks[1]
assert eng.stats["l2_hits"] > 0
assert eng.stats["l2_integrity_drops"] == 0
print("L2HITS", eng.stats["l2_hits"])
""", devices=8))
    assert "L2HITS" in out
