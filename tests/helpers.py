"""Test helpers: run a snippet in a subprocess with N host devices, and
an optional-``hypothesis`` shim.

Multi-device tests (sharding rules, compression, pipeline, dry-run)
need ``--xla_force_host_platform_device_count``, which must be set
before jax initializes — so they run in a fresh interpreter. The parent
test process keeps its single device.

``hypothesis`` is a dev-only dependency; when it is absent, property
tests must *skip* while the rest of their module keeps running. Import
``given``/``settings``/``st`` from here instead of from ``hypothesis``:
with hypothesis installed they are the real thing, without it ``given``
turns the test into a skip.
"""
from __future__ import annotations

import os
import subprocess
import sys

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                    # pragma: no cover - env-dependent
    import pytest as _pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg replacement (no functools.wraps: pytest must not
            # see the original signature and hunt for fixtures)
            def skipper():
                _pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Stub: strategy constructors are only evaluated at decoration
        time and never executed (the test body is replaced by a skip)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def run_with_devices(code: str, devices: int = 8, timeout: int = 600
                     ) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


def check(proc: subprocess.CompletedProcess):
    assert proc.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout
