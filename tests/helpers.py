"""Test helpers: run a snippet in a subprocess with N host devices.

Multi-device tests (sharding rules, compression, pipeline, dry-run)
need ``--xla_force_host_platform_device_count``, which must be set
before jax initializes — so they run in a fresh interpreter. The parent
test process keeps its single device.
"""
from __future__ import annotations

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def run_with_devices(code: str, devices: int = 8, timeout: int = 600
                     ) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


def check(proc: subprocess.CompletedProcess):
    assert proc.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout
