"""Paged prefix-cache conformance: shared-prefix reuse is a *copy*, not
a recompute — it must never change what the model emits.

Every test here pins warm-cache engine generations token-for-token
against the cold path (and both against the sequential single-request
reference) across the arch kinds the mixer-state interface serves:
attention (TINY), hybrid RG-LRU + sliding attention (TINY_RG), and pure
xLSTM (TINY_XL). Coverage:

* full-prefix hits (identical prompt resubmitted; reuse capped one page
  short of the prompt so >= 1 suffix token always prefills),
* partial hits with mid-page divergence (match floors to the last
  shared page boundary; the divergent request records sibling pages —
  copy-on-write, pool pages are immutable),
* LRU eviction under a tiny ``cache_pages`` budget,
* the A^3 path (sorted columns + ``sorted_upto`` watermark restored at
  the boundary; generations cross re-sort cadences),
* the stats identity ``prefill_tokens_cold == prefill_tokens_warm +
  prefix_tokens_reused`` on the same workload,
* decoder-level: a warm-admitted slot's cache equals a cold chunked
  prefill of the matched prefix, leaf for leaf,
* ``slice_sorted_keys`` == a from-keys sort of the truncated ring,
* adaptive prefill chunking (``prefill_chunk_min``): the effective
  chunk shrinks while slots decode, outputs stay identical, and the
  engine's dispatch invariants hold,
* ``ServeConfig`` construction-time validation.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import check, run_with_devices

from repro.config import A3Config, AttentionKind, BlockKind, ModelConfig, \
    ServeConfig
from repro.core.candidate_selection import SortedKeys, select_candidates, \
    slice_sorted_keys, sort_key_columns
from repro.models import decoder as dec
from repro.serve.engine import ServeEngine
from repro.serve.prefix_cache import PrefixCache

TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                   dtype="float32")
TINY_RG = ModelConfig("tiny-rg", "hybrid", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, head_dim=16,
                      attention_kind=AttentionKind.SLIDING, window_size=24,
                      block_pattern=(BlockKind.RGLRU, BlockKind.RGLRU,
                                     BlockKind.ATTENTION),
                      act="gelu", dtype="float32")
TINY_XL = ModelConfig("tiny-xl", "ssm", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=256,
                      head_dim=16,
                      block_pattern=(BlockKind.MLSTM, BlockKind.MLSTM,
                                     BlockKind.SLSTM),
                      dtype="float32")
MAX_LEN = 96
MAX_NEW = 6
PAGE = 8


@pytest.fixture(scope="module")
def all_params():
    return {
        "tiny": dec.init_params(jax.random.PRNGKey(0), TINY),
        "tiny-rg": dec.init_params(jax.random.PRNGKey(1), TINY_RG),
        "tiny-xl": dec.init_params(jax.random.PRNGKey(2), TINY_XL),
    }


def _reference_generate(params, cfg, prompt, max_new=MAX_NEW,
                        a3=A3Config()):
    use_a3 = a3.mode.value != "off"
    lg, cache = dec.prefill(params, cfg, jnp.asarray(prompt, jnp.int32)[None],
                            max_len=MAX_LEN, a3=use_a3)
    cur, pos, out = int(jnp.argmax(lg[0])), len(prompt), []
    out.append(cur)
    for _ in range(max_new - 1):
        lg, cache = dec.decode_step(params, cfg, cache,
                                    jnp.asarray([cur], jnp.int32),
                                    jnp.int32(pos), a3=a3)
        cur = int(jnp.argmax(lg[0]))
        out.append(cur)
        pos += 1
    return out


def _shared_prefix_prompts(vocab, *, shared_len=24, n=3, seed=7):
    """n prompts sharing a ``shared_len``-token prefix with distinct
    suffixes (the multi-turn / system-prompt serving shape)."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, size=shared_len)
    return [np.concatenate([shared,
                            rng.integers(0, vocab, size=4 + 3 * i)])
            for i in range(n)]


def _engine_invariants(eng):
    t, s = eng.decode_block, eng.stats
    assert s["decode_steps"] == t * s["decode_dispatches"]
    assert s["prefill_dispatches"] <= s["ticks"]
    assert s["host_syncs"] <= s["decode_dispatches"] + s["handoff_syncs"]
    bound = math.ceil(s["decode_steps"] / t) + s["prefill_dispatches"]
    assert s["decode_dispatches"] <= bound
    assert s["host_syncs"] <= bound


# ---------------------------------------------------------------------------
# warm == cold, token for token, across mixer kinds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [TINY, TINY_RG, TINY_XL],
                         ids=["attention", "rglru", "xlstm"])
def test_prefix_warm_matches_cold_across_kinds(all_params, cfg):
    """Requests sharing a prompt prefix: the first admission is cold and
    records pages; every later admission walks the trie, gathers the
    matched pages, and prefills only its suffix — with generations
    token-for-token identical to the sequential reference for every
    mixer kind (KV ring pages for attention, carry snapshots for
    RG-LRU / mLSTM / sLSTM)."""
    params = all_params[cfg.name]
    prompts = _shared_prefix_prompts(cfg.vocab_size)
    refs = [_reference_generate(params, cfg, p) for p in prompts]
    eng = ServeEngine(params, cfg, slots=2, max_len=MAX_LEN,
                      prefill_chunk=PAGE, page_size=PAGE, cache_pages=32)
    u0 = eng.submit(prompts[0], max_new_tokens=MAX_NEW)
    eng.run_to_completion()
    assert eng.result(u0) == refs[0]
    assert eng.stats["prefix_hits"] == 0          # cold: nothing to match
    assert eng.stats["pages_recorded"] > 0
    uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts[1:]]
    eng.run_to_completion()
    for u, ref in zip(uids, refs[1:]):
        assert eng.result(u) == ref, cfg.name
    assert eng.stats["prefix_hits"] == len(prompts) - 1
    # every warm request matched the full 24-token shared prefix
    assert eng.stats["prefix_tokens_reused"] == 24 * (len(prompts) - 1)
    _engine_invariants(eng)


def test_prefix_full_hit_reuses_all_but_last_page(all_params):
    """An identical prompt resubmitted is the maximal hit — matched up
    to the last page boundary strictly before the prompt end (>= 1
    suffix token must prefill to produce next-token logits), i.e.
    >= 0.9x the prompt at these sizes, and the generation is still
    token-for-token the reference."""
    params = all_params["tiny"]
    rng = np.random.default_rng(3)
    p = rng.integers(0, TINY.vocab_size, size=40)
    ref = _reference_generate(params, TINY, p)
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=PAGE, page_size=4, cache_pages=32)
    u0 = eng.submit(p, max_new_tokens=MAX_NEW)
    eng.run_to_completion()
    cold_tokens = eng.stats["prefill_tokens"]
    u1 = eng.submit(p, max_new_tokens=MAX_NEW)
    eng.run_to_completion()
    assert eng.result(u0) == ref
    assert eng.result(u1) == ref
    assert eng.stats["prefix_tokens_reused"] == 36      # floor((40-1)/4)*4
    assert eng.stats["prefix_tokens_reused"] >= 0.9 * len(p)
    assert eng.stats["prefill_tokens"] == cold_tokens + 4
    _engine_invariants(eng)


@pytest.mark.parametrize("cfg", [TINY, TINY_RG, TINY_XL],
                         ids=["attention", "rglru", "xlstm"])
def test_prefix_partial_hit_mid_page_divergence_cow(all_params, cfg):
    """A request diverging mid-page matches only up to the last fully
    shared page boundary and records its own sibling pages from there —
    copy-on-write: the donor's pages are never mutated, and BOTH
    requests keep generating reference tokens afterwards."""
    params = all_params[cfg.name]
    rng = np.random.default_rng(9)
    shared = rng.integers(0, cfg.vocab_size, size=24)
    p_a = np.concatenate([shared, rng.integers(0, cfg.vocab_size, size=6)])
    # diverges 4 tokens into page 3 (pages of 8): match floors to 16
    p_b = np.concatenate([shared[:20],
                          rng.integers(0, cfg.vocab_size, size=9)])
    eng = ServeEngine(params, cfg, slots=2, max_len=MAX_LEN,
                      prefill_chunk=PAGE, page_size=PAGE, cache_pages=32)
    ua = eng.submit(p_a, max_new_tokens=MAX_NEW)
    eng.run_to_completion()
    ub = eng.submit(p_b, max_new_tokens=MAX_NEW)
    ua2 = eng.submit(p_a, max_new_tokens=MAX_NEW)   # donor pages intact
    eng.run_to_completion()
    assert eng.result(ua) == _reference_generate(params, cfg, p_a)
    assert eng.result(ub) == _reference_generate(params, cfg, p_b)
    assert eng.result(ua2) == eng.result(ua)
    # b matched 2 full pages (16 tokens), a2 matched 24 (3 pages)
    assert eng.stats["prefix_tokens_reused"] == 16 + 24
    _engine_invariants(eng)


@pytest.mark.parametrize("cfg,expect_reuse", [
    (TINY, 32 + 24),        # page-granularity terminals (global attention)
    (TINY_RG, 32 + 16),     # chunk-end terminals (carry + sliding ring)
    (TINY_XL, 32 + 16),     # chunk-end terminals (carry)
], ids=["attention", "rglru", "xlstm"])
def test_prefix_multipage_chunk_recording(all_params, cfg, expect_reuse):
    """prefill_chunk > page_size: a recording chunk spans several pages
    per dispatch (floor-aligned; bounded by the narrowest sliding ring)
    and records every crossed page, so cold admission speed is not
    page-limited. Warm matches terminate at page granularity on
    global-attention stacks and at chunk-END boundaries where a
    recurrent carry / sliding-ring capture requires it — outputs stay
    token-for-token the reference either way."""
    params = all_params[cfg.name]
    rng = np.random.default_rng(29)
    shared = rng.integers(0, cfg.vocab_size, size=32)
    p1 = np.concatenate([shared, rng.integers(0, cfg.vocab_size, size=5)])
    p2 = np.concatenate([shared, rng.integers(0, cfg.vocab_size, size=8)])
    # diverges mid 4th page (token 28): page floor 24, chunk floor 16
    p3 = np.concatenate([shared[:28],
                         rng.integers(0, cfg.vocab_size, size=9)])
    eng = ServeEngine(params, cfg, slots=2, max_len=MAX_LEN,
                      prefill_chunk=16, page_size=PAGE, cache_pages=32)
    u1 = eng.submit(p1, max_new_tokens=MAX_NEW)
    eng.run_to_completion()
    assert eng.stats["pages_recorded"] == 4      # every crossed page
    u2 = eng.submit(p2, max_new_tokens=MAX_NEW)
    u3 = eng.submit(p3, max_new_tokens=MAX_NEW)
    eng.run_to_completion()
    for u, p in ((u1, p1), (u2, p2), (u3, p3)):
        assert eng.result(u) == _reference_generate(params, cfg, p), \
            cfg.name
    assert eng.stats["prefix_tokens_reused"] == expect_reuse, cfg.name
    _engine_invariants(eng)


def test_prefix_wide_final_chunk_never_records_stale_ring_rows(
        all_params):
    """Regression: prefill_chunk wider than a sliding window (chunk=64
    vs window=24) must still record valid pages. An unclamped final
    chunk used to capture pages whose early positions the chunk itself
    had already overwritten in the ring; a second prompt ending a chunk
    exactly on such a boundary then upgraded the stale node to a match
    terminal, and a third request warm-admitted corrupted K/V. Every
    recording chunk — final included — is now bounded by record_span,
    so all three generations must equal the reference and the warm hit
    must be real."""
    params = all_params["tiny-rg"]
    rng = np.random.default_rng(31)
    shared = rng.integers(0, TINY_RG.vocab_size, size=60)
    p_a = shared                                       # one "chunk" cold
    p_b = np.concatenate([shared[:32],
                          rng.integers(0, TINY_RG.vocab_size, size=9)])
    p_c = np.concatenate([shared[:32],
                          rng.integers(0, TINY_RG.vocab_size, size=6)])
    eng = ServeEngine(params, TINY_RG, slots=1, max_len=MAX_LEN,
                      prefill_chunk=64, page_size=PAGE, cache_pages=32)
    uids = []
    for p in (p_a, p_b, p_c):
        uids.append(eng.submit(p, max_new_tokens=MAX_NEW))
        eng.run_to_completion()
    for u, p in zip(uids, (p_a, p_b, p_c)):
        assert eng.result(u) == _reference_generate(params, TINY_RG, p)
    assert eng.stats["prefix_hits"] >= 2               # b and c both hit
    assert eng.stats["prefix_tokens_reused"] > 0
    _engine_invariants(eng)


def test_prefix_page_wider_than_window_unaligned_chunks(all_params):
    """Regression (page_size > sliding window, chunk unaligned to both):
    recording chunks must land exactly on crossed page boundaries, or
    the post-chunk capture reads ring rows the chunk already overwrote
    (window 24 < page 32: an unaligned 72-token final chunk used to
    record positions 40-47 of the [32, 64) page from stale rows, and a
    later prompt ending a chunk at 64 upgraded that node to a match
    terminal). Warm admissions must equal the cold reference."""
    params = all_params["tiny-rg"]
    rng = np.random.default_rng(37)
    shared = rng.integers(0, TINY_RG.vocab_size, size=72)
    p_a = shared
    p_b = np.concatenate([shared[:64],
                          rng.integers(0, TINY_RG.vocab_size, size=9)])
    eng = ServeEngine(params, TINY_RG, slots=1, max_len=MAX_LEN,
                      prefill_chunk=20, page_size=32, cache_pages=32)
    uids = []
    for p in (p_a, p_b):
        uids.append(eng.submit(p, max_new_tokens=MAX_NEW))
        eng.run_to_completion()
    for u, p in zip(uids, (p_a, p_b)):
        assert eng.result(u) == _reference_generate(params, TINY_RG, p)
    # the sharp check: warm-admit the 64-token prefix into a fresh cache
    # and diff EVERY leaf against a cold chunked prefill of the same
    # prefix — stale ring rows (positions 40-47 under the old unaligned
    # capture) differ by O(1), far outside chunk-split float noise
    pc = eng._pc
    probe = np.concatenate([shared[:64],
                            rng.integers(0, TINY_RG.vocab_size, size=6)])
    restored, t, _ = pc.admit(dec.init_cache(TINY_RG, 1, MAX_LEN), 0,
                              probe)
    assert t == 64
    ref_cache = dec.init_cache(TINY_RG, 1, MAX_LEN)
    cur = 0
    while cur < t:
        take = min(16, t - cur)
        toks = np.zeros((1, 16), np.int32)
        toks[0, :take] = shared[cur:cur + take]
        _, ref_cache = dec.prefill_chunk(params, TINY_RG, ref_cache,
                                         jnp.asarray(toks),
                                         jnp.asarray([cur], jnp.int32),
                                         jnp.asarray([take], jnp.int32))
        cur += take
    flat_g, _ = jax.tree_util.tree_flatten_with_path(restored)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(ref_cache)
    for (ka, a), (kb, b) in zip(flat_g, flat_r):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-4, err_msg=str(ka))
    _engine_invariants(eng)


def test_prefix_unaligned_cursor_from_adaptive_floor_records_valid_pages(
        all_params):
    """Regression (adaptive floor < page_size + window < 2*page_size):
    a sub-page adaptive chunk leaves the recording cursor unaligned,
    and a following full chunk crossing TWO boundaries used to record
    the first page from ring rows the chunk had already overwritten
    (window 12, page 8: page [0,8) captured at cursor 16 held positions
    12-15 in rows 0-3). A later prompt ending a chunk at 8 upgraded the
    stale node to a match terminal. Unaligned starts now realign at the
    FIRST boundary; the warm-restored cache must equal a cold prefill."""
    import dataclasses
    cfg = dataclasses.replace(TINY_RG, name="tiny-rg-w12", window_size=12)
    params = all_params["tiny-rg"]        # params are window-independent
    rng = np.random.default_rng(43)
    shared = rng.integers(0, cfg.vocab_size, size=30)
    short = rng.integers(0, cfg.vocab_size, size=5)
    eng = ServeEngine(params, cfg, slots=2, max_len=MAX_LEN,
                      prefill_chunk=16, prefill_chunk_min=6,
                      page_size=PAGE, cache_pages=32)
    # budget 3: the decoder lives exactly long enough to shrink A's
    # FIRST chunk to the 6-token floor (cursor lands unaligned at 6),
    # then dies — A's next chunk runs at the full 16 with no decoder,
    # crossing boundaries 8 and 16 from the unaligned start
    u0 = eng.submit(short, max_new_tokens=3)
    eng.step()                            # slot 0 decoding (budget 1)
    assert eng.slots[0].decoding
    ua = eng.submit(shared, max_new_tokens=MAX_NEW)   # admits at floor 6
    eng.run_to_completion()
    assert eng.stats["adaptive_shrink_ticks"] > 0     # floor engaged
    # B ends a chunk exactly on boundary 8 -> dedupe upgrade path
    p_b = np.concatenate([shared[:8],
                          rng.integers(0, cfg.vocab_size, size=5)])
    ub = eng.submit(p_b, max_new_tokens=MAX_NEW)
    eng.run_to_completion()
    assert eng.result(u0) == _reference_generate(params, cfg, short, 3)
    assert eng.result(ua) == _reference_generate(params, cfg, shared)
    assert eng.result(ub) == _reference_generate(params, cfg, p_b)
    # the sharp check: warm-restore the 8-token prefix and diff every
    # leaf against a cold chunked prefill of the same prefix
    pc = eng._pc
    probe = np.concatenate([shared[:8],
                            rng.integers(0, cfg.vocab_size, size=4)])
    restored, t, _ = pc.admit(dec.init_cache(cfg, 2, MAX_LEN), 1, probe)
    assert t == 8
    ref_cache = dec.init_cache(cfg, 2, MAX_LEN)
    toks = np.zeros((2, 8), np.int32)
    toks[1] = shared[:8]
    _, ref_cache = dec.prefill_chunk(params, cfg, ref_cache,
                                     jnp.asarray(toks),
                                     jnp.asarray([0, 0], jnp.int32),
                                     jnp.asarray([0, 8], jnp.int32))
    flat_g, _ = jax.tree_util.tree_flatten_with_path(restored)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(ref_cache)
    for (ka, a), (kb, b) in zip(flat_g, flat_r):
        np.testing.assert_allclose(np.asarray(a, np.float32)[:, 1],
                                   np.asarray(b, np.float32)[:, 1],
                                   rtol=1e-4, atol=1e-4, err_msg=str(ka))


def test_prefix_eviction_under_tiny_budget(all_params):
    """With a 2-page budget the trie evicts LRU leaves constantly —
    correctness must be unaffected (eviction only forgets reuse
    opportunities, never corrupts admitted state)."""
    params = all_params["tiny-rg"]
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, TINY_RG.vocab_size, size=30)
               for _ in range(4)]
    eng = ServeEngine(params, TINY_RG, slots=1, max_len=MAX_LEN,
                      prefill_chunk=PAGE, page_size=PAGE, cache_pages=2)
    uids = [eng.submit(p, max_new_tokens=4) for p in prompts]
    eng.run_to_completion()
    for u, p in zip(uids, prompts):
        assert eng.result(u) == _reference_generate(params, TINY_RG, p, 4)
    assert eng.stats["pages_evicted"] > 0
    assert eng._pc.pages_in_use <= 2
    _engine_invariants(eng)


def test_prefix_lru_heap_stays_bounded_under_steady_hits(all_params):
    """The lazy-deletion LRU heap must not grow without bound when the
    trie stays under budget (allocation never drains it): every lookup
    touches the matched chain, and compaction keeps the heap at a small
    multiple of the live node count."""
    params = all_params["tiny"]
    rng = np.random.default_rng(41)
    p = rng.integers(0, TINY.vocab_size, size=25)
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=PAGE, page_size=PAGE, cache_pages=64)
    eng.submit(p, max_new_tokens=2)
    eng.run_to_completion()
    pc = eng._pc
    assert len(pc) > 0
    for _ in range(5000):                     # steady warm traffic
        pc.lookup(p)
    assert len(pc._heap) <= 4 * (len(pc._nodes) + 16) + 1
    # and eviction still works after compaction: drain the budget
    t, node = pc.lookup(p)
    assert t > 0 and node.page_id >= 0


def test_prefix_a3_warm_matches_cold(all_params):
    """The A^3 path: warm admission restores the sorted columns and the
    ``sorted_upto`` watermark at the boundary (no admission re-sort);
    the suffix's final chunk folds the full-ring sort exactly like a
    cold admission, and decode crosses re-sort cadences identically —
    same tokens, same host-mirrored resort count as a cache-less run."""
    params = all_params["tiny"]
    a3 = A3Config.conservative()
    prompts = _shared_prefix_prompts(TINY.vocab_size, seed=11)
    refs = [_reference_generate(params, TINY, p, a3=a3) for p in prompts]

    cold = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                       prefill_chunk=PAGE, a3=a3, resort_every=2)
    warm = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                       prefill_chunk=PAGE, a3=a3, resort_every=2,
                       page_size=PAGE, cache_pages=32)
    for eng in (cold, warm):
        u0 = eng.submit(prompts[0], max_new_tokens=MAX_NEW)
        eng.run_to_completion()
        uids = [eng.submit(p, max_new_tokens=MAX_NEW)
                for p in prompts[1:]]
        eng.run_to_completion()
        for u, ref in zip([u0] + uids, refs):
            assert eng.result(u) == ref
        assert eng.stats["resorts"] > 0
        _engine_invariants(eng)
    assert warm.stats["resorts"] == cold.stats["resorts"]
    assert warm.stats["prefix_hits"] == len(prompts) - 1


def test_prefix_stats_invariant_cold_equals_warm_plus_reused(all_params):
    """The accounting identity: on the same workload, the cold engine's
    prefilled tokens equal the warm engine's prefilled tokens plus the
    tokens it reused from the trie — reuse removes work, it never
    changes how much work exists."""
    params = all_params["tiny"]
    prompts = _shared_prefix_prompts(TINY.vocab_size, n=4, seed=13)
    stats = {}
    for label, pages in (("cold", 0), ("warm", 64)):
        eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                          prefill_chunk=PAGE, page_size=PAGE,
                          cache_pages=pages)
        for p in prompts:
            eng.submit(p, max_new_tokens=MAX_NEW)
            eng.run_to_completion()   # serialize so later prompts can hit
        stats[label] = eng.stats
    assert stats["cold"]["prefix_tokens_reused"] == 0
    assert stats["warm"]["prefix_tokens_reused"] > 0
    assert stats["cold"]["prefill_tokens"] == \
        stats["warm"]["prefill_tokens"] + \
        stats["warm"]["prefix_tokens_reused"]


def test_prefix_flash_crowd_batched_admission_one_dispatch(all_params):
    """The flash-crowd shape: N same-prefix requests admitted on ONE
    tick warm-admit through a single stacked gather dispatch —
    ``gather_dispatches`` counts 1, not N — and each still gets exactly
    its cold-path tokens."""
    params = all_params["tiny"]
    prompts = _shared_prefix_prompts(TINY.vocab_size, n=4, seed=17)
    refs = [_reference_generate(params, TINY, p) for p in prompts]
    eng = ServeEngine(params, TINY, slots=3, max_len=MAX_LEN,
                      prefill_chunk=PAGE, page_size=PAGE,
                      cache_pages=64)
    u0 = eng.submit(prompts[0], max_new_tokens=MAX_NEW)
    eng.run_to_completion()
    assert eng.result(u0) == refs[0]
    assert eng.stats["gather_dispatches"] == 0
    # all three slots free, three same-prefix arrivals: one tick must
    # admit all of them through one stacked copy dispatch
    uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts[1:]]
    eng.step()
    assert eng.stats["prefix_hits"] == 3
    assert eng.stats["gather_dispatches"] == 1
    eng.run_to_completion()
    for u, ref in zip(uids, refs[1:]):
        assert eng.result(u) == ref
    _engine_invariants(eng)


# ---------------------------------------------------------------------------
# decoder-level: the gather restores exactly the cold-prefill cache
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [TINY, TINY_RG, TINY_XL],
                         ids=["attention", "rglru", "xlstm"])
def test_prefix_gather_restores_cache_like_cold_prefill(all_params, cfg):
    """Drive PrefixCache standalone: record a prompt from lane 0 with
    page-aligned chunks, then admit its prefix into lane 1 of a fresh
    cache — lane 1's every leaf must equal a cold chunked prefill of
    the same prefix (ring rows, recurrent carries; unwritten rows read
    zero)."""
    params = all_params[cfg.name]
    rng = np.random.default_rng(17)
    p = rng.integers(0, cfg.vocab_size, size=26)
    ps, t = PAGE, 16
    pc = PrefixCache(cfg, max_len=MAX_LEN, page_size=ps, cache_pages=8)
    cache = dec.init_cache(cfg, 2, MAX_LEN)
    node = pc.root
    for cur in range(0, len(p), ps):
        take = min(ps, len(p) - cur)
        toks = np.zeros((2, ps), np.int32)
        toks[0, :take] = p[cur:cur + take]
        _, cache = dec.prefill_chunk(params, cfg, cache,
                                     jnp.asarray(toks),
                                     jnp.asarray([cur, 0], jnp.int32),
                                     jnp.asarray([take, 0], jnp.int32))
        if (cur + take) % ps == 0:
            node = pc.record_boundary(cache, 0, p, cur + take, node)
            assert node is not None
    # warm-admit the 16-token prefix into lane 1 of a FRESH cache
    fresh = dec.init_cache(cfg, 2, MAX_LEN)
    fresh2, got_t, _ = pc.admit(fresh, 1, p[:t + 1])
    assert got_t == t
    # cold reference: chunked prefill of p[:16] into lane 1
    ref_cache = dec.init_cache(cfg, 2, MAX_LEN)
    for cur in range(0, t, ps):
        toks = np.zeros((2, ps), np.int32)
        toks[1] = p[cur:cur + ps]
        _, ref_cache = dec.prefill_chunk(params, cfg, ref_cache,
                                         jnp.asarray(toks),
                                         jnp.asarray([0, cur], jnp.int32),
                                         jnp.asarray([0, ps], jnp.int32))
    flat_g, _ = jax.tree_util.tree_flatten_with_path(fresh2)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(ref_cache)
    for (ka, a), (kb, b) in zip(flat_g, flat_r):
        assert str(ka) == str(kb)
        np.testing.assert_allclose(np.asarray(a, np.float32)[:, 1],
                                   np.asarray(b, np.float32)[:, 1],
                                   rtol=1e-6, atol=1e-6, err_msg=str(ka))


def test_prefix_slice_sorted_keys_matches_from_keys_sort():
    """slice_sorted_keys recovers the comprehension sort of a truncated
    ring from the longer snapshot: values equal a from-keys sort of the
    zeroed-out matrix exactly, and candidate selection agrees."""
    rng = np.random.default_rng(21)
    n, d = 16, 8
    key = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    sk_full = sort_key_columns(key)
    for boundary in (1, 5, 12, 16):
        keep = jnp.arange(n) < boundary
        sliced = slice_sorted_keys(sk_full, keep)
        ref = sort_key_columns(jnp.where(keep[:, None], key, 0.0))
        np.testing.assert_array_equal(np.asarray(sliced.values),
                                      np.asarray(ref.values))
        # rows may reorder only among exactly-zero ties
        nz = np.asarray(ref.values) != 0.0
        np.testing.assert_array_equal(np.asarray(sliced.rows)[nz],
                                      np.asarray(ref.rows)[nz])
        q = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        m_a, g_a = select_candidates(sliced, q, m_iters=12)
        m_b, g_b = select_candidates(ref, q, m_iters=12)
        np.testing.assert_array_equal(np.asarray(m_a), np.asarray(m_b))
        np.testing.assert_allclose(np.asarray(g_a), np.asarray(g_b),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# adaptive prefill chunking
# ---------------------------------------------------------------------------

def test_prefix_adaptive_chunk_shrinks_under_decode_load(all_params):
    """prefill_chunk_min: ticks with >= 1 decoding slot use the floor
    chunk (bounding the admission stall), a cold queue drains at the
    full chunk — and chunk adaptation, like all chunking, never changes
    outputs. The per-tick stall stays bounded: while a decoder was
    active, no prefill dispatch moved more than prefill_chunk_min
    tokens per lane."""
    params = all_params["tiny"]
    rng = np.random.default_rng(23)
    short = rng.integers(0, TINY.vocab_size, size=6)
    long_p = rng.integers(0, TINY.vocab_size, size=64)
    ref_s = _reference_generate(params, TINY, short, 16)
    ref_l = _reference_generate(params, TINY, long_p)
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=32, prefill_chunk_min=8)
    us = eng.submit(short, max_new_tokens=16)
    eng.step()                          # short admits at the FULL chunk
    assert eng.stats["adaptive_shrink_ticks"] == 0
    assert eng.slots[0].decoding
    ul = eng.submit(long_p, max_new_tokens=MAX_NEW)
    ticks_before = eng.stats["ticks"]
    eng.run_to_completion()
    assert eng.result(us) == ref_s
    assert eng.result(ul) == ref_l
    # the long prompt admitted against an active decoder: every one of
    # its prefill ticks shrank to the floor -> 64/8 = 8 shrunk ticks
    assert eng.stats["adaptive_shrink_ticks"] == 8
    assert eng.stats["ticks"] - ticks_before >= 8
    _engine_invariants(eng)


def test_prefix_adaptive_chunk_cold_queue_uses_full_chunk(all_params):
    """No decoding slots -> the full chunk drains the queue: a 64-token
    prompt admits in ceil(64/32)=2 dispatches, not 8."""
    params = all_params["tiny"]
    rng = np.random.default_rng(25)
    p = rng.integers(0, TINY.vocab_size, size=64)
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=32, prefill_chunk_min=8)
    u = eng.submit(p, max_new_tokens=8)
    eng.step()
    eng.step()
    assert eng.slots[0].decoding        # prompt fully admitted
    assert eng.stats["prefill_dispatches"] == 2
    assert eng.stats["adaptive_shrink_ticks"] == 0
    eng.run_to_completion()
    assert eng.result(u) == _reference_generate(params, TINY, p, 8)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    {"slots": 0},
    {"max_len": 0},
    {"prefill_chunk": 0},
    {"prefill_chunk": -3},
    {"prefill_chunk_min": 0},
    {"prefill_chunk": 16, "prefill_chunk_min": 32},
    {"decode_block": 0},
    {"page_size": 0},
    {"cache_pages": -1},
    {"temperature": -0.5},
])
def test_prefix_serveconfig_rejects_nonsense(kw):
    """ServeConfig validates at construction with a clear error instead
    of admitting values that explode (or silently mis-serve) three
    layers deep in the engine."""
    with pytest.raises(ValueError):
        ServeConfig(**kw)


def test_prefix_engine_rejects_bad_cache_knobs(all_params):
    params = all_params["tiny"]
    with pytest.raises(ValueError):
        ServeEngine(params, TINY, slots=1, max_len=32, page_size=0,
                    cache_pages=4)
    with pytest.raises(ValueError):
        ServeEngine(params, TINY, slots=1, max_len=32, cache_pages=-1)
    with pytest.raises(ValueError):
        ServeEngine(params, TINY, slots=1, max_len=32, prefill_chunk=8,
                    prefill_chunk_min=16)
    eng = ServeEngine(params, TINY, slots=1, max_len=32, prefill_chunk=8)
    with pytest.raises(ValueError):
        eng.submit(np.asarray([], np.int32))    # empty prompt


# ---------------------------------------------------------------------------
# sharded lowering (exercised on the multi-device CI matrix entry)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_prefix_gather_lowering():
    """The warm-admission gather copy lowers under GSPMD on the CI mesh
    for attention, hybrid-recurrent, and pure-recurrent archs, with the
    slot cache donated (in-place restore) and the page pool sharded by
    the same rules as the rings."""
    out = check(run_with_devices("""
from repro.config import A3Config, ShapeConfig, ShapeKind, \\
    ShardingConfig, get_arch, smoke_variant
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import lower_gather_pages
pshape = ShapeConfig("prefill_smoke", ShapeKind.PREFILL, 256, 8)
mesh = make_mesh((2, 4), ("data", "model"))
scfg = ShardingConfig(remat="none")
with mesh:
    for arch in ("phi4-mini-3.8b", "recurrentgemma-2b", "xlstm-350m"):
        cfg = smoke_variant(get_arch(arch))
        c = lower_gather_pages(cfg, pshape, mesh, scfg, page_size=64,
                               pages=128,
                               a3=A3Config.conservative()).compile()
        assert c.memory_analysis().alias_size_in_bytes > 0, arch
print("OK")
""", devices=8, timeout=900))
    assert "OK" in out
