"""mLSTM chunk kernel: shape/dtype sweep vs the sequential oracle, and
consistency with the model-level chunkwise implementation."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_kernel
from repro.kernels.mlstm_chunk.ref import mlstm_chunk_ref
from repro.models import xlstm as X


def _inputs(key, b, h, s, dk, dv, dtype):
    ks = jax.random.split(key, 5)
    q = (jax.random.normal(ks[0], (b, h, s, dk)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, h, s, dk)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (b, h, s, dv)) * 0.5).astype(dtype)
    li = jax.random.normal(ks[3], (b, h, s)) * 1.0
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, s)) + 2.0)
    return q, k, v, li, lf


@pytest.mark.parametrize("shape", [
    (1, 1, 16, 8, 8), (2, 2, 32, 16, 16), (1, 3, 64, 32, 16),
])
@pytest.mark.parametrize("chunk", [8, 16])
def test_kernel_matches_oracle(shape, chunk):
    b, h, s, dk, dv = shape
    q, k, v, li, lf = _inputs(jax.random.PRNGKey(0), b, h, s, dk, dv,
                              jnp.float32)
    scale = 1.0 / math.sqrt(dk)
    out = mlstm_chunk_kernel(q, k, v, li, lf, chunk=chunk, scale=scale,
                             interpret=True)
    ref = mlstm_chunk_ref(q, k, v, li, lf, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-4)


def test_kernel_bf16_inputs():
    q, k, v, li, lf = _inputs(jax.random.PRNGKey(1), 2, 2, 32, 16, 16,
                              jnp.bfloat16)
    out = mlstm_chunk_kernel(q, k, v, li, lf, chunk=16, scale=0.25,
                             interpret=True)
    ref = mlstm_chunk_ref(q, k, v, li, lf, scale=0.25)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_matches_model_chunkwise_core():
    """The kernel core == the model-level chunkwise mLSTM (pre-LN/gate):
    run the model path and the kernel path from the same projections."""
    b, s, D, H, Dh = 2, 32, 64, 2, 16
    key = jax.random.PRNGKey(2)
    p = X.mlstm_init(key, D, H, Dh, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (b, s, D)) * 0.5

    q = jnp.moveaxis((x @ p["wq"]).reshape(b, s, H, Dh), 2, 1)
    k = jnp.moveaxis((x @ p["wk"]).reshape(b, s, H, Dh), 2, 1)
    v = jnp.moveaxis((x @ p["wv"]).reshape(b, s, H, Dh), 2, 1)
    li, lf = X._mlstm_gates(p, x)
    li = jnp.moveaxis(li, 2, 1)
    lf = jnp.moveaxis(lf, 2, 1)
    scale = 1.0 / math.sqrt(Dh)

    hk = mlstm_chunk_kernel(q, k, v, li, lf, chunk=8, scale=scale,
                            interpret=True)
    href = mlstm_chunk_ref(q, k, v, li, lf, scale=scale)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(href),
                               atol=2e-5, rtol=2e-4)
