"""Chaos-injection conformance: fault ISOLATION proven the same way
PRs 2-5 proved correctness — against a fault-free oracle run.

The headline property: running the engine under seeded injection
(corrupt a decoding lane's mixer state / fail a warm page gather /
abort ticks mid-phase), every UN-injected request's token stream is
token-for-token identical to the chaos-free run, every injected
request terminates FAILED (never hangs a slot), and ``host_syncs``
does not increase — poison detection rides the per-block ring harvest
the engine already pays for (``decoder.POISON`` sentinel), not an
extra device read.

The corrupt-site workload keeps requests <= slots so the schedule of
surviving lanes is pinned tick-for-tick: with no backlog, a victim's
early death cannot re-cohort the others, making "host_syncs does not
increase" an exact equality check rather than a statistical one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.models import decoder as dec
from repro.serve.chaos import ChaosConfig, ChaosError, ChaosInjector, \
    corrupt_cache_lane
from repro.serve.engine import ServeEngine

TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                   dtype="float32")
MAX_LEN = 96
PROMPT_LENS = (5, 12, 23)


@pytest.fixture(scope="module")
def params():
    return dec.init_params(jax.random.PRNGKey(0), TINY)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(0)
    return [rng.integers(0, TINY.vocab_size, size=n) for n in PROMPT_LENS]


def _run(params, prompts, *, chaos=None, slots=3, max_new=6,
         decode_block=4, cache_pages=0, max_ticks=10_000):
    eng = ServeEngine(params, TINY, slots=slots, max_len=MAX_LEN,
                      prefill_chunk=8, decode_block=decode_block,
                      page_size=8, cache_pages=cache_pages, chaos=chaos)
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run_to_completion(max_ticks=max_ticks)
    return eng, uids


def _check_conservation(eng):
    s = eng.stats
    assert s["submitted"] == (s["finished"] + s["rejected"]
                              + s["cancelled"] + s["expired"]
                              + s["failed"] + eng.in_flight), s


# ---------------------------------------------------------------------------
# the POISON sentinel at the decoder level
# ---------------------------------------------------------------------------

def test_chaos_poison_sentinel_rides_ring(params):
    """A NaN'd lane emits POISON exactly once on the existing token
    ring, then freezes; the healthy lane's ring row is bit-identical
    to the uncorrupted run — the quarantine select is lane-local."""
    rng = np.random.default_rng(1)
    toks = rng.integers(0, TINY.vocab_size, size=(2, 12))
    logits, cache = dec.prefill(params, TINY, jnp.asarray(toks),
                                max_len=32)
    first = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((2,), 12, jnp.int32)
    left = jnp.full((2,), 4, jnp.int32)
    ring_ok, _, _ = dec.decode_block(params, TINY, cache, first, pos,
                                     left, steps=4)
    ring_bad, carry_bad, _ = dec.decode_block(params, TINY,
                                              corrupt_cache_lane(cache, 0),
                                              first, pos, left, steps=4)
    ring_ok, ring_bad = np.asarray(ring_ok), np.asarray(ring_bad)
    assert (ring_ok >= 0).all()
    assert ring_bad[0, 0] == dec.POISON          # poisoned once...
    assert (ring_bad[0, 1:] == -1).all()         # ...then frozen
    np.testing.assert_array_equal(ring_bad[1], ring_ok[1])
    # the poisoned lane's carry froze at its input token — feeding it
    # to a next block keeps the lane frozen (token != POISON guard is
    # on the INPUT token; its non-finite logits re-poison regardless)
    assert int(np.asarray(carry_bad)[0]) == int(first[0])


def test_chaos_corrupt_cache_lane_targets_one_lane(params):
    _, cache = dec.prefill(params, TINY,
                           jnp.zeros((3, 4), jnp.int32), max_len=16)
    bad = corrupt_cache_lane(cache, 1)
    for name, sc in bad.items():
        for key, leaf in sc.items():
            ref = cache[name][key]
            if jnp.issubdtype(np.asarray(leaf).dtype, np.floating):
                assert np.isnan(np.asarray(leaf)[:, 1]).all(), (name, key)
            np.testing.assert_array_equal(np.asarray(leaf)[:, 0],
                                          np.asarray(ref)[:, 0])
            np.testing.assert_array_equal(np.asarray(leaf)[:, 2],
                                          np.asarray(ref)[:, 2])


# ---------------------------------------------------------------------------
# headline conformance: corrupt injection
# ---------------------------------------------------------------------------

def test_chaos_conformance_corrupt_isolates_victim(params, prompts):
    free, fu = _run(params, prompts)
    chaos = ChaosInjector(ChaosConfig(seed=0, rate=0.5,
                                      raise_mid_tick=False,
                                      fail_gather=False,
                                      max_injections=1))
    eng, uids = _run(params, prompts, chaos=chaos)
    victims = chaos.injected_uids
    assert victims, "the pinned (seed, rate) schedule must inject"
    for u, f in zip(uids, fu):
        if u in victims:
            # injected -> FAILED, no result, slot was reclaimed (the
            # run completed without exhausting max_ticks)
            assert eng.status(u) == "failed"
            assert eng.result(u) is None
        else:
            # un-injected -> token-for-token identical to chaos-free
            assert eng.status(u) == "finished"
            assert eng.result(u) == free.result(f)
    assert eng.stats["failed"] == len(victims)
    # poison detection rides the existing per-block harvest: with no
    # backlog the surviving lanes' schedule is pinned, so syncs are
    # EQUAL, and in general must never increase
    assert eng.stats["host_syncs"] <= free.stats["host_syncs"]
    assert eng.stats["host_syncs"] <= (eng.stats["decode_dispatches"]
                                       + eng.stats["handoff_syncs"])
    _check_conservation(eng)
    # the victim's slot is genuinely reusable: new work completes on it
    u_next = eng.submit(prompts[0], max_new_tokens=4)
    eng.run_to_completion()
    assert eng.status(u_next) == "finished"
    assert eng.result(u_next) == free.result(fu[0])[:4]
    _check_conservation(eng)


def test_chaos_determinism_same_seed_same_faults(params, prompts):
    cfg = ChaosConfig(seed=0, rate=0.5, raise_mid_tick=False,
                      fail_gather=False, max_injections=1)
    ch1, ch2 = ChaosInjector(cfg), ChaosInjector(cfg)
    e1, u1 = _run(params, prompts, chaos=ch1)
    e2, u2 = _run(params, prompts, chaos=ch2)
    assert ch1.events == ch2.events
    assert [e1.status(u) for u in u1] == [e2.status(u) for u in u2]
    for a, b in zip(u1, u2):
        assert e1.result(a) == e2.result(b)


# ---------------------------------------------------------------------------
# gather-failure injection (prefix-cache admission)
# ---------------------------------------------------------------------------

def test_chaos_gather_failure_fails_request_not_engine(params, prompts):
    long_prompt = np.concatenate([prompts[2], prompts[1], prompts[2]])[:48]
    chaos = ChaosInjector(ChaosConfig(seed=1, rate=1.0,
                                      corrupt_logits=False,
                                      raise_mid_tick=False,
                                      max_injections=1))
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8, page_size=8, cache_pages=16,
                      chaos=chaos)
    # cold admission never gathers -> cannot be a gather victim
    u0 = eng.submit(long_prompt, max_new_tokens=4)
    eng.run_to_completion()
    assert eng.status(u0) == "finished"
    # warm admission: rate 1.0 -> the gather deterministically fails
    u1 = eng.submit(long_prompt, max_new_tokens=4)
    eng.run_to_completion()
    assert eng.status(u1) == "failed"
    assert ("gather_fail" in {k for k, _, _ in chaos.events})
    assert chaos.injected_uids == {u1}
    # max_injections exhausted: the retry reuses the cache and matches
    # the cold run token-for-token (no refs/pages were leaked by the
    # failed admission)
    u2 = eng.submit(long_prompt, max_new_tokens=4)
    eng.run_to_completion()
    assert eng.status(u2) == "finished"
    assert eng.result(u2) == eng.result(u0)
    assert eng.stats["prefix_hits"] >= 1
    assert eng._pc.referenced_nodes == 0
    _check_conservation(eng)


# ---------------------------------------------------------------------------
# mid-tick abort / delay injection
# ---------------------------------------------------------------------------

def test_chaos_mid_tick_aborts_change_nothing(params, prompts):
    """Raise-only chaos at tick phase boundaries: ticks abort and are
    retried, device-resident handoff tokens are flushed (not
    overwritten), and every request still finishes with exactly the
    chaos-free tokens."""
    free, fu = _run(params, prompts, slots=2, cache_pages=16)
    chaos = ChaosInjector(ChaosConfig(seed=3, rate=0.3,
                                      corrupt_logits=False,
                                      fail_gather=False,
                                      raise_mid_tick=True,
                                      delay_mid_tick=True))
    eng, uids = _run(params, prompts, chaos=chaos, slots=2,
                     cache_pages=16)
    aborts = [e for e in chaos.events if e[0] == "raise"]
    assert aborts, "the pinned (seed, rate) schedule must abort ticks"
    assert eng.stats["chaos_aborted_ticks"] == len(aborts)
    # delays are *virtual* stall ticks (no wall clock): every consumed
    # stall is counted, and none can exceed what the fired events accrued
    delays = [e for e in chaos.events if e[0] == "delay"]
    assert delays, "the pinned (seed, rate) schedule must fire delays"
    assert 0 < eng.stats["chaos_delayed_ticks"] \
        <= len(delays) * chaos.config.delay_ticks
    for u, f in zip(uids, fu):
        assert eng.status(u) == "finished"
        assert eng.result(u) == free.result(f)
    assert eng.stats["host_syncs"] <= (eng.stats["decode_dispatches"]
                                       + eng.stats["handoff_syncs"])
    assert eng._pc.referenced_nodes == 0
    _check_conservation(eng)


def test_chaos_step_propagates_chaos_error(params, prompts):
    """Callers driving step() by hand see the ChaosError; the engine
    is left consistent and the next step() simply resumes."""
    chaos = ChaosInjector(ChaosConfig(seed=3, rate=1.0,
                                      corrupt_logits=False,
                                      fail_gather=False,
                                      raise_mid_tick=True))
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8, chaos=chaos)
    u = eng.submit(prompts[0], max_new_tokens=2)
    with pytest.raises(ChaosError):
        eng.step()
    _check_conservation(eng)
    assert eng.status(u) in ("queued", "prefilling", "decoding")


# ---------------------------------------------------------------------------
# injector plumbing
# ---------------------------------------------------------------------------

def test_chaos_config_validation():
    with pytest.raises(ValueError):
        ChaosConfig(rate=1.5)
    with pytest.raises(ValueError):
        ChaosConfig(rate=-0.1)
    with pytest.raises(ValueError):
        ChaosConfig(delay_ticks=-1)
    with pytest.raises(ValueError):
        ChaosConfig(spill_pages=-1)
    with pytest.raises(ValueError):
        ChaosConfig(max_injections=-1)


def test_chaos_rate_zero_is_injection_free(params, prompts):
    free, fu = _run(params, prompts)
    chaos = ChaosInjector(ChaosConfig(seed=9, rate=0.0))
    eng, uids = _run(params, prompts, chaos=chaos)
    assert chaos.events == []
    # stats must match counter-for-counter; the tick_ns_* keys are
    # wall-clock timings and host_sync_stalls races the device's
    # is_ready() against real time — both legitimately differ
    strip = lambda st: {k: v for k, v in st.items()
                        if not k.startswith("tick_ns")
                        and k != "host_sync_stalls"}
    assert strip(eng.stats) == strip(free.stats)
    for u, f in zip(uids, fu):
        assert eng.result(u) == free.result(f)
