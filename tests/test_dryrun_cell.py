"""Dry-run integration tests (subprocess with 512 placeholder devices):
one fast cell per mesh compiles and yields sane roofline terms. The full
34-cell x 2-mesh sweep runs via ``python -m repro.launch.dryrun`` and is
recorded in EXPERIMENTS.md; these tests keep the machinery from rotting.
"""
from __future__ import annotations

import pytest

from tests.helpers import check, run_with_devices

_CELL = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
rec = run_cell("xlstm-350m", "decode_32k", multi_pod={mp}, verbose=False)
assert rec["flops_per_device"] > 0
assert rec["bytes_per_device"] > 0
assert rec["memory"]["peak_device_bytes"] < 16 * 2**30   # fits v5e HBM
assert rec["chips"] == {chips}
print("OK", rec["bottleneck"], rec["memory"]["peak_device_bytes"])
"""


@pytest.mark.slow
def test_single_pod_cell():
    out = check(run_with_devices(_CELL.format(mp=False, chips=256),
                                 devices=512, timeout=900))
    assert "OK" in out


@pytest.mark.slow
def test_multi_pod_cell():
    out = check(run_with_devices(_CELL.format(mp=True, chips=512),
                                 devices=512, timeout=900))
    assert "OK" in out


@pytest.mark.slow
def test_a3_decode_cell_reduces_memory_term():
    """The paper's technique must reduce the decode memory term (H3)."""
    out = check(run_with_devices("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.config import A3Config
from repro.launch.dryrun import run_cell
base = run_cell("internlm2-1.8b", "decode_32k", verbose=False)
a3 = run_cell("internlm2-1.8b", "decode_32k", verbose=False,
              a3=A3Config.aggressive())
print("OK", base["memory_s"], a3["memory_s"])
""", devices=512, timeout=1800))
    assert "OK" in out
