"""Serving conformance suite: the engine is an *oracle-checked* system.

Chunked + ragged admission prefill and multi-step *blocked* decode are
pure scheduling changes — they must not alter what the model computes.
Every test here pins ``ServeEngine`` generations against the sequential
single-request reference (whole-prompt ``decoder.prefill`` + a scalar
decode loop), across slot counts, admission orders, ``prefill_chunk``
settings (including the whole-prompt ``None`` mode), and
``decode_block`` sizes (T decode steps per dispatch with in-graph
sampling + in-graph A^3 re-sort), plus the engine's dispatch/sync-count
invariants:

* ``decode_steps == T * decode_dispatches`` (executed scan iterations),
  with ``decode_dispatches <= decode_steps_advanced <= decode_steps``
  (the steps that advanced at least one lane; T=1 recovers the old
  one-step-per-tick engine exactly)
* ``decode_dispatches <= ceil(decode_steps_advanced / T) +
  prefill_dispatches`` — the falsifiable dispatch-efficiency bound: a
  partial block (every active lane finishes in it) can only follow a
  prefill dispatch that flipped its cohort to DECODING
* ``prefill_dispatches <= ticks``        (one ragged prefill per tick)
* ``host_syncs <= decode_dispatches + handoff_syncs`` — the
  device-resident prefill->decode handoff: one ring harvest per decode
  dispatch, prefill ticks never block (a finishing lane's in-graph
  first-token draw rides the same tick's decode block), and
  ``handoff_syncs`` counts the rare direct reads when a prompt finishes
  with no decode block to ride (budget 1 / max_len-length prompt)
* both ``host_syncs`` and ``decode_dispatches`` are bounded by
  ``ceil(decode_steps / T) + prefill_dispatches`` (the sync-elimination
  acceptance bound): syncs per generated token fall as ~1/T.

Chunked admission covers EVERY architecture through the per-segment
mixer-state interface: the recurrent sections below pin chunked ==
whole-prompt generations token-for-token for downscaled RG-LRU and
xLSTM configs across chunk sizes {8, 64, whole}, admission orders, and
mid-prompt chunk boundaries, with pad-lane state required bit-identical
to untouched.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import check, given, run_with_devices, settings, st

from repro.config import A3Config, AttentionKind, BlockKind, ModelConfig
from repro.models import decoder as dec
from repro.serve.engine import ServeEngine

TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                   dtype="float32")
# downscaled recurrent/hybrid archs: the mixer-state interface must
# carry mid-prompt recurrent state across chunk boundaries for these
# (recurrentgemma-like RG-LRU pattern; xlstm-like mLSTM/sLSTM pattern)
TINY_RG = ModelConfig("tiny-rg", "hybrid", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, head_dim=16,
                      attention_kind=AttentionKind.SLIDING, window_size=24,
                      block_pattern=(BlockKind.RGLRU, BlockKind.RGLRU,
                                     BlockKind.ATTENTION),
                      act="gelu", dtype="float32")
TINY_XL = ModelConfig("tiny-xl", "ssm", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=256,
                      head_dim=16,
                      block_pattern=(BlockKind.MLSTM, BlockKind.MLSTM,
                                     BlockKind.SLSTM),
                      dtype="float32")
MAX_LEN = 96
MAX_NEW = 6
PROMPT_LENS = (5, 12, 23, 31, 9)


@pytest.fixture(scope="module")
def params():
    return dec.init_params(jax.random.PRNGKey(0), TINY)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(7)
    return [rng.integers(0, TINY.vocab_size, size=n) for n in PROMPT_LENS]


def _reference_generate(params, prompt, max_new, a3=A3Config(), cfg=TINY):
    """Sequential single-request oracle: whole-prompt prefill + scalar
    greedy decode (no batching, no chunking, no engine)."""
    use_a3 = a3.mode.value != "off"
    lg, cache = dec.prefill(params, cfg, jnp.asarray(prompt, jnp.int32)[None],
                            max_len=MAX_LEN, a3=use_a3)
    cur, pos, out = int(jnp.argmax(lg[0])), len(prompt), []
    out.append(cur)
    for _ in range(max_new - 1):
        lg, cache = dec.decode_step(params, cfg, cache,
                                    jnp.asarray([cur], jnp.int32),
                                    jnp.int32(pos), a3=a3)
        cur = int(jnp.argmax(lg[0]))
        out.append(cur)
        pos += 1
    return out


@pytest.fixture(scope="module")
def refs(params, prompts):
    return [_reference_generate(params, p, MAX_NEW) for p in prompts]


def _assert_invariants(eng):
    t, s = eng.decode_block, eng.stats
    assert s["decode_steps"] == t * s["decode_dispatches"]  # scan iterations
    # decode_steps_advanced = sequential steps that advanced at least one
    # lane (deepest lane per dispatch); the gap is partial-block padding
    adv = s["decode_steps_advanced"]
    assert s["decode_dispatches"] <= adv <= s["decode_steps"]
    # falsifiable dispatch-efficiency bound on the *advanced* work: a
    # partial block means every active lane finished in it, which can
    # only follow a prefill dispatch that flipped that cohort to
    # DECODING — so an engine that re-dispatched blocks for finished
    # slots (inflating dispatches without advancing lanes) fails here
    assert s["decode_dispatches"] <= (math.ceil(adv / t)
                                      + s["prefill_dispatches"])
    # chunked admission covers every mode (prefill_chunk=None uses
    # the default min(max_len, 512) chunk): at most one ragged
    # prefill dispatch per tick
    assert s["prefill_dispatches"] <= s["ticks"]
    # the device-resident prefill->decode handoff bound: one ring
    # harvest per decode dispatch — prefill ticks never block — plus
    # the rare direct first-token read when a prompt finishes with no
    # decode block to ride (budget 1 or a max_len-length prompt)
    assert s["host_syncs"] <= s["decode_dispatches"] + s["handoff_syncs"]
    assert s["handoff_syncs"] <= s["prefill_dispatches"]
    # the sync-elimination acceptance bound: with decode_block=T both
    # the dispatch count and the host-sync count are at most
    # ceil(decode_steps / T) + prefill_dispatches
    bound = math.ceil(s["decode_steps"] / t) + s["prefill_dispatches"]
    assert s["decode_dispatches"] <= bound
    assert s["host_syncs"] <= bound


def _run_engine(params, prompts, *, slots, chunk, order="upfront",
                a3=A3Config(), resort_every=64, decode_block=1, cfg=TINY):
    eng = ServeEngine(params, cfg, slots=slots, max_len=MAX_LEN, a3=a3,
                      prefill_chunk=chunk, resort_every=resort_every,
                      decode_block=decode_block)
    uids = {}
    if order == "upfront":
        for i, p in enumerate(prompts):
            uids[i] = eng.submit(p, max_new_tokens=MAX_NEW)
        eng.run_to_completion()
    elif order == "reversed":
        for i in reversed(range(len(prompts))):
            uids[i] = eng.submit(prompts[i], max_new_tokens=MAX_NEW)
        eng.run_to_completion()
    elif order == "staggered":
        pending = list(enumerate(prompts))
        while pending or eng._queue or any(s.active for s in eng.slots):
            if pending and eng.stats["ticks"] % 2 == 0:
                i, p = pending.pop(0)
                uids[i] = eng.submit(p, max_new_tokens=MAX_NEW)
            eng.step()
    else:
        raise ValueError(order)
    return {i: eng.result(u) for i, u in uids.items()}, eng


# ---------------------------------------------------------------------------
# chunking is output-invariant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("slots", [1, 4])
@pytest.mark.parametrize("chunk", [8, 64, None])  # None = default chunk
def test_engine_matches_sequential_reference(params, prompts, refs, slots,
                                             chunk):
    """Engine generations are identical to per-request sequential decode
    for every (slot count, prefill chunking) combination — chunk
    boundaries and ragged admission batching change *scheduling*, never
    outputs."""
    out, eng = _run_engine(params, prompts, slots=slots, chunk=chunk)
    for i, ref in enumerate(refs):
        assert out[i] == ref, (i, chunk, slots)
    _assert_invariants(eng)


def test_admission_order_does_not_change_outputs(params, prompts, refs):
    """Each request's generation depends only on its own prompt — not on
    queue order or on which slots are decoding while it prefills."""
    for order in ("reversed", "staggered"):
        out, eng = _run_engine(params, prompts, slots=4, chunk=8,
                               order=order)
        for i, ref in enumerate(refs):
            assert out[i] == ref, (i, order)
        _assert_invariants(eng)


def test_ragged_admission_batches_prefills(params, prompts):
    """With chunk >= every prompt, all slots admitted on the same tick
    prefill in ONE padded dispatch — strictly fewer dispatches than the
    one-prefill-per-admit path."""
    out, eng = _run_engine(params, prompts, slots=4, chunk=64)
    # 5 requests through 4 slots: 4 admitted on tick 1 (1 dispatch), the
    # 5th after a slot frees (1 more) — far fewer than 5 per-admit calls.
    assert eng.stats["prefill_dispatches"] <= 2
    assert eng.stats["prefill_tokens"] == sum(PROMPT_LENS)
    _assert_invariants(eng)


def test_long_prompt_prefill_interleaves_with_decode(params, prompts, refs):
    """A long prompt admitted mid-stream advances chunk-by-chunk while
    already-decoding slots keep producing a token every tick (no
    multi-tick stall), and still generates the reference tokens."""
    rng = np.random.default_rng(11)
    long_prompt = rng.integers(0, TINY.vocab_size, size=64)
    long_ref = _reference_generate(params, long_prompt, MAX_NEW)

    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8)
    u0 = eng.submit(prompts[0], max_new_tokens=16)
    eng.step()                       # prompt 0 starts prefilling
    eng.step()
    gen_before = len(eng.slots[0].generated)
    u1 = eng.submit(long_prompt, max_new_tokens=MAX_NEW)
    # 64-token prompt at chunk=8 -> 8 prefill ticks; slot 0 must advance
    # by one token on every one of them.
    for _ in range(8):
        before = len(eng.slots[0].generated)
        eng.step()
        assert len(eng.slots[0].generated) == before + 1
    assert eng.slots[1].decoding     # long prompt finished prefilling
    eng.run_to_completion()
    assert eng.result(u1) == long_ref
    assert eng.result(u0) == _reference_generate(params, prompts[0], 16)
    _assert_invariants(eng)


# ---------------------------------------------------------------------------
# A^3 path: chunked incremental sort == whole-prompt comprehension sort
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [8, None])
def test_a3_chunked_matches_sequential_reference(params, prompts, chunk):
    a3 = A3Config.conservative()
    refs_a3 = [_reference_generate(params, p, MAX_NEW, a3=a3)
               for p in prompts[:3]]
    out, eng = _run_engine(params, prompts[:3], slots=2, chunk=chunk,
                           a3=a3, resort_every=4)
    for i, ref in enumerate(refs_a3):
        assert out[i] == ref, (i, chunk)
    _assert_invariants(eng)


# ---------------------------------------------------------------------------
# recurrent-arch chunked admission: the mixer-state interface carries
# mid-prompt RG-LRU / mLSTM / sLSTM state across chunk boundaries
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def rg_params():
    return dec.init_params(jax.random.PRNGKey(1), TINY_RG)


@pytest.fixture(scope="module")
def xl_params():
    return dec.init_params(jax.random.PRNGKey(2), TINY_XL)


def _recurrent_setup(cfg, rg_params, xl_params):
    return rg_params if cfg is TINY_RG else xl_params


@pytest.mark.parametrize("cfg", [TINY_RG, TINY_XL], ids=["rglru", "xlstm"])
@pytest.mark.parametrize("chunk", [8, 64, None])  # None = default chunk
def test_recurrent_engine_matches_whole_prompt_reference(
        rg_params, xl_params, prompts, cfg, chunk):
    """Chunked admission for recurrent/hybrid archs is token-for-token
    identical to the whole-prompt sequential reference across chunk
    sizes — chunk=8 puts boundaries mid-prompt (23- and 31-token
    prompts), exercising the carried conv tail / LRU hidden / matrix
    and cell states; chunk=64 covers every prompt in one chunk; None
    admits through the default min(max_len, 512) chunk — a single
    dispatch at these sizes."""
    params = _recurrent_setup(cfg, rg_params, xl_params)
    refs = [_reference_generate(params, p, MAX_NEW, cfg=cfg)
            for p in prompts[:3]]
    out, eng = _run_engine(params, prompts[:3], slots=2, chunk=chunk,
                           cfg=cfg)
    for i, ref in enumerate(refs):
        assert out[i] == ref, (cfg.name, i, chunk)
    _assert_invariants(eng)


@pytest.mark.parametrize("cfg", [TINY_RG, TINY_XL], ids=["rglru", "xlstm"])
@pytest.mark.parametrize("order", ["reversed", "staggered"])
def test_recurrent_admission_order_does_not_change_outputs(
        rg_params, xl_params, prompts, cfg, order):
    """Recurrent-arch generations are independent of admission order and
    of which slots decode while others prefill (mixed ticks: decoding
    lanes ride the prefill dispatch at length 0, prefilling lanes ride
    the decode block at pos=-1 — both must leave recurrent state
    untouched)."""
    params = _recurrent_setup(cfg, rg_params, xl_params)
    refs = [_reference_generate(params, p, MAX_NEW, cfg=cfg)
            for p in prompts[:3]]
    out, eng = _run_engine(params, prompts[:3], slots=2, chunk=8,
                           order=order, decode_block=4, cfg=cfg)
    for i, ref in enumerate(refs):
        assert out[i] == ref, (cfg.name, i, order)
    _assert_invariants(eng)


@pytest.mark.parametrize("cfg", [TINY_RG, TINY_XL], ids=["rglru", "xlstm"])
@pytest.mark.parametrize("plen,chunk", [(23, 8), (7, 3), (16, 16), (30, 7)])
def test_recurrent_prefill_chunk_extends_cache_like_whole_prompt(
        rg_params, xl_params, cfg, plen, chunk):
    """Decoder-level: running a prompt through prefill_chunk in any
    chunk split yields the same recurrent states (conv tail, LRU h,
    mLSTM (C, n, m), sLSTM (c, n, m, h)) and final logits as one
    whole-prompt prefill — including splits with mid-prompt boundaries
    and chunks that don't divide the prompt."""
    params = _recurrent_setup(cfg, rg_params, xl_params)
    rng = np.random.default_rng(plen * 100 + chunk)
    p = rng.integers(0, cfg.vocab_size, size=plen)
    lg_ref, cache_ref = dec.prefill(params, cfg,
                                    jnp.asarray(p, jnp.int32)[None],
                                    max_len=32)
    cache = dec.init_cache(cfg, 1, 32)
    cur, lg = 0, None
    while cur < plen:
        take = min(chunk, plen - cur)
        toks = np.zeros((1, chunk), np.int32)
        toks[0, :take] = p[cur:cur + take]
        lg, cache = dec.prefill_chunk(params, cfg, cache,
                                      jnp.asarray(toks),
                                      jnp.asarray([cur], jnp.int32),
                                      jnp.asarray([take], jnp.int32))
        cur += take
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                               rtol=3e-5, atol=3e-5)
    flat_c, _ = jax.tree_util.tree_flatten_with_path(cache)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(cache_ref)
    for (ka, a), (kb, b) in zip(flat_c, flat_r):
        assert str(ka) == str(kb)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-5, atol=3e-5, err_msg=str(ka))


@pytest.mark.parametrize("cfg", [TINY_RG, TINY_XL], ids=["rglru", "xlstm"])
def test_recurrent_pad_lane_state_is_bit_identical(rg_params, xl_params,
                                                   cfg):
    """Uniform ragged pad-lane masking: a lane riding a chunk dispatch
    with length 0 and a lane riding a decode step at pos=-1 keep every
    recurrent state leaf BIT-identical (np.testing.assert_array_equal,
    not allclose) — the engine interleaves such ride-alongs on every
    mixed prefill/decode tick."""
    params = _recurrent_setup(cfg, rg_params, xl_params)
    rng = np.random.default_rng(3)
    p = rng.integers(0, cfg.vocab_size, size=(2, 9))
    _, cache = dec.prefill(params, cfg, jnp.asarray(p, jnp.int32),
                           max_len=32)
    # chunk dispatch: lane 1 rides at length 0
    toks = np.zeros((2, 4), np.int32)
    toks[0] = rng.integers(0, cfg.vocab_size, size=4)
    _, new_cache = dec.prefill_chunk(params, cfg, cache,
                                     jnp.asarray(toks),
                                     jnp.asarray([9, 0], jnp.int32),
                                     jnp.asarray([4, 0], jnp.int32))
    flat_n, _ = jax.tree_util.tree_flatten_with_path(new_cache)
    flat_o, _ = jax.tree_util.tree_flatten_with_path(cache)
    for (ka, a), (kb, b) in zip(flat_n, flat_o):
        np.testing.assert_array_equal(np.asarray(a)[:, 1],
                                      np.asarray(b)[:, 1], err_msg=str(ka))
    # decode dispatch: lane 1 rides at pos=-1
    tok = jnp.asarray([5, 6], jnp.int32)
    pos = jnp.asarray([9, -1], jnp.int32)
    _, dec_cache = dec.decode_step(params, cfg, cache, tok, pos)
    flat_d, _ = jax.tree_util.tree_flatten_with_path(dec_cache)
    for (ka, a), (kb, b) in zip(flat_d, flat_o):
        np.testing.assert_array_equal(np.asarray(a)[:, 1],
                                      np.asarray(b)[:, 1], err_msg=str(ka))


@pytest.mark.parametrize("cfg", [TINY_RG, TINY_XL], ids=["rglru", "xlstm"])
def test_recurrent_fresh_lane_resets_stale_slot_state(rg_params, xl_params,
                                                      cfg):
    """A lane admitted at pos=0 into a slot holding a finished request's
    recurrent state must reset it in-graph: the chunked cache equals a
    from-scratch chunked prefill of the new prompt."""
    params = _recurrent_setup(cfg, rg_params, xl_params)
    rng = np.random.default_rng(4)
    stale = rng.integers(0, cfg.vocab_size, size=(1, 13))
    _, cache = dec.prefill(params, cfg, jnp.asarray(stale, jnp.int32),
                           max_len=32)          # slot holds stale state
    p = rng.integers(0, cfg.vocab_size, size=(1, 6))
    toks = jnp.asarray(p, jnp.int32)
    _, reused = dec.prefill_chunk(params, cfg, cache, toks,
                                  jnp.asarray([0], jnp.int32),
                                  jnp.asarray([6], jnp.int32))
    _, scratch = dec.prefill_chunk(params, cfg, dec.init_cache(cfg, 1, 32),
                                   toks, jnp.asarray([0], jnp.int32),
                                   jnp.asarray([6], jnp.int32))
    flat_a, _ = jax.tree_util.tree_flatten_with_path(reused)
    flat_b, _ = jax.tree_util.tree_flatten_with_path(scratch)
    for (ka, a), (kb, b) in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(ka))


# ---------------------------------------------------------------------------
# blocked decode: T scanned steps per dispatch == per-step sequential
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block", [4, 16])
@pytest.mark.parametrize("chunk", [8, None])
def test_blocked_decode_matches_sequential_reference(params, prompts, refs,
                                                     block, chunk):
    """decode_block=T runs T decode steps per jitted dispatch with
    in-graph sampling; generations must be token-for-token identical to
    the per-step sequential reference. MAX_NEW=6 < 16 forces mid-block
    slot finishes (masked lanes with dropped ring writes) at block=16,
    and 5 remaining decode steps against block=4 forces a partial
    second block."""
    out, eng = _run_engine(params, prompts, slots=4, chunk=chunk,
                           decode_block=block)
    for i, ref in enumerate(refs):
        assert out[i] == ref, (i, block, chunk)
    _assert_invariants(eng)


@pytest.mark.parametrize("block", [4, 16])
def test_blocked_decode_mixed_prefill_decode_ticks(params, prompts, refs,
                                                   block):
    """Ticks where some lanes prefill a chunk while others run a decode
    block: prefilling lanes ride the block at pos=-1, and admission
    order stays irrelevant to outputs."""
    for order in ("reversed", "staggered"):
        out, eng = _run_engine(params, prompts, slots=4, chunk=8,
                               order=order, decode_block=block)
        for i, ref in enumerate(refs):
            assert out[i] == ref, (i, order, block)
        _assert_invariants(eng)


def test_blocked_decode_cuts_host_syncs_per_token(params, prompts):
    """The point of the tentpole: same workload, same tokens, ~1/T the
    host syncs and dispatches on decode-heavy traffic."""
    outs, stats = {}, {}
    for block in (1, 8):
        out, eng = _run_engine(params, prompts, slots=4, chunk=64,
                               decode_block=block)
        outs[block], stats[block] = out, eng.stats
    assert outs[1] == outs[8]
    assert stats[8]["decode_dispatches"] < stats[1]["decode_dispatches"]
    assert stats[8]["host_syncs"] < stats[1]["host_syncs"]


@pytest.mark.parametrize("block", [4, 16])
def test_a3_blocked_decode_across_resort_boundaries(params, prompts, block):
    """A^3 blocked decode with an aggressive re-sort cadence: the
    in-graph watermark check fires mid-block, and the blocked engine
    must replay the per-step engine's schedule exactly — same tokens,
    same re-sort count (host mirror)."""
    a3 = A3Config.conservative()
    ref_out, ref_eng = _run_engine(params, prompts[:3], slots=2, chunk=8,
                                   a3=a3, resort_every=2, decode_block=1)
    out, eng = _run_engine(params, prompts[:3], slots=2, chunk=8, a3=a3,
                           resort_every=2, decode_block=block)
    assert ref_eng.stats["resorts"] > 0          # boundaries were crossed
    for i in ref_out:
        assert out[i] == ref_out[i], (i, block)
    assert eng.stats["resorts"] == ref_eng.stats["resorts"]
    _assert_invariants(eng)


@pytest.mark.parametrize("resort_every", [0, 2])
def test_in_graph_resort_advances_device_watermark(params, resort_every):
    """The engine's jitted dispatch must actually run the in-graph
    resort (not just count it host-side): after decoding past the
    cadence, the *device* ``sorted_upto`` watermark equals the host
    mirror's prediction, and ``stats["resorts"]`` matches. Also covers
    the ``resort_every=0`` clamp (historical meaning: resort whenever
    any fresh tail exists, i.e. cadence 1)."""
    plen, new = 10, 5
    rng = np.random.default_rng(9)
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8, a3=A3Config.conservative(),
                      resort_every=resort_every, decode_block=4)
    eng.submit(rng.integers(0, TINY.vocab_size, size=plen),
               max_new_tokens=new)
    eng.run_to_completion()
    upto, resorts = plen, 0
    cadence = max(1, resort_every)
    for pos in range(plen, plen + new - 1):   # decode-step positions
        if pos - upto >= cadence:
            upto, resorts = pos, resorts + 1
    dev_upto = int(np.asarray(
        jax.device_get(eng.cache["seg0"]["sorted_upto"]))[0, 0])
    assert dev_upto == upto
    assert eng.stats["resorts"] == resorts * eng._n_a3_segs
    assert resorts > 0                        # the scenario is non-trivial


def test_decode_block_one_step_equals_decode_step(params):
    """decoder.decode_block with steps=1 is decode_step + in-graph
    argmax: same ring token, same cache update."""
    rng = np.random.default_rng(5)
    p = rng.integers(0, TINY.vocab_size, size=(2, 9))
    _, cache = dec.prefill(params, TINY, jnp.asarray(p, jnp.int32),
                           max_len=32)
    tok = jnp.asarray([5, 6], jnp.int32)
    pos = jnp.asarray([9, 9], jnp.int32)
    lg, cache_ref = dec.decode_step(params, TINY,
                                    jax.tree.map(lambda x: x, cache),
                                    tok, pos)
    ring, carry, cache_blk = dec.decode_block(params, TINY, cache, tok,
                                              pos,
                                              jnp.asarray([1, 1],
                                                          jnp.int32),
                                              steps=1)
    # the carry is the scan's final token — with one step, the ring's
    # only column (the value the pipelined engine feeds the next block)
    np.testing.assert_array_equal(np.asarray(carry), np.asarray(ring[:, 0]))
    np.testing.assert_array_equal(np.asarray(ring[:, 0]),
                                  np.asarray(jnp.argmax(lg, -1)))
    flat_b, _ = jax.tree_util.tree_flatten_with_path(cache_blk)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(cache_ref)
    for (ka, a), (kb, b) in zip(flat_b, flat_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6, err_msg=str(ka))


def test_decode_block_exhausted_lane_rides_along(params):
    """A lane whose steps_left hits 0 mid-block freezes: ring entries
    read -1 and its cache rows stay bit-identical from that step on."""
    rng = np.random.default_rng(6)
    p = rng.integers(0, TINY.vocab_size, size=(2, 9))
    _, cache = dec.prefill(params, TINY, jnp.asarray(p, jnp.int32),
                           max_len=32)
    tok = jnp.asarray([5, 6], jnp.int32)
    pos = jnp.asarray([9, 9], jnp.int32)
    ring, carry, cache_blk = dec.decode_block(
        params, TINY, jax.tree.map(lambda x: x, cache), tok, pos,
        jnp.asarray([4, 2], jnp.int32), steps=4)
    ring, carry = np.asarray(ring), np.asarray(carry)
    assert (ring[0] >= 0).all()
    assert (ring[1, :2] >= 0).all() and (ring[1, 2:] == -1).all()
    # the carry holds each lane's LAST emitted token — the exhausted
    # lane's froze at its final pre-exhaustion value, not at -1
    assert carry[0] == ring[0, -1] and carry[1] == ring[1, 1]
    # lane 1's cache must equal a 2-step blocked decode of lane 1 alone
    cache1 = jax.tree.map(lambda x: x[:, 1:2], cache)
    _, _, cache1_ref = dec.decode_block(params, TINY, cache1, tok[1:],
                                        pos[1:],
                                        jnp.asarray([2], jnp.int32),
                                        steps=2)
    flat_b, _ = jax.tree_util.tree_flatten_with_path(cache_blk)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(cache1_ref)
    for (ka, a), (kb, b) in zip(flat_b, flat_r):
        np.testing.assert_allclose(np.asarray(a)[:, 1:2], np.asarray(b),
                                   rtol=1e-6, atol=1e-6, err_msg=str(ka))


def _run_sampling_engine(params, ps, *, block, seed=3):
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8, decode_block=block,
                      temperature=0.8, sample_seed=seed)
    uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in ps]
    eng.run_to_completion()
    return [eng.result(u) for u in uids]


def test_temperature_sampling_blocking_invariant(params, prompts, refs):
    """temperature > 0 draws in-graph from the tempered softmax, keyed
    per (seed, request uid, position): draws are identical across
    decode_block sizes (the key folds the absolute position, not the
    step index), requests with identical prompts diverge (distinct uid
    key streams — including the *first* token, which is drawn at the
    prefill handoff, not argmax'd), and the sampled stream differs from
    greedy. Also the only place the rng/sample_ids dispatch variant is
    traced."""
    outs = {b: _run_sampling_engine(params, prompts[:2], block=b)
            for b in (1, 4)}
    for b, out in outs.items():
        for r in out:
            assert r is not None and len(r) == MAX_NEW
            assert max(r) < TINY.vocab_size, b
    assert outs[1] == outs[4]
    assert outs[1][0] != refs[0]        # sampling engaged, not argmax
    # same prompt submitted twice -> different uids -> decorrelated
    # draws from the very first token
    twin = _run_sampling_engine(params, [prompts[0], prompts[0]], block=4)
    assert twin[0] != twin[1]
    assert twin[0][0] != twin[1][0]     # first token sampled per-request


# ---------------------------------------------------------------------------
# in-graph A^3 re-sort == host-side sort of the ring
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    pos=st.lists(st.integers(min_value=-1, max_value=30), min_size=3,
                 max_size=3),
    upto=st.lists(st.integers(min_value=0, max_value=20), min_size=3,
                  max_size=3),
    resort_every=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_resort_sorted_keys_matches_host_sort(pos, upto, resort_every,
                                              seed):
    """Property: ``decoder.resort_sorted_keys`` leaves due lanes'
    sk_vals/sk_rows identical to a host-side ``sort_key_columns`` of
    the ring (and advances their watermark to ``pos``), while non-due
    lanes keep all three leaves bit-identical."""
    from repro.core.candidate_selection import sort_key_columns
    rng = np.random.default_rng(seed)
    L, B, H, W, D = 2, 3, 2, 8, 4
    k = jnp.asarray(rng.normal(size=(L, B, H, W, D)), jnp.float32)
    stale_v = jnp.asarray(rng.normal(size=(L, B, H, W, D)), jnp.float32)
    stale_r = jnp.asarray(rng.integers(0, W, size=(L, B, H, W, D)),
                          jnp.int32)
    upto_a = jnp.asarray(np.broadcast_to(np.asarray(upto, np.int32),
                                         (L, B)))
    cache = {"seg0": {"k": k, "v": jnp.zeros_like(k), "sk_vals": stale_v,
                      "sk_rows": stale_r, "sorted_upto": upto_a},
             "seg1": {"k": k + 1, "v": jnp.zeros_like(k)}}  # no sk: untouched
    pos_a = jnp.asarray(pos, jnp.int32)
    out = dec.resort_sorted_keys(cache, pos_a, resort_every)
    ref = jax.vmap(jax.vmap(jax.vmap(sort_key_columns)))(k)
    for b in range(B):
        due = pos[b] >= 0 and pos[b] - upto[b] >= resort_every
        if due:
            np.testing.assert_array_equal(
                np.asarray(out["seg0"]["sk_vals"][:, b]),
                np.asarray(ref.values[:, b]))
            np.testing.assert_array_equal(
                np.asarray(out["seg0"]["sk_rows"][:, b]),
                np.asarray(ref.rows[:, b]))
            assert (np.asarray(out["seg0"]["sorted_upto"][:, b])
                    == pos[b]).all()
        else:
            np.testing.assert_array_equal(
                np.asarray(out["seg0"]["sk_vals"][:, b]),
                np.asarray(stale_v[:, b]))
            np.testing.assert_array_equal(
                np.asarray(out["seg0"]["sk_rows"][:, b]),
                np.asarray(stale_r[:, b]))
            assert (np.asarray(out["seg0"]["sorted_upto"][:, b])
                    == upto[b]).all()
    # segments without sorted-key state pass through untouched
    np.testing.assert_array_equal(np.asarray(out["seg1"]["k"]),
                                  np.asarray(cache["seg1"]["k"]))


# ---------------------------------------------------------------------------
# decoder-level: prefill_chunk == prefill (cache + logits)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a3", [False, True])
@pytest.mark.parametrize("plen,chunk", [(23, 8), (23, 64), (7, 3), (16, 16)])
def test_prefill_chunk_extends_cache_like_whole_prompt(params, a3, plen,
                                                       chunk):
    """Running a prompt through prefill_chunk in any chunk split yields
    the same cache rows (incl. the A^3 sorted-key matrices and
    watermarks) and final logits as one whole-prompt prefill."""
    rng = np.random.default_rng(plen * 100 + chunk)
    p = rng.integers(0, TINY.vocab_size, size=plen)
    lg_ref, cache_ref = dec.prefill(params, TINY,
                                    jnp.asarray(p, jnp.int32)[None],
                                    max_len=32, a3=a3)
    cache = dec.init_cache(TINY, 1, 32, a3=a3)
    cur = 0
    lg = None
    while cur < plen:
        take = min(chunk, plen - cur)
        toks = np.zeros((1, chunk), np.int32)
        toks[0, :take] = p[cur:cur + take]
        lg, cache = dec.prefill_chunk(params, TINY, cache,
                                      jnp.asarray(toks),
                                      jnp.asarray([cur], jnp.int32),
                                      jnp.asarray([take], jnp.int32),
                                      a3=a3)
        cur += take
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                               rtol=1e-5, atol=1e-5)
    flat_c, _ = jax.tree_util.tree_flatten_with_path(cache)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(cache_ref)
    for (ka, a), (kb, b) in zip(flat_c, flat_r):
        assert str(ka) == str(kb)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5, err_msg=str(ka))


@pytest.mark.parametrize("plen,chunk", [(24, 20), (30, 7), (16, 16)])
def test_prefill_chunk_ring_wrap_matches_whole_prompt(plen, chunk):
    """Sliding-window segments keep an O(window) ring; prompts longer
    than the ring wrap it, and chunks longer than the ring land only
    their last ``w`` positions — chunked prefill must still reproduce
    whole-prompt prefill (which computes windowed attention over the
    full prompt and stores the last ``w`` rows)."""
    import dataclasses
    from repro.config import AttentionKind
    swa = dataclasses.replace(TINY, name="tiny-swa",
                              attention_kind=AttentionKind.SLIDING,
                              window_size=16)
    params = dec.init_params(jax.random.PRNGKey(1), swa)
    rng = np.random.default_rng(plen * 10 + chunk)
    p = rng.integers(0, swa.vocab_size, size=plen)
    lg_ref, cache_ref = dec.prefill(params, swa,
                                    jnp.asarray(p, jnp.int32)[None],
                                    max_len=32)
    cache = dec.init_cache(swa, 1, 32)
    cur, lg = 0, None
    while cur < plen:
        take = min(chunk, plen - cur)
        toks = np.zeros((1, chunk), np.int32)
        toks[0, :take] = p[cur:cur + take]
        lg, cache = dec.prefill_chunk(params, swa, cache,
                                      jnp.asarray(toks),
                                      jnp.asarray([cur], jnp.int32),
                                      jnp.asarray([take], jnp.int32))
        cur += take
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                               rtol=1e-5, atol=1e-5)
    flat_c, _ = jax.tree_util.tree_flatten_with_path(cache)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(cache_ref)
    for (ka, a), (kb, b) in zip(flat_c, flat_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5, err_msg=str(ka))


@pytest.mark.parametrize("block", [1, 4])
def test_prompt_at_max_len_finishes_with_prefill_token(params, block):
    """A prompt of length >= max_len leaves no room to decode
    (``pos >= max_len - 1`` immediately): the slot must finish with
    exactly its prefill token — no decode dispatch for it, no ring
    wrap-around write, and no -1 sentinels leaking into the result."""
    rng = np.random.default_rng(8)
    eng = ServeEngine(params, TINY, slots=2, max_len=16, prefill_chunk=8,
                      decode_block=block)
    u_long = eng.submit(rng.integers(0, TINY.vocab_size, size=16),
                        max_new_tokens=4)
    u_ok = eng.submit(rng.integers(0, TINY.vocab_size, size=5),
                      max_new_tokens=4)
    eng.run_to_completion()
    r = eng.result(u_long)
    assert len(r) == 1 and r[0] >= 0
    assert len(eng.result(u_ok)) == 4
    assert all(tok >= 0 for tok in eng.result(u_ok))
    _assert_invariants(eng)


def test_engine_rejects_empty_prompt(params):
    eng = ServeEngine(params, TINY, slots=1, max_len=32, prefill_chunk=8)
    with pytest.raises(ValueError):
        eng.submit(np.asarray([], np.int32))


def test_engine_rejects_frontend_arch(params):
    """Frontend archs serve from precomputed embeddings the token-prompt
    engine cannot carry — construction must raise, not degrade."""
    import dataclasses
    front = dataclasses.replace(TINY, frontend="audio_frames")
    with pytest.raises(ValueError):
        ServeEngine(params, front, slots=1, max_len=32)


def test_handoff_syncs_only_without_decode_block(params):
    """The device-resident handoff's sync accounting: a prompt whose
    budget is 1 finishes with only its prefill token and no decode
    block to ride — exactly one direct first-token read
    (handoff_syncs == 1). With budget >= 2 the first token rides the
    same tick's decode harvest and prefill ticks never block
    (handoff_syncs == 0, host_syncs == decode_dispatches)."""
    rng = np.random.default_rng(7)
    p = rng.integers(0, TINY.vocab_size, size=9)
    ref_lg, _ = dec.prefill(params, TINY, jnp.asarray(p, jnp.int32)[None],
                            max_len=32)
    first = int(jnp.argmax(ref_lg[0]))

    eng = ServeEngine(params, TINY, slots=1, max_len=32, prefill_chunk=8)
    u = eng.submit(p, max_new_tokens=1)
    eng.run_to_completion()
    assert eng.result(u) == [first]
    assert eng.stats["handoff_syncs"] == 1
    assert eng.stats["host_syncs"] == 1          # the direct read only
    _assert_invariants(eng)

    eng2 = ServeEngine(params, TINY, slots=1, max_len=32, prefill_chunk=8)
    u2 = eng2.submit(p, max_new_tokens=3)
    eng2.run_to_completion()
    assert eng2.result(u2)[0] == first
    assert len(eng2.result(u2)) == 3
    assert eng2.stats["handoff_syncs"] == 0
    assert eng2.stats["host_syncs"] == eng2.stats["decode_dispatches"]
    _assert_invariants(eng2)


def test_prefill_chunk_zero_length_lane_is_identity(params):
    """Lanes with length 0 (idle/decoding slots sharing the dispatch
    batch) pass their cache rows through bit-identically."""
    rng = np.random.default_rng(3)
    p = rng.integers(0, TINY.vocab_size, size=(2, 9))
    _, cache = dec.prefill(params, TINY, jnp.asarray(p, jnp.int32),
                           max_len=32)
    toks = np.zeros((2, 4), np.int32)
    toks[0] = rng.integers(0, TINY.vocab_size, size=4)
    _, new_cache = dec.prefill_chunk(params, TINY, cache,
                                     jnp.asarray(toks),
                                     jnp.asarray([9, 0], jnp.int32),
                                     jnp.asarray([4, 0], jnp.int32))
    flat_n, _ = jax.tree_util.tree_flatten_with_path(new_cache)
    flat_o, _ = jax.tree_util.tree_flatten_with_path(cache)
    for (ka, a), (kb, b) in zip(flat_n, flat_o):
        np.testing.assert_array_equal(np.asarray(a)[:, 1],
                                      np.asarray(b)[:, 1], err_msg=str(ka))


def test_decode_negative_pos_lane_drops_ring_write(params):
    """pos=-1 lanes (idle/prefilling engine slots riding along in the
    decode batch) must not touch their cache rows."""
    rng = np.random.default_rng(4)
    p = rng.integers(0, TINY.vocab_size, size=(2, 9))
    _, cache = dec.prefill(params, TINY, jnp.asarray(p, jnp.int32),
                           max_len=32)
    tok = jnp.asarray([5, 6], jnp.int32)
    pos = jnp.asarray([9, -1], jnp.int32)
    logits, new_cache = dec.decode_step(params, TINY, cache, tok, pos)
    flat_n, _ = jax.tree_util.tree_flatten_with_path(new_cache)
    flat_o, _ = jax.tree_util.tree_flatten_with_path(cache)
    for (ka, a), (kb, b) in zip(flat_n, flat_o):
        np.testing.assert_array_equal(np.asarray(a)[:, 1],
                                      np.asarray(b)[:, 1], err_msg=str(ka))
    # the active lane still decoded normally
    lg_ref, _ = dec.decode_step(params, TINY,
                                jax.tree.map(lambda x: x[:, :1], cache),
                                tok[:1], jnp.int32(9))
    np.testing.assert_allclose(np.asarray(logits[0]),
                               np.asarray(lg_ref[0]), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# sharded serve lowering (exercised on the multi-device CI matrix entry)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_serve_lowering_ragged_shapes():
    """The sharded serve path lowers the same ragged dispatches the
    engine runs: decode with a per-slot pos *vector* + donated cache,
    the chunked admission-prefill dispatch, and the multi-step scanned
    decode-block dispatch (in-graph sampling + A^3 re-sort) — so the
    blocked dispatch lowers under GSPMD on every PR."""
    out = check(run_with_devices("""
import jax
from repro.config import A3Config, ShapeConfig, ShapeKind, ShardingConfig, \\
    get_arch, smoke_variant
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import input_specs, lower_decode, \\
    lower_decode_block, lower_prefill_chunk

cfg = smoke_variant(get_arch("phi4-mini-3.8b"))
dshape = ShapeConfig("decode_smoke", ShapeKind.DECODE, 256, 8)
pshape = ShapeConfig("prefill_smoke", ShapeKind.PREFILL, 256, 8)
spec = input_specs(cfg, dshape)
assert spec["pos"].shape == (8,), spec["pos"]        # vector, not scalar
mesh = make_mesh((2, 4), ("data", "model"))
scfg = ShardingConfig(remat="none")
with mesh:
    c = lower_decode(cfg, dshape, mesh, scfg, A3Config.conservative()
                     ).compile()
    assert c.memory_analysis().alias_size_in_bytes > 0   # donation held
    c2 = lower_prefill_chunk(cfg, pshape, mesh, scfg, chunk=64,
                             a3=A3Config.conservative()).compile()
    assert c2.memory_analysis().alias_size_in_bytes > 0
    c3 = lower_decode_block(cfg, dshape, mesh, scfg, steps=8,
                            a3=A3Config.conservative(),
                            resort_every=64).compile()
    assert c3.memory_analysis().alias_size_in_bytes > 0
print("OK")
""", devices=8, timeout=900))
    assert "OK" in out


@pytest.mark.slow
def test_sharded_recurrent_prefill_chunk_lowering():
    """Recurrent-arch chunked admission lowers under GSPMD: the ragged
    prefill-chunk dispatch for a hybrid RG-LRU config (and the xLSTM
    mixer states) compiles on the 8-device CI mesh with the cache
    donated — the mixer-state interface's carried recurrent state is
    sharded by the same cache specs as the KV rings."""
    out = check(run_with_devices("""
from repro.config import ShapeConfig, ShapeKind, ShardingConfig, \\
    get_arch, smoke_variant
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import lower_prefill_chunk
pshape = ShapeConfig("prefill_smoke", ShapeKind.PREFILL, 256, 8)
mesh = make_mesh((2, 4), ("data", "model"))
scfg = ShardingConfig(remat="none")
with mesh:
    for arch in ("recurrentgemma-2b", "xlstm-350m"):
        cfg = smoke_variant(get_arch(arch))
        c = lower_prefill_chunk(cfg, pshape, mesh, scfg, chunk=64).compile()
        assert c.memory_analysis().alias_size_in_bytes > 0, arch
print("OK")
""", devices=8, timeout=900))
    assert "OK" in out
