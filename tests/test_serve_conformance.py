"""Serving conformance suite: the engine is an *oracle-checked* system.

Chunked + ragged admission prefill is a pure scheduling change — it must
not alter what the model computes. Every test here pins ``ServeEngine``
generations against the sequential single-request reference
(whole-prompt ``decoder.prefill`` + a scalar decode loop), across slot
counts, admission orders, and ``prefill_chunk`` settings (including the
whole-prompt ``None`` mode), plus the engine's dispatch-count
invariants:

* ``decode_dispatches == decode_steps``   (one ragged decode per tick)
* ``prefill_dispatches <= ticks``         (one ragged prefill per tick)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import check, run_with_devices

from repro.config import A3Config, ModelConfig
from repro.models import decoder as dec
from repro.serve.engine import ServeEngine

TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                   dtype="float32")
MAX_LEN = 96
MAX_NEW = 6
PROMPT_LENS = (5, 12, 23, 31, 9)


@pytest.fixture(scope="module")
def params():
    return dec.init_params(jax.random.PRNGKey(0), TINY)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(7)
    return [rng.integers(0, TINY.vocab_size, size=n) for n in PROMPT_LENS]


def _reference_generate(params, prompt, max_new, a3=A3Config()):
    """Sequential single-request oracle: whole-prompt prefill + scalar
    greedy decode (no batching, no chunking, no engine)."""
    use_a3 = a3.mode.value != "off"
    lg, cache = dec.prefill(params, TINY, jnp.asarray(prompt, jnp.int32)[None],
                            max_len=MAX_LEN, a3=use_a3)
    cur, pos, out = int(jnp.argmax(lg[0])), len(prompt), []
    out.append(cur)
    for _ in range(max_new - 1):
        lg, cache = dec.decode_step(params, TINY, cache,
                                    jnp.asarray([cur], jnp.int32),
                                    jnp.int32(pos), a3=a3)
        cur = int(jnp.argmax(lg[0]))
        out.append(cur)
        pos += 1
    return out


@pytest.fixture(scope="module")
def refs(params, prompts):
    return [_reference_generate(params, p, MAX_NEW) for p in prompts]


def _assert_invariants(eng):
    assert eng.stats["decode_dispatches"] == eng.stats["decode_steps"]
    assert eng.stats["prefill_dispatches"] <= eng.stats["ticks"]


def _run_engine(params, prompts, *, slots, chunk, order="upfront",
                a3=A3Config(), resort_every=64):
    eng = ServeEngine(params, TINY, slots=slots, max_len=MAX_LEN, a3=a3,
                      prefill_chunk=chunk, resort_every=resort_every)
    uids = {}
    if order == "upfront":
        for i, p in enumerate(prompts):
            uids[i] = eng.submit(p, max_new_tokens=MAX_NEW)
        eng.run_to_completion()
    elif order == "reversed":
        for i in reversed(range(len(prompts))):
            uids[i] = eng.submit(prompts[i], max_new_tokens=MAX_NEW)
        eng.run_to_completion()
    elif order == "staggered":
        pending = list(enumerate(prompts))
        while pending or eng._queue or any(s.active for s in eng.slots):
            if pending and eng.stats["ticks"] % 2 == 0:
                i, p = pending.pop(0)
                uids[i] = eng.submit(p, max_new_tokens=MAX_NEW)
            eng.step()
    else:
        raise ValueError(order)
    return {i: eng.result(u) for i, u in uids.items()}, eng


# ---------------------------------------------------------------------------
# chunking is output-invariant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("slots", [1, 4])
@pytest.mark.parametrize("chunk", [8, 64, None])  # None = whole-prompt
def test_engine_matches_sequential_reference(params, prompts, refs, slots,
                                             chunk):
    """Engine generations are identical to per-request sequential decode
    for every (slot count, prefill chunking) combination — chunk
    boundaries and ragged admission batching change *scheduling*, never
    outputs."""
    out, eng = _run_engine(params, prompts, slots=slots, chunk=chunk)
    for i, ref in enumerate(refs):
        assert out[i] == ref, (i, chunk, slots)
    _assert_invariants(eng)


def test_admission_order_does_not_change_outputs(params, prompts, refs):
    """Each request's generation depends only on its own prompt — not on
    queue order or on which slots are decoding while it prefills."""
    for order in ("reversed", "staggered"):
        out, eng = _run_engine(params, prompts, slots=4, chunk=8,
                               order=order)
        for i, ref in enumerate(refs):
            assert out[i] == ref, (i, order)
        _assert_invariants(eng)


def test_ragged_admission_batches_prefills(params, prompts):
    """With chunk >= every prompt, all slots admitted on the same tick
    prefill in ONE padded dispatch — strictly fewer dispatches than the
    one-prefill-per-admit path."""
    out, eng = _run_engine(params, prompts, slots=4, chunk=64)
    # 5 requests through 4 slots: 4 admitted on tick 1 (1 dispatch), the
    # 5th after a slot frees (1 more) — far fewer than 5 per-admit calls.
    assert eng.stats["prefill_dispatches"] <= 2
    assert eng.stats["prefill_tokens"] == sum(PROMPT_LENS)
    _assert_invariants(eng)


def test_long_prompt_prefill_interleaves_with_decode(params, prompts, refs):
    """A long prompt admitted mid-stream advances chunk-by-chunk while
    already-decoding slots keep producing a token every tick (no
    multi-tick stall), and still generates the reference tokens."""
    rng = np.random.default_rng(11)
    long_prompt = rng.integers(0, TINY.vocab_size, size=64)
    long_ref = _reference_generate(params, long_prompt, MAX_NEW)

    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8)
    u0 = eng.submit(prompts[0], max_new_tokens=16)
    eng.step()                       # prompt 0 starts prefilling
    eng.step()
    gen_before = len(eng.slots[0].generated)
    u1 = eng.submit(long_prompt, max_new_tokens=MAX_NEW)
    # 64-token prompt at chunk=8 -> 8 prefill ticks; slot 0 must advance
    # by one token on every one of them.
    for _ in range(8):
        before = len(eng.slots[0].generated)
        eng.step()
        assert len(eng.slots[0].generated) == before + 1
    assert eng.slots[1].decoding     # long prompt finished prefilling
    eng.run_to_completion()
    assert eng.result(u1) == long_ref
    assert eng.result(u0) == _reference_generate(params, prompts[0], 16)
    _assert_invariants(eng)


# ---------------------------------------------------------------------------
# A^3 path: chunked incremental sort == whole-prompt comprehension sort
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [8, None])
def test_a3_chunked_matches_sequential_reference(params, prompts, chunk):
    a3 = A3Config.conservative()
    refs_a3 = [_reference_generate(params, p, MAX_NEW, a3=a3)
               for p in prompts[:3]]
    out, eng = _run_engine(params, prompts[:3], slots=2, chunk=chunk,
                           a3=a3, resort_every=4)
    for i, ref in enumerate(refs_a3):
        assert out[i] == ref, (i, chunk)
    _assert_invariants(eng)


# ---------------------------------------------------------------------------
# decoder-level: prefill_chunk == prefill (cache + logits)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a3", [False, True])
@pytest.mark.parametrize("plen,chunk", [(23, 8), (23, 64), (7, 3), (16, 16)])
def test_prefill_chunk_extends_cache_like_whole_prompt(params, a3, plen,
                                                       chunk):
    """Running a prompt through prefill_chunk in any chunk split yields
    the same cache rows (incl. the A^3 sorted-key matrices and
    watermarks) and final logits as one whole-prompt prefill."""
    rng = np.random.default_rng(plen * 100 + chunk)
    p = rng.integers(0, TINY.vocab_size, size=plen)
    lg_ref, cache_ref = dec.prefill(params, TINY,
                                    jnp.asarray(p, jnp.int32)[None],
                                    max_len=32, a3=a3)
    cache = dec.init_cache(TINY, 1, 32, a3=a3)
    cur = 0
    lg = None
    while cur < plen:
        take = min(chunk, plen - cur)
        toks = np.zeros((1, chunk), np.int32)
        toks[0, :take] = p[cur:cur + take]
        lg, cache = dec.prefill_chunk(params, TINY, cache,
                                      jnp.asarray(toks),
                                      jnp.asarray([cur], jnp.int32),
                                      jnp.asarray([take], jnp.int32),
                                      a3=a3)
        cur += take
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                               rtol=1e-5, atol=1e-5)
    flat_c, _ = jax.tree_util.tree_flatten_with_path(cache)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(cache_ref)
    for (ka, a), (kb, b) in zip(flat_c, flat_r):
        assert str(ka) == str(kb)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5, err_msg=str(ka))


@pytest.mark.parametrize("plen,chunk", [(24, 20), (30, 7), (16, 16)])
def test_prefill_chunk_ring_wrap_matches_whole_prompt(plen, chunk):
    """Sliding-window segments keep an O(window) ring; prompts longer
    than the ring wrap it, and chunks longer than the ring land only
    their last ``w`` positions — chunked prefill must still reproduce
    whole-prompt prefill (which computes windowed attention over the
    full prompt and stores the last ``w`` rows)."""
    import dataclasses
    from repro.config import AttentionKind
    swa = dataclasses.replace(TINY, name="tiny-swa",
                              attention_kind=AttentionKind.SLIDING,
                              window_size=16)
    params = dec.init_params(jax.random.PRNGKey(1), swa)
    rng = np.random.default_rng(plen * 10 + chunk)
    p = rng.integers(0, swa.vocab_size, size=plen)
    lg_ref, cache_ref = dec.prefill(params, swa,
                                    jnp.asarray(p, jnp.int32)[None],
                                    max_len=32)
    cache = dec.init_cache(swa, 1, 32)
    cur, lg = 0, None
    while cur < plen:
        take = min(chunk, plen - cur)
        toks = np.zeros((1, chunk), np.int32)
        toks[0, :take] = p[cur:cur + take]
        lg, cache = dec.prefill_chunk(params, swa, cache,
                                      jnp.asarray(toks),
                                      jnp.asarray([cur], jnp.int32),
                                      jnp.asarray([take], jnp.int32))
        cur += take
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                               rtol=1e-5, atol=1e-5)
    flat_c, _ = jax.tree_util.tree_flatten_with_path(cache)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(cache_ref)
    for (ka, a), (kb, b) in zip(flat_c, flat_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5, err_msg=str(ka))


def test_engine_rejects_empty_prompt(params):
    eng = ServeEngine(params, TINY, slots=1, max_len=32, prefill_chunk=8)
    with pytest.raises(ValueError):
        eng.submit(np.asarray([], np.int32))


def test_prefill_chunk_zero_length_lane_is_identity(params):
    """Lanes with length 0 (idle/decoding slots sharing the dispatch
    batch) pass their cache rows through bit-identically."""
    rng = np.random.default_rng(3)
    p = rng.integers(0, TINY.vocab_size, size=(2, 9))
    _, cache = dec.prefill(params, TINY, jnp.asarray(p, jnp.int32),
                           max_len=32)
    toks = np.zeros((2, 4), np.int32)
    toks[0] = rng.integers(0, TINY.vocab_size, size=4)
    _, new_cache = dec.prefill_chunk(params, TINY, cache,
                                     jnp.asarray(toks),
                                     jnp.asarray([9, 0], jnp.int32),
                                     jnp.asarray([4, 0], jnp.int32))
    flat_n, _ = jax.tree_util.tree_flatten_with_path(new_cache)
    flat_o, _ = jax.tree_util.tree_flatten_with_path(cache)
    for (ka, a), (kb, b) in zip(flat_n, flat_o):
        np.testing.assert_array_equal(np.asarray(a)[:, 1],
                                      np.asarray(b)[:, 1], err_msg=str(ka))


def test_decode_negative_pos_lane_drops_ring_write(params):
    """pos=-1 lanes (idle/prefilling engine slots riding along in the
    decode batch) must not touch their cache rows."""
    rng = np.random.default_rng(4)
    p = rng.integers(0, TINY.vocab_size, size=(2, 9))
    _, cache = dec.prefill(params, TINY, jnp.asarray(p, jnp.int32),
                           max_len=32)
    tok = jnp.asarray([5, 6], jnp.int32)
    pos = jnp.asarray([9, -1], jnp.int32)
    logits, new_cache = dec.decode_step(params, TINY, cache, tok, pos)
    flat_n, _ = jax.tree_util.tree_flatten_with_path(new_cache)
    flat_o, _ = jax.tree_util.tree_flatten_with_path(cache)
    for (ka, a), (kb, b) in zip(flat_n, flat_o):
        np.testing.assert_array_equal(np.asarray(a)[:, 1],
                                      np.asarray(b)[:, 1], err_msg=str(ka))
    # the active lane still decoded normally
    lg_ref, _ = dec.decode_step(params, TINY,
                                jax.tree.map(lambda x: x[:, :1], cache),
                                tok[:1], jnp.int32(9))
    np.testing.assert_allclose(np.asarray(logits[0]),
                               np.asarray(lg_ref[0]), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# sharded serve lowering (exercised on the multi-device CI matrix entry)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_serve_lowering_ragged_shapes():
    """The sharded serve path lowers the same ragged dispatches the
    engine runs: decode with a per-slot pos *vector* + donated cache,
    and the chunked admission-prefill dispatch."""
    out = check(run_with_devices("""
import jax
from repro.config import A3Config, ShapeConfig, ShapeKind, ShardingConfig, \\
    get_arch, smoke_variant
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import input_specs, lower_decode, \\
    lower_prefill_chunk

cfg = smoke_variant(get_arch("phi4-mini-3.8b"))
dshape = ShapeConfig("decode_smoke", ShapeKind.DECODE, 256, 8)
pshape = ShapeConfig("prefill_smoke", ShapeKind.PREFILL, 256, 8)
spec = input_specs(cfg, dshape)
assert spec["pos"].shape == (8,), spec["pos"]        # vector, not scalar
mesh = make_mesh((2, 4), ("data", "model"))
scfg = ShardingConfig(remat="none")
with mesh:
    c = lower_decode(cfg, dshape, mesh, scfg, A3Config.conservative()
                     ).compile()
    assert c.memory_analysis().alias_size_in_bytes > 0   # donation held
    c2 = lower_prefill_chunk(cfg, pshape, mesh, scfg, chunk=64,
                             a3=A3Config.conservative()).compile()
    assert c2.memory_analysis().alias_size_in_bytes > 0
print("OK")
""", devices=8, timeout=600))
    assert "OK" in out
