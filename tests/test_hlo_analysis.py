"""HLO accounting tests: the roofline's FLOP/byte/collective numbers
must be trustworthy — validated against analytic counts on real
compiled programs and against hand-written HLO text.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import HloModule, _shape_bytes


def test_dot_flops_simple_matmul():
    m, k, n = 64, 128, 32

    @jax.jit
    def f(a, b):
        return a @ b

    txt = f.lower(jax.ShapeDtypeStruct((m, k), jnp.float32),
                  jax.ShapeDtypeStruct((k, n), jnp.float32)) \
        .compile().as_text()
    flops = HloModule(txt).dot_flops()
    assert abs(flops - 2 * m * k * n) / (2 * m * k * n) < 0.01


def test_dot_flops_scan_trip_count():
    """Dots inside a lax.scan must be scaled by the trip count."""
    L, d = 7, 32
    w = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((d,), jnp.float32)

    @jax.jit
    def f(w, x):
        def body(h, wi):
            return wi @ h, None
        h, _ = jax.lax.scan(body, x, w)
        return h

    txt = f.lower(w, x).compile().as_text()
    flops = HloModule(txt).dot_flops()
    expect = L * 2 * d * d
    assert abs(flops - expect) / expect < 0.05, (flops, expect)


def test_shape_bytes_tuple():
    assert _shape_bytes("(bf16[4,8], f32[2])") == 4 * 8 * 2 + 2 * 4
    assert _shape_bytes("pred[16]") == 16
    assert _shape_bytes("s32[]") == 4


def test_collective_parse_synthetic():
    hlo = """
HloModule test, entry_computation_layout={()->f32[]}

%body.1 (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]) parameter(0)
  %g = f32[64,64] get-tuple-element(%p), index=1
  %ar = f32[64,64] all-reduce(%g), replica_groups={{0,1,2,3}}, to_apply=%add.1
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[64,64]) tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[64,64])) -> pred[] {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64] parameter(0)
  %init = (s32[], f32[64,64]) tuple(%zero, %a)
  %w = (s32[], f32[64,64]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[128,64] all-gather(%a), dimensions={0}, replica_groups={{0,1}}
  ROOT %out = f32[64,64] get-tuple-element(%w), index=1
}
"""
    mod = HloModule(hlo)
    ob, oc, wire = mod.collectives()
    # all-reduce runs 5x (trip count), operand 64*64*4 bytes
    assert ob["all-reduce"] == 5 * 64 * 64 * 4
    # all-gather once, operand is %a
    assert ob["all-gather"] == 64 * 64 * 4
    assert oc["all-reduce"] == 5


def test_hbm_bytes_excludes_fusion_internals():
    @jax.jit
    def f(a, b):
        return jnp.tanh(a * 2.0 + b)   # one fused loop

    txt = f.lower(jax.ShapeDtypeStruct((1024,), jnp.float32),
                  jax.ShapeDtypeStruct((1024,), jnp.float32)) \
        .compile().as_text()
    b = HloModule(txt).hbm_bytes()
    # fused elementwise: ~2 reads + 1 write = 12 KiB; allow copies
    assert b <= 6 * 1024 * 4, b
