"""Fixed-point quantization + two-LUT exponent numerics (paper §III)."""
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.core.quantization import (
    cached_lut_exp,
    dequantize_int8_block,
    make_lut_exp,
    quantize_fixed_point,
    quantize_int8_block,
    softmax_fixed_point,
)


def test_fixed_point_grid():
    x = jnp.asarray([0.0, 0.11, -0.12, 3.14159, -7.9, 100.0, -100.0])
    q = quantize_fixed_point(x, int_bits=4, frac_bits=4)
    step = 2.0 ** -4
    limit = 2.0 ** 4 - step
    qn = np.asarray(q)
    # on the grid
    np.testing.assert_allclose(qn / step, np.round(qn / step), atol=1e-6)
    # clipped to the representable range
    assert qn.max() <= limit and qn.min() >= -limit
    # rounding error bounded by half a step for in-range values
    inr = np.abs(np.asarray(x)) <= limit
    assert np.all(np.abs(qn[inr] - np.asarray(x)[inr]) <= step / 2 + 1e-7)


@given(st.floats(-15.9, 15.9), st.integers(2, 6), st.integers(2, 6))
@settings(max_examples=60, deadline=None)
def test_fixed_point_error_bound(v, i, f):
    limit = 2.0 ** i - 2.0 ** (-f)
    q = float(quantize_fixed_point(jnp.float32(v), i, f))
    if abs(v) <= limit:
        assert abs(q - v) <= 2.0 ** (-f) / 2 + 1e-5
    else:
        assert abs(q) <= limit + 1e-6


def test_lut_exp_equals_single_table():
    """Two-LUT decomposition must equal the mathematically exact e^x at
    every representable input (e^{a+b} = e^a e^b is exact; only the output
    register rounding remains)."""
    lut = make_lut_exp(frac_bits=8, total_bits=16, out_frac_bits=24)
    ks = np.arange(0, 2 ** 16, 97)           # sample the input lattice
    x = -(ks * 2.0 ** -8)
    y = np.asarray(lut(jnp.asarray(x, dtype=jnp.float32)))
    ref = np.exp(x)
    np.testing.assert_allclose(y, ref, rtol=2e-5, atol=2e-7)


def test_lut_exp_footnote1_error_bound():
    """Footnote 1: for x <= 0, |e^{x+eps} - e^x| < |eps| — input quantization
    error shrinks through the exponent."""
    rng = np.random.default_rng(0)
    x = -rng.uniform(0, 20, size=4096)
    f = 8
    lut = make_lut_exp(frac_bits=f, total_bits=16, out_frac_bits=24)
    y = np.asarray(lut(jnp.asarray(x, dtype=jnp.float32)))
    eps = 2.0 ** -f / 2            # max input quantization error
    err = np.abs(y - np.exp(x))
    assert np.all(err <= eps + 1e-6), err.max()


def test_lut_table_size_reduction():
    """§III-A: 2×256 entries replace 65,536."""
    lut = make_lut_exp(frac_bits=8, total_bits=16)
    assert lut.table_entries == 512
    assert 2 ** lut.total_bits == 65536


@pytest.mark.parametrize("n", [8, 64, 320])
def test_softmax_fixed_point_close_to_float(n):
    rng = np.random.default_rng(n)
    scores = rng.standard_normal(n).astype(np.float32) * 3
    sq = quantize_fixed_point(jnp.asarray(scores), 8, 8)
    w = np.asarray(softmax_fixed_point(sq, frac_bits=8))
    ref = np.exp(scores - scores.max())
    ref = ref / ref.sum()
    assert np.abs(w - ref).max() < 2e-2
    assert abs(w.sum() - 1.0) < 2e-2


def test_softmax_fixed_point_mask():
    scores = jnp.asarray([1.0, 5.0, 2.0, 4.0])
    mask = jnp.asarray([True, False, True, True])
    w = np.asarray(softmax_fixed_point(scores, frac_bits=8, mask=mask))
    assert w[1] == 0.0
    assert abs(w.sum() - 1.0) < 1e-2


def test_fixed_point_bf16_grid_matches_f32():
    """Regression: the rounding grid must be built in f32 internally.

    bf16's 8-bit mantissa cannot represent ``x * 2**frac_bits`` for
    frac_bits >= 1 without destroying the fractional part, so a grid
    computed in the input dtype silently no-ops (jnp weak typing keeps
    the Python scalar multiply in bf16). The fix computes in f32 and
    casts back — a bf16 input must land on exactly the same grid points
    (post-cast) as the f32 reference."""
    rng = np.random.default_rng(7)
    x32 = rng.uniform(-15.0, 15.0, size=512).astype(np.float32)
    xbf = jnp.asarray(x32).astype(jnp.bfloat16)
    q_bf = quantize_fixed_point(xbf, int_bits=4, frac_bits=4)
    assert q_bf.dtype == jnp.bfloat16
    ref = quantize_fixed_point(xbf.astype(jnp.float32), 4, 4)
    # bit-equality with the f32 grid, rounded back into bf16
    np.testing.assert_array_equal(
        np.asarray(q_bf.astype(jnp.float32)),
        np.asarray(ref.astype(jnp.bfloat16).astype(jnp.float32)))
    # and it must actually quantize: bf16 in-range values off the grid
    # may not pass through unchanged
    step = 2.0 ** -4
    g = np.asarray(q_bf.astype(jnp.float32))
    np.testing.assert_allclose(g / step, np.round(g / step), atol=1e-6)


def test_softmax_fixed_point_bf16_grid():
    """Same weak-typing regression for the softmax output register."""
    rng = np.random.default_rng(11)
    s32 = (rng.standard_normal(64) * 3).astype(np.float32)
    sbf = jnp.asarray(s32).astype(jnp.bfloat16)
    w = softmax_fixed_point(sbf, frac_bits=6)
    assert w.dtype == jnp.bfloat16
    wref = softmax_fixed_point(sbf.astype(jnp.float32), frac_bits=6)
    np.testing.assert_array_equal(
        np.asarray(w.astype(jnp.float32)),
        np.asarray(wref.astype(jnp.bfloat16).astype(jnp.float32)))
    # outputs sit on the 2**-12 output grid (f32 reference path)
    ostep = 2.0 ** -12
    wn = np.asarray(wref)
    np.testing.assert_allclose(wn / ostep, np.round(wn / ostep), atol=1e-5)


def test_cached_lut_exp_identity():
    """The module-level LUT cache must return ONE LutExp per
    (frac_bits, total_bits) — table construction happens once, not per
    traced call (quantization.py's softmax default + a3_attention both
    route through it)."""
    a = cached_lut_exp(16, 21)
    b = cached_lut_exp(16, 21)
    assert a is b
    assert cached_lut_exp(8, 16) is not a
    # and the cached builder matches a fresh make_lut_exp numerically
    fresh = make_lut_exp(frac_bits=16, total_bits=21)
    x = jnp.asarray(-np.linspace(0.0, 20.0, 257), jnp.float32)
    np.testing.assert_array_equal(np.asarray(a(x)), np.asarray(fresh(x)))


def test_int8_block_quant_roundtrip_bound():
    """Symmetric int8: roundtrip error <= scale/2 per element, scale
    = amax/127 per block."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal((4, 8, 16)).astype(np.float32) * 5
    q, scale = quantize_int8_block(jnp.asarray(x), axes=(2,))
    assert q.dtype == jnp.int8
    assert scale.shape == (4, 8, 1)
    back = np.asarray(dequantize_int8_block(q, scale))
    err = np.abs(back - x)
    bound = np.broadcast_to(np.asarray(scale) / 2, x.shape)
    assert np.all(err <= bound + 1e-7)
    # amax element is exactly representable (hits +-127)
    assert np.abs(np.asarray(q)).max() == 127
