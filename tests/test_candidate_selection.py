"""Candidate selection: vectorized TPU-native algorithm == paper's Fig. 7 oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.core.candidate_selection import (
    select_candidates,
    select_candidates_batch,
    select_candidates_oracle,
    sort_key_columns,
)

jax.config.update("jax_enable_x64", False)


def _random_kq(rng, n, d):
    key = rng.standard_normal((n, d)).astype(np.float32)
    query = rng.standard_normal((d,)).astype(np.float32)
    return key, query


@pytest.mark.parametrize("n,d,m", [
    (8, 4, 4), (32, 8, 16), (64, 16, 32), (320, 64, 160), (320, 64, 40),
    (50, 64, 25), (16, 4, 64),  # m > n
])
@pytest.mark.parametrize("heuristic", [True, False])
def test_vectorized_matches_oracle(n, d, m, heuristic):
    rng = np.random.default_rng(n * 1000 + d * 10 + m + int(heuristic))
    key, query = _random_kq(rng, n, d)

    mask_o, score_o = select_candidates_oracle(key, query, m, heuristic)
    sk = sort_key_columns(jnp.asarray(key))
    mask_v, score_v = select_candidates(sk, jnp.asarray(query), m, heuristic)

    np.testing.assert_array_equal(np.asarray(mask_v), mask_o)
    np.testing.assert_allclose(np.asarray(score_v), score_o, rtol=2e-4, atol=2e-4)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(4, 96),
    d=st.integers(2, 24),
    m_frac=st.sampled_from([0.125, 0.25, 0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
    heuristic=st.booleans(),
)
def test_property_equivalence(n, d, m_frac, seed, heuristic):
    rng = np.random.default_rng(seed)
    key, query = _random_kq(rng, n, d)
    m = max(1, int(m_frac * n))
    mask_o, score_o = select_candidates_oracle(key, query, m, heuristic)
    sk = sort_key_columns(jnp.asarray(key))
    mask_v, score_v = select_candidates(sk, jnp.asarray(query), m, heuristic)
    np.testing.assert_array_equal(np.asarray(mask_v), mask_o)
    np.testing.assert_allclose(np.asarray(score_v), score_o, rtol=2e-4, atol=2e-4)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(4, 64),
    d=st.integers(2, 16),
    m_frac=st.sampled_from([0.25, 0.5, 1.0]),
    seed=st.integers(0, 2**31 - 1),
    heuristic=st.booleans(),
)
def test_property_permutation_invariance(n, d, m_frac, seed, heuristic):
    """Column-sorting is row-order-free: permuting the key rows permutes
    the candidate mask and greedy scores, nothing else. (This is what
    makes the sorted-key matrix a valid *comprehension-time* artifact —
    the ring-buffer write order at serve time cannot affect selection.)"""
    rng = np.random.default_rng(seed)
    key, query = _random_kq(rng, n, d)
    m = max(1, int(m_frac * n))
    perm = rng.permutation(n)
    sk = sort_key_columns(jnp.asarray(key))
    mask, score = select_candidates(sk, jnp.asarray(query), m, heuristic)
    sk_p = sort_key_columns(jnp.asarray(key[perm]))
    mask_p, score_p = select_candidates(sk_p, jnp.asarray(query), m,
                                        heuristic)
    score, score_p = np.asarray(score), np.asarray(score_p)
    np.testing.assert_allclose(score_p, score[perm], rtol=1e-5, atol=1e-6)
    # mask = (score > 0); compare away from the boundary where fp
    # reassociation of the scatter-adds could legitimately flip the sign
    stable = np.abs(score[perm]) > 1e-5
    np.testing.assert_array_equal(np.asarray(mask_p)[stable],
                                  np.asarray(mask)[perm][stable])


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(4, 48),
    d=st.integers(2, 12),
    seed=st.integers(0, 2**31 - 1),
    m_top=st.integers(1, 8),
)
def test_property_full_walk_superset_of_exact_topm(n, d, seed, m_top):
    """With the full pop budget (M = n*d, heuristic off) the greedy
    score telescopes to the exact dot product — so the candidate set
    contains every positive-scoring row, in particular the exact top-M
    rows of k @ q (the retrieval set A^3 must never miss)."""
    rng = np.random.default_rng(seed)
    key, query = _random_kq(rng, n, d)
    sk = sort_key_columns(jnp.asarray(key))
    mask, score = select_candidates(sk, jnp.asarray(query), n * d,
                                    use_heuristic=False)
    exact = key @ query
    np.testing.assert_allclose(np.asarray(score), exact, rtol=2e-4,
                               atol=2e-4)
    mask = np.asarray(mask)
    top = np.argsort(exact)[::-1][:min(m_top, n)]
    for r in top:
        if exact[r] > 1e-4:        # positive with fp margin
            assert mask[r], (r, exact[r])


def test_candidates_contain_top_scores():
    """Sanity: a key genuinely similar to the query (the retrieval case the
    paper targets) is reliably selected at the conservative M=n/2."""
    rng = np.random.default_rng(0)
    hits = 0
    trials = 30
    for t in range(trials):
        key, query = _random_kq(rng, 320, 64)
        target = rng.integers(0, 320)
        key[target] = query + 0.3 * rng.standard_normal(64).astype(np.float32)
        sk = sort_key_columns(jnp.asarray(key))
        mask, _ = select_candidates(sk, jnp.asarray(query), 160)
        true_top = int(np.argmax(key @ query))
        hits += bool(np.asarray(mask)[true_top])
    assert hits / trials >= 0.95, f"top-1 recall {hits/trials} too low"


def test_more_iterations_more_candidates():
    rng = np.random.default_rng(1)
    key, query = _random_kq(rng, 256, 32)
    sk = sort_key_columns(jnp.asarray(key))
    counts = []
    for m in (8, 32, 128, 256):
        mask, _ = select_candidates(sk, jnp.asarray(query), m)
        counts.append(int(np.asarray(mask).sum()))
    assert counts == sorted(counts), counts
    assert counts[-1] > counts[0]


def test_batch_matches_single():
    rng = np.random.default_rng(2)
    key = rng.standard_normal((64, 16)).astype(np.float32)
    queries = rng.standard_normal((5, 16)).astype(np.float32)
    sk = sort_key_columns(jnp.asarray(key))
    masks_b, scores_b = select_candidates_batch(sk, jnp.asarray(queries), 32)
    for i in range(5):
        m1, s1 = select_candidates(sk, jnp.asarray(queries[i]), 32)
        np.testing.assert_array_equal(np.asarray(masks_b[i]), np.asarray(m1))
        np.testing.assert_allclose(np.asarray(scores_b[i]), np.asarray(s1), rtol=1e-6)


def test_sorted_keys_roundtrip():
    rng = np.random.default_rng(3)
    key = rng.standard_normal((40, 8)).astype(np.float32)
    sk = sort_key_columns(jnp.asarray(key))
    # values are ascending per column
    assert bool(jnp.all(jnp.diff(sk.values, axis=0) >= 0))
    # rows map back to the original matrix
    rebuilt = np.take_along_axis(key, np.asarray(sk.rows), axis=0)
    np.testing.assert_allclose(np.asarray(sk.values), rebuilt)


def test_jit_and_grad_safety():
    """select_candidates must be jittable (used inside serving graphs)."""
    rng = np.random.default_rng(4)
    key, query = _random_kq(rng, 128, 16)
    sk = sort_key_columns(jnp.asarray(key))
    f = jax.jit(lambda q: select_candidates(sk, q, 64)[0])
    mask = f(jnp.asarray(query))
    assert mask.shape == (128,)
