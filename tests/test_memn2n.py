"""MemN2N (paper workload) tests: learns the synthetic bAbI task, and
the A^3 pipeline preserves accuracy at conservative settings — the
paper's central accuracy claim at small scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import A3Config, A3Mode, OptimizerConfig
from repro.data.babi import generate_babi, make_task
from repro.models import memn2n
from repro.optim.adamw import adamw_init, adamw_update


@pytest.fixture(scope="module")
def trained():
    task = make_task(num_actors=32, num_places=8, max_sentences=24,
                     max_words=8)
    cfg = memn2n.MemN2NConfig(vocab_size=task.vocab_size, d_embed=32,
                              num_hops=2, max_sentences=task.max_sentences,
                              max_words=task.max_words)
    params = memn2n.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=10, total_steps=700,
                           weight_decay=0.0, min_lr_ratio=0.3)
    opt = adamw_init(params, ocfg)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(memn2n.loss_fn)(params, batch, cfg)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    for i in range(700):
        b = generate_babi(task, 64, 20, seed=100 + i)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, _ = step(params, opt, b)
    test = generate_babi(task, 256, 20, seed=9)
    test = {k: jnp.asarray(v) for k, v in test.items()}
    return params, cfg, test


def test_learns_task(trained):
    params, cfg, test = trained
    acc = float(memn2n.accuracy(params, test, cfg))
    assert acc > 0.85, acc


def test_a3_conservative_small_drop(trained):
    params, cfg, test = trained
    base = float(memn2n.accuracy(params, test, cfg))
    acc = float(memn2n.accuracy(params, test, cfg, A3Config.conservative()))
    assert acc >= base - 0.05, (base, acc)


def test_a3_m_monotonic_candidates(trained):
    """More iterations M -> more (or equal) candidates selected."""
    params, cfg, test = trained
    counts = []
    for frac in [0.125, 0.5, 1.0]:
        a3 = A3Config(mode=A3Mode.CUSTOM, m_fraction=frac,
                      threshold_pct=1e-4)

        def cand(s, q):
            _, aux = memn2n.answer_with_a3(params, s, q, cfg, a3)
            return jnp.sum(aux["hop0"]["candidates"])

        c = jax.vmap(cand)(test["sentences"][:32], test["question"][:32])
        counts.append(float(jnp.mean(c)))
    assert counts[0] <= counts[1] + 1e-6 <= counts[2] + 2e-6, counts


def test_quantized_path_close(trained):
    """i=4,f=4 fixed-point inputs (paper SSVI-B): accuracy within 2%."""
    params, cfg, test = trained
    base = float(memn2n.accuracy(params, test, cfg))
    a3 = A3Config(mode=A3Mode.CUSTOM, m_fraction=1.0, threshold_pct=1e-4,
                  int_bits=4, frac_bits=4)
    acc = float(memn2n.accuracy(params, test, cfg, a3))
    assert acc >= base - 0.02, (base, acc)
