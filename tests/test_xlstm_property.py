"""Property tests (hypothesis): chunkwise-parallel mLSTM == sequential
recurrence, sLSTM scan == per-step cell, RG-LRU scan == decode steps —
the core invariant that makes prefill/decode serving exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from helpers import given, settings, st

from repro.models import xlstm as X
from repro.models import rglru as R


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(2, 33), st.integers(0, 2 ** 31 - 1),
       st.sampled_from([4, 8]))
def test_mlstm_chunkwise_equals_sequential(b, s, seed, chunk):
    H, Dh, D = 2, 8, 32
    key = jax.random.PRNGKey(seed % 1000)
    p = X.mlstm_init(key, D, H, Dh, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed % 997), (b, s, D)) * 0.5
    par = X.mlstm_parallel(p, x, H, Dh, chunk=chunk)
    state = X.mlstm_init_state(b, H, Dh)
    outs = []
    for t in range(s):
        o, state = X.mlstm_decode_step(p, x[:, t:t + 1], state, H, Dh)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(par), np.asarray(seq),
                               atol=2e-5, rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(2, 17), st.integers(0, 2 ** 31 - 1))
def test_slstm_scan_equals_steps(b, s, seed):
    H, D = 2, 16
    key = jax.random.PRNGKey(seed % 1000)
    p = X.slstm_init(key, D, H, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed % 997), (b, s, D)) * 0.5
    full, _ = X.slstm_apply_scan(p, x, H)
    state = X.slstm_init_state(b, D)
    outs = []
    for t in range(s):
        o, state = X.slstm_decode_step(p, x[:, t:t + 1], state, H)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq),
                               atol=2e-5, rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 2), st.integers(2, 17), st.integers(0, 2 ** 31 - 1))
def test_rglru_scan_equals_steps(b, s, seed):
    D, C = 16, 24
    key = jax.random.PRNGKey(seed % 1000)
    p = R.rglru_init(key, D, C, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed % 997), (b, s, D)) * 0.5
    full, h_last, buf_last = R.rglru_apply_scan(p, x)
    h = jnp.zeros((b, C), jnp.float32)
    buf = jnp.zeros((b, R.CONV_WIDTH - 1, C), jnp.float32)
    outs = []
    for t in range(s):
        o, h, buf = R.rglru_decode_step(p, x[:, t:t + 1], h, buf)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq),
                               atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               atol=2e-5, rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 20), st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_rglru_state_handoff(s, split, seed):
    """Running [0:k] then [k:s] with carried state == full scan."""
    b, D, C = 1, 16, 24
    k = min(split, s - 1)
    key = jax.random.PRNGKey(seed % 1000)
    p = R.rglru_init(key, D, C, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed % 997), (b, s, D)) * 0.5
    full, _, _ = R.rglru_apply_scan(p, x)
    o1, h1, buf1 = R.rglru_apply_scan(p, x[:, :k])
    o2, _, _ = R.rglru_apply_scan(p, x[:, k:], h0=h1, conv_buf=buf1)
    joined = jnp.concatenate([o1, o2], axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(joined),
                               atol=2e-5, rtol=2e-4)
