"""Shared pytest plumbing.

``jax.clear_caches()`` between test modules: a full-suite run in one
process accumulates hundreds of compiled executables, and the CPU
backend in this container segfaults inside ``backend_compile`` once
enough of them pile up (reproducible at the same cumulative compile
count regardless of which test is compiling — every module passes in
isolation). Dropping jax's compilation caches at each module boundary
keeps the per-process accumulation bounded; modules recompile their
own jits, which they overwhelmingly do anyway (each builds engines
against its own tiny configs), so the runtime cost is small.
"""
from __future__ import annotations

import pytest

import jax


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    jax.clear_caches()
    yield
