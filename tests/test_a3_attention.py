"""A³ attention pipeline semantics (paper Fig. 10 end-to-end)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import A3Config, A3Mode
from repro.core.a3_attention import (
    a3_attention_batch,
    a3_attention_single,
    a3_self_attention,
    candidate_block_map,
    flop_savings,
    preprocess,
)


def _memory(rng, n=320, d=64, dv=64, planted=True, q_count=1):
    key = rng.standard_normal((n, d)).astype(np.float32)
    value = rng.standard_normal((n, dv)).astype(np.float32)
    queries = rng.standard_normal((q_count, d)).astype(np.float32)
    if planted:
        for i in range(q_count):
            t = rng.integers(0, n)
            key[t] = queries[i] * 0.8 + 0.2 * rng.standard_normal(d)
    return key, value, queries


def _exact_attention(key, value, q):
    s = key @ q
    w = np.exp(s - s.max())
    w = w / w.sum()
    return w @ value


def test_off_mode_is_exact():
    rng = np.random.default_rng(0)
    key, value, queries = _memory(rng)
    st = preprocess(jnp.asarray(key), jnp.asarray(value))
    out, aux = a3_attention_single(st, jnp.asarray(queries[0]), A3Config())
    ref = _exact_attention(key, value, queries[0])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-5)
    assert bool(jnp.all(aux["kept"]))


@pytest.mark.parametrize("cfg,tol", [
    (A3Config.conservative(), 0.05),
    (A3Config.aggressive(), 0.35),
])
def test_approximation_quality(cfg, tol):
    """Approximate output stays close to exact for retrieval-style data.
    Conservative must be much tighter than aggressive (paper Fig. 13)."""
    rng = np.random.default_rng(1)
    errs = []
    for _ in range(10):
        key, value, queries = _memory(rng)
        st = preprocess(jnp.asarray(key), jnp.asarray(value))
        out, _ = a3_attention_single(st, jnp.asarray(queries[0]), cfg)
        ref = _exact_attention(key, value, queries[0])
        errs.append(np.linalg.norm(np.asarray(out) - ref) / np.linalg.norm(ref))
    assert np.mean(errs) < tol, (cfg.mode, np.mean(errs))


def test_aggressive_selects_fewer():
    rng = np.random.default_rng(2)
    key, value, queries = _memory(rng)
    st = preprocess(jnp.asarray(key), jnp.asarray(value))
    _, aux_c = a3_attention_single(st, jnp.asarray(queries[0]), A3Config.conservative())
    _, aux_a = a3_attention_single(st, jnp.asarray(queries[0]), A3Config.aggressive())
    assert int(aux_a["candidates"].sum()) <= int(aux_c["candidates"].sum())
    assert int(aux_a["kept"].sum()) <= int(aux_c["kept"].sum())
    assert int(aux_c["kept"].sum()) <= int(aux_c["candidates"].sum())


def test_post_scoring_threshold_semantics():
    """Kept rows have post-softmax weight >= T% of the max weight (by
    construction of t = -ln(T/100)); dropped candidate rows fall below it."""
    rng = np.random.default_rng(3)
    key, value, queries = _memory(rng)
    cfg = A3Config(mode=A3Mode.CUSTOM, m_fraction=1.0, threshold_pct=5.0)
    st = preprocess(jnp.asarray(key), jnp.asarray(value))
    _, aux = a3_attention_single(st, jnp.asarray(queries[0]), cfg)
    s = np.asarray(aux["scores"], dtype=np.float64)
    cand = np.asarray(aux["candidates"])
    kept = np.asarray(aux["kept"])
    smax = s[cand].max()
    rel_weight = np.exp(s - smax)
    assert np.all(rel_weight[kept] >= 0.05 - 1e-6)
    dropped = cand & ~kept
    if dropped.any():
        assert np.all(rel_weight[dropped] < 0.05 + 1e-6)


def test_quantized_pipeline_small_error():
    """§VI-B: f=4 costs <0.1% accuracy; here we check output closeness."""
    rng = np.random.default_rng(4)
    key, value, queries = _memory(rng)
    key = np.clip(key, -3, 3)
    cfg = A3Config(mode=A3Mode.OFF, int_bits=4, frac_bits=4, lut_exponent=True)
    st = preprocess(jnp.asarray(key), jnp.asarray(value))
    out, _ = a3_attention_single(st, jnp.asarray(queries[0]), cfg)
    ref = _exact_attention(key, value, queries[0])
    rel = np.linalg.norm(np.asarray(out) - ref) / np.linalg.norm(ref)
    assert rel < 0.15, rel


def test_batch_pipelining_matches_single():
    rng = np.random.default_rng(5)
    key, value, queries = _memory(rng, q_count=4)
    cfg = A3Config.conservative()
    st = preprocess(jnp.asarray(key), jnp.asarray(value))
    outs, _ = a3_attention_batch(st, jnp.asarray(queries), cfg)
    for i in range(4):
        o1, _ = a3_attention_single(st, jnp.asarray(queries[i]), cfg)
        np.testing.assert_allclose(np.asarray(outs[i]), np.asarray(o1),
                                   rtol=1e-5, atol=1e-6)


def test_self_attention_causal_off_matches_dense():
    rng = np.random.default_rng(6)
    q = rng.standard_normal((32, 16)).astype(np.float32)
    k = rng.standard_normal((32, 16)).astype(np.float32)
    v = rng.standard_normal((32, 8)).astype(np.float32)
    out, _ = a3_self_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                               A3Config(), causal=True)
    # dense reference
    s = (q / np.sqrt(16)) @ k.T
    mask = np.tril(np.ones((32, 32), dtype=bool))
    s = np.where(mask, s, -np.inf)
    w = np.exp(s - s.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(out), w @ v, rtol=1e-4, atol=1e-5)


def test_self_attention_approx_respects_causal():
    rng = np.random.default_rng(7)
    q = rng.standard_normal((64, 16)).astype(np.float32)
    k = rng.standard_normal((64, 16)).astype(np.float32)
    v = rng.standard_normal((64, 8)).astype(np.float32)
    _, aux = a3_self_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                               A3Config.conservative(), causal=True)
    kept = np.asarray(aux["kept"])
    future = np.triu(np.ones((64, 64), dtype=bool), k=1)
    assert not np.any(kept & future)


def test_candidate_block_map():
    mask = jnp.zeros((256, 256), dtype=bool).at[5, 200].set(True)
    bm = candidate_block_map(mask, 128, 128)
    assert bm.shape == (2, 2)
    assert bool(bm[0, 1]) and not bool(bm[1, 0])


def test_flop_savings_accounting():
    rng = np.random.default_rng(8)
    key, value, queries = _memory(rng)
    st = preprocess(jnp.asarray(key), jnp.asarray(value))
    _, aux = a3_attention_single(st, jnp.asarray(queries[0]), A3Config.aggressive())
    stats = flop_savings(
        {k: v[None] for k, v in aux.items() if k in ("candidates", "kept")},
        n=320, d=64)
    assert float(stats["score_flop_fraction"]) < 0.9
    assert float(stats["output_flop_fraction"]) <= float(stats["score_flop_fraction"]) + 1e-6
