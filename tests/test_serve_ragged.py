"""Ragged continuous batching: per-slot-position decode parity and the
single-dispatch-per-tick engine invariant."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import A3Config, ModelConfig
from repro.models import decoder as dec
from repro.serve.engine import ServeEngine

TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                   dtype="float32")


@pytest.fixture(scope="module")
def params():
    return dec.init_params(jax.random.PRNGKey(0), TINY)


def _stacked_cache(caches):
    """Concatenate B=1 caches along the batch axis (leaves are [L,B,...])."""
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1), *caches)


def test_ragged_decode_matches_per_slot_scalar_reference(params):
    """decode_step with pos [B] == a per-slot loop of scalar-pos decodes,
    both in logits and in the updated ring caches."""
    rng = np.random.default_rng(0)
    lens = [5, 11, 23]
    prompts = [rng.integers(0, TINY.vocab_size, size=n) for n in lens]
    caches, toks = [], []
    for p in prompts:
        lg, c = dec.prefill(params, TINY, jnp.asarray(p, jnp.int32)[None],
                            max_len=32)
        caches.append(c)
        toks.append(int(jnp.argmax(lg[0])))

    # ragged: one batched call with per-slot positions
    cache_b = _stacked_cache(caches)
    pos = jnp.asarray(lens, jnp.int32)
    logits_r, cache_r = dec.decode_step(params, TINY, cache_b,
                                        jnp.asarray(toks, jnp.int32), pos)

    # reference: scalar-pos decode per slot
    ref_logits, ref_caches = [], []
    for i, c in enumerate(caches):
        lg, nc = dec.decode_step(params, TINY, c,
                                 jnp.asarray([toks[i]], jnp.int32),
                                 jnp.int32(lens[i]))
        ref_logits.append(lg)
        ref_caches.append(nc)

    np.testing.assert_allclose(np.asarray(logits_r),
                               np.asarray(jnp.concatenate(ref_logits)),
                               rtol=1e-5, atol=1e-5)
    ref_cache = _stacked_cache(ref_caches)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(cache_r)
    flat_e, _ = jax.tree_util.tree_flatten_with_path(ref_cache)
    for (ka, a), (kb, b_) in zip(flat_r, flat_e):
        assert str(ka) == str(kb)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-6, atol=1e-6, err_msg=str(ka))


def test_ragged_decode_scalar_pos_still_works(params):
    """Scalar pos (dry-run / legacy callers) broadcasts to all slots."""
    rng = np.random.default_rng(1)
    p = rng.integers(0, TINY.vocab_size, size=(2, 7))
    _, cache = dec.prefill(params, TINY, jnp.asarray(p, jnp.int32),
                           max_len=32)
    tok = jnp.asarray([3, 4], jnp.int32)
    l_scalar, _ = dec.decode_step(params, TINY, cache, tok, jnp.int32(7))
    l_vec, _ = dec.decode_step(params, TINY, cache, tok,
                               jnp.asarray([7, 7], jnp.int32))
    np.testing.assert_allclose(np.asarray(l_scalar), np.asarray(l_vec),
                               rtol=1e-6, atol=1e-6)


def test_ragged_decode_a3_per_slot_fresh_tail(params):
    """A^3 ragged decode: per-slot fresh-tail masks track per-slot
    positions; each slot matches its own scalar-pos A^3 decode."""
    a3 = A3Config.conservative()
    rng = np.random.default_rng(2)
    lens = [17, 29]
    prompts = [rng.integers(0, TINY.vocab_size, size=n) for n in lens]
    caches, toks = [], []
    for p in prompts:
        lg, c = dec.prefill(params, TINY, jnp.asarray(p, jnp.int32)[None],
                            max_len=32, a3=True)
        caches.append(c)
        toks.append(int(jnp.argmax(lg[0])))
    cache_b = _stacked_cache(caches)
    logits_r, _ = dec.decode_step(params, TINY, cache_b,
                                  jnp.asarray(toks, jnp.int32),
                                  jnp.asarray(lens, jnp.int32), a3=a3)
    for i, c in enumerate(caches):
        lg, _ = dec.decode_step(params, TINY, c,
                                jnp.asarray([toks[i]], jnp.int32),
                                jnp.int32(lens[i]), a3=a3)
        np.testing.assert_allclose(np.asarray(logits_r[i]),
                                   np.asarray(lg[0]),
                                   rtol=1e-5, atol=1e-5)


def test_engine_single_dispatch_per_tick_staggered(params):
    """Staggered arrivals force maximal position skew; the engine must
    still issue exactly ONE jitted decode dispatch per tick and produce
    the same tokens as isolated per-request decoding."""
    rng = np.random.default_rng(3)
    eng = ServeEngine(params, TINY, slots=3, max_len=64)
    prompts = [rng.integers(0, TINY.vocab_size, size=n)
               for n in (4, 9, 14)]

    # isolated reference generations
    refs = []
    for p in prompts:
        lg, cache = dec.prefill(params, TINY, jnp.asarray(p, jnp.int32)[None],
                                max_len=64)
        cur, pos, out = int(jnp.argmax(lg[0])), len(p), []
        out.append(cur)
        for _ in range(5):
            lg, cache = dec.decode_step(params, TINY, cache,
                                        jnp.asarray([cur], jnp.int32),
                                        jnp.int32(pos))
            cur = int(jnp.argmax(lg[0]))
            out.append(cur)
            pos += 1
        refs.append(out)

    # staggered submission: one new request every other tick
    uids = []
    uids.append(eng.submit(prompts[0], max_new_tokens=6))
    eng.step()
    eng.step()
    uids.append(eng.submit(prompts[1], max_new_tokens=6))
    eng.step()
    uids.append(eng.submit(prompts[2], max_new_tokens=6))
    eng.run_to_completion()

    for u, ref in zip(uids, refs):
        assert eng.result(u) == ref
    # one jitted dispatch per advancing tick, regardless of skew
    assert eng.stats["decode_dispatches"] == eng.stats["decode_steps"]
    # 3 requests x 5 decode ticks each, overlapped: strictly fewer
    # dispatches than the per-pos-group engine would have issued
    assert eng.stats["decode_dispatches"] < 15


def test_engine_a3_staggered_with_resort(params):
    """A^3 engine path under staggered arrivals: batched re-sort path
    runs, outputs stay within the real vocab, budgets respected."""
    rng = np.random.default_rng(4)
    eng = ServeEngine(params, TINY, slots=2, max_len=64,
                      a3=A3Config.conservative(), resort_every=4)
    uids = []
    uids.append(eng.submit(rng.integers(0, TINY.vocab_size, size=20),
                           max_new_tokens=8))
    eng.step()
    uids.append(eng.submit(rng.integers(0, TINY.vocab_size, size=9),
                           max_new_tokens=8))
    eng.run_to_completion()
    for u in uids:
        r = eng.result(u)
        assert r is not None and len(r) == 8
        assert max(r) < TINY.vocab_size
    assert eng.stats["resorts"] > 0
    assert eng.stats["decode_dispatches"] == eng.stats["decode_steps"]
