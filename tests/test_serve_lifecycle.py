"""Request lifecycle + bounded-admission (load shedding) suite.

The engine's state machine (engine module docstring) promises: every
submitted request reaches exactly one terminal state {FINISHED,
REJECTED, CANCELLED, EXPIRED, FAILED}; releasing a slot from any
in-flight state reclaims the lane the same tick and drops prefix-cache
recording pins (trie refcounts return to baseline); and the stats
counters obey the conservation identity::

    submitted == finished + rejected + cancelled + expired + failed
                 + in_flight

Every test here closes with ``_check_conservation`` so a leaked or
double-counted request anywhere in the lifecycle fails loudly.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.config import ModelConfig, ServeConfig
from repro.models import decoder as dec
from repro.serve.engine import ServeEngine

TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                   dtype="float32")
MAX_LEN = 96
PROMPT_LENS = (5, 12, 23, 31, 9)


@pytest.fixture(scope="module")
def params():
    return dec.init_params(jax.random.PRNGKey(0), TINY)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(0)
    return [rng.integers(0, TINY.vocab_size, size=n) for n in PROMPT_LENS]


def _check_conservation(eng: ServeEngine):
    s = eng.stats
    assert s["submitted"] == (s["finished"] + s["rejected"]
                              + s["cancelled"] + s["expired"]
                              + s["failed"] + eng.in_flight), s


# ---------------------------------------------------------------------------
# submit() input hardening
# ---------------------------------------------------------------------------

def test_lifecycle_submit_rejects_bad_inputs(params):
    eng = ServeEngine(params, TINY, slots=1, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(np.array([], np.int32))          # empty
    with pytest.raises(ValueError):
        eng.submit(np.array([[1, 2]], np.int32))    # not 1-D
    with pytest.raises(TypeError):
        eng.submit(np.array([0.5, 1.5]))            # float dtype
    with pytest.raises(ValueError):
        eng.submit(np.arange(17, dtype=np.int32))   # length > max_len
    with pytest.raises(ValueError):
        eng.submit(np.array([-1, 3], np.int32))     # negative token id
    with pytest.raises(ValueError):
        eng.submit(np.array([TINY.vocab_size], np.int32))  # out of vocab
    with pytest.raises(ValueError):
        eng.submit(np.array([1, 2], np.int32), max_new_tokens=0)
    with pytest.raises(ValueError):
        eng.submit(np.array([1, 2], np.int32), deadline_ticks=0)
    # nothing above consumed a uid or touched the counters
    assert eng.stats["submitted"] == 0 and eng.in_flight == 0
    _check_conservation(eng)


def test_lifecycle_submit_at_max_len_allowed(params):
    # a prompt of length EXACTLY max_len is admitted and finishes with
    # just its prefill-sampled token (no room to decode past max_len) —
    # only longer prompts are an error
    eng = ServeEngine(params, TINY, slots=1, max_len=16)
    u = eng.submit(np.arange(16, dtype=np.int32), max_new_tokens=8)
    eng.run_to_completion()
    assert eng.status(u) == "finished"
    assert len(eng.result(u)) == 1
    _check_conservation(eng)


def test_lifecycle_status_unknown_uid_raises(params):
    eng = ServeEngine(params, TINY, slots=1, max_len=16)
    with pytest.raises(KeyError):
        eng.status(123)


# ---------------------------------------------------------------------------
# cancel / deadline expiry / drain
# ---------------------------------------------------------------------------

def test_lifecycle_cancel_queued_and_on_slot(params, prompts):
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8, decode_block=2)
    uids = [eng.submit(p, max_new_tokens=20) for p in prompts]
    assert eng.cancel(uids[4])                 # still queued
    assert eng.status(uids[4]) == "cancelled"
    eng.step(); eng.step()
    assert eng.status(uids[0]) == "decoding"
    assert eng.cancel(uids[0])                 # mid-decode: slot reclaimed
    assert eng.status(uids[0]) == "cancelled"
    assert eng.result(uids[0]) is None
    assert not eng.cancel(uids[0])             # already terminal
    eng.run_to_completion()
    assert [eng.status(u) for u in uids] == \
        ["cancelled", "finished", "finished", "finished", "cancelled"]
    assert eng.stats["cancelled"] == 2 and eng.stats["finished"] == 3
    _check_conservation(eng)


def test_lifecycle_cancel_mid_prefill_reclaims_slot(params, prompts):
    # chunk 4 means the 31-token prompt needs several prefill ticks;
    # cancelling mid-prefill must free the lane for the next request
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=4)
    u0 = eng.submit(prompts[3], max_new_tokens=4)   # 31 tokens
    u1 = eng.submit(prompts[0], max_new_tokens=4)
    eng.step()
    assert eng.status(u0) == "prefilling"
    assert eng.cancel(u0)
    eng.run_to_completion()
    assert eng.status(u0) == "cancelled"
    assert eng.status(u1) == "finished"
    assert len(eng.result(u1)) == 4
    _check_conservation(eng)


def test_lifecycle_deadline_expires_queued_and_on_slot(params, prompts):
    # one slot, engine-wide deadline of 3 ticks: the head request hogs
    # the slot past everyone else's deadline
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8, deadline_ticks=3)
    uids = [eng.submit(p, max_new_tokens=30) for p in prompts[:3]]
    eng.run_to_completion()
    assert all(eng.status(u) == "expired" for u in uids)
    assert eng.stats["expired"] == 3
    _check_conservation(eng)


def test_lifecycle_deadline_generous_finishes(params, prompts):
    # a deadline that is never hit changes nothing: token-for-token
    # identical to the no-deadline run
    free = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                       prefill_chunk=8)
    fu = [free.submit(p, max_new_tokens=6) for p in prompts]
    free.run_to_completion()
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8, deadline_ticks=1000)
    uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.run_to_completion()
    assert all(eng.status(u) == "finished" for u in uids)
    for a, b in zip(uids, fu):
        assert eng.result(a) == free.result(b)
    _check_conservation(eng)


def test_lifecycle_drain_graceful_shutdown(params, prompts):
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8)
    uids = [eng.submit(p, max_new_tokens=4) for p in prompts[:3]]
    eng.step()                      # first request reaches a slot
    eng.drain()
    assert eng.draining
    rejected = eng.submit(prompts[0], max_new_tokens=4)
    assert eng.status(rejected) == "rejected"
    eng.run_to_completion()         # in-flight work finishes
    assert eng.status(uids[0]) == "finished"
    assert [eng.status(u) for u in uids[1:]] == ["cancelled", "cancelled"]
    eng.drain()                     # idempotent
    _check_conservation(eng)


# ---------------------------------------------------------------------------
# bounded admission + load shedding
# ---------------------------------------------------------------------------

def test_shed_reject_new_bounds_queue(params, prompts):
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN, max_queue=2)
    uids = [eng.submit(p, max_new_tokens=2) for p in prompts]
    # no tick has run: the first two queue, the rest are shed
    assert [eng.status(u) for u in uids] == \
        ["queued", "queued", "rejected", "rejected", "rejected"]
    assert all(eng.result(u) is None for u in uids)
    eng.run_to_completion()
    assert [eng.status(u) for u in uids[:2]] == ["finished", "finished"]
    assert eng.stats["rejected"] == 3
    _check_conservation(eng)


def test_shed_evict_oldest_queued_prefers_fresh(params, prompts):
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN, max_queue=2,
                      shed_policy="evict-oldest-queued")
    uids = [eng.submit(p, max_new_tokens=2) for p in prompts]
    # each overflow evicts the then-oldest queued request
    assert [eng.status(u) for u in uids] == \
        ["rejected", "rejected", "rejected", "queued", "queued"]
    eng.run_to_completion()
    assert [eng.status(u) for u in uids[3:]] == ["finished", "finished"]
    assert eng.stats["rejected"] == 3
    _check_conservation(eng)


def test_shed_queue_drains_then_admits_again(params, prompts):
    # shedding is a function of the *current* queue depth, not history
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN, max_queue=1)
    u0 = eng.submit(prompts[0], max_new_tokens=2)
    eng.step()                                      # u0 admitted to a slot
    u1 = eng.submit(prompts[1], max_new_tokens=2)   # queue free again
    u2 = eng.submit(prompts[2], max_new_tokens=2)   # queue full -> shed
    assert eng.status(u1) == "queued"
    assert eng.status(u2) == "rejected"
    eng.run_to_completion()
    u3 = eng.submit(prompts[2], max_new_tokens=2)   # queue empty again
    assert eng.status(u3) == "queued"
    eng.run_to_completion()
    assert [eng.status(u) for u in (u0, u1, u3)] == \
        ["finished", "finished", "finished"]
    _check_conservation(eng)


def test_shed_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(max_queue=-1)
    with pytest.raises(ValueError):
        ServeConfig(shed_policy="drop-the-table")
    with pytest.raises(ValueError):
        ServeConfig(deadline_ticks=0)
    ServeConfig(max_queue=8, shed_policy="evict-oldest-queued",
                deadline_ticks=100)   # valid combination constructs


def test_shed_engine_validation(params):
    with pytest.raises(ValueError):
        ServeEngine(params, TINY, slots=1, max_len=16, max_queue=-1)
    with pytest.raises(ValueError):
        ServeEngine(params, TINY, slots=1, max_len=16, shed_policy="nope")
    with pytest.raises(ValueError):
        ServeEngine(params, TINY, slots=1, max_len=16, deadline_ticks=0)


# ---------------------------------------------------------------------------
# run_to_completion max_ticks exhaustion
# ---------------------------------------------------------------------------

def test_lifecycle_max_ticks_exhaustion_raises(params, prompts):
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8)
    u = eng.submit(prompts[0], max_new_tokens=50)
    with pytest.raises(RuntimeError, match="max_ticks"):
        eng.run_to_completion(max_ticks=2)
    assert eng.stats["max_ticks_exhausted"] == 1
    assert eng.status(u) in ("prefilling", "decoding")  # not stranded
    _check_conservation(eng)
    eng.run_to_completion()         # and the engine can simply resume
    assert eng.status(u) == "finished"
    assert len(eng.result(u)) == 50
    _check_conservation(eng)


# ---------------------------------------------------------------------------
# prefix-cache refcount audit
# ---------------------------------------------------------------------------

def test_lifecycle_refcount_audit_after_mixed_terminals(params, prompts):
    """After any mix of finish / cancel / expire, every trie node's
    refcount returns to baseline (0 — pins exist only while a slot
    prefills) and the FULL pool is evictable: the allocator can hand
    out every page, which is impossible if a terminal path leaked a
    recording pin."""
    shared = np.asarray(prompts[3], np.int32)       # 31 tokens
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8, page_size=8, cache_pages=12)
    # finish: records the shared prefix
    u0 = eng.submit(shared, max_new_tokens=3)
    eng.run_to_completion()
    assert eng.status(u0) == "finished"
    # cancel mid-prefill: rec_node pin must be dropped
    u1 = eng.submit(np.concatenate([shared, shared])[:48],
                    max_new_tokens=3)
    eng.step()
    assert eng.status(u1) == "prefilling"
    assert eng.cancel(u1)
    # expire mid-decode
    u2 = eng.submit(shared[:16], max_new_tokens=40, deadline_ticks=2)
    eng.run_to_completion()
    assert eng.status(u2) == "expired"
    # cancel while queued never takes a ref at all
    u3 = eng.submit(shared, max_new_tokens=3)
    assert eng.cancel(u3)
    _check_conservation(eng)

    pc = eng._pc
    assert pc.referenced_nodes == 0
    assert len(pc) > 0 and pc.pages_in_use > 0
    # full pool evictable: drain the allocator to capacity
    got = [pc._alloc_page() for _ in range(pc.capacity)]
    assert all(p is not None for p in got)
    assert sorted(got) == list(range(pc.capacity))
    assert len(pc) == 0             # every node evicted


def test_lifecycle_l2_refcount_audit_with_checkpoint_restore(
        params, prompts, tmp_path):
    """The PR-6 audit extended to the durable tiers: after mixed
    terminals + forced evictions (demote to L2) + warm promotions + a
    full checkpoint/restore cycle, refcounts are back at 0, the FULL
    device pool is drainable, and the two tiers never double-hold a
    page (every L2 key is disjoint from the live trie — promotion pops
    the blob, demotion drops the node)."""
    shared = np.asarray(prompts[3], np.int32)       # 31 tokens
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8, page_size=8, cache_pages=12,
                      l2_bytes=1 << 22)
    u0 = eng.submit(shared, max_new_tokens=3)
    eng.run_to_completion()
    assert eng.status(u0) == "finished"
    u1 = eng.submit(np.concatenate([shared, shared])[:48],
                    max_new_tokens=3)
    eng.step()
    assert eng.cancel(u1)                           # cancel mid-prefill
    # force-demote the whole trie, then warm re-admit: promotions must
    # pop their blobs (a page lives in exactly one tier)
    assert eng._pc.spill(10 ** 6) > 0
    u2 = eng.submit(shared, max_new_tokens=3)
    eng.run_to_completion()
    assert eng.status(u2) == "finished"
    assert eng.stats["l2_hits"] > 0
    # checkpoint/restore cycle: refs re-derive from the restored slots
    ck = str(tmp_path / "ckpt")
    eng.checkpoint(ck)
    eng = ServeEngine.restore(ck, params, TINY)
    _check_conservation(eng)

    pc = eng._pc
    assert pc.referenced_nodes == 0
    live = {pc._path_of(n) for n in pc._nodes}
    assert all(k not in live for k in pc.l2.keys())
    # full pool drainable (draining demotes — the disjointness must
    # keep holding as nodes move tiers)
    got = [pc._alloc_page() for _ in range(pc.capacity)]
    assert all(p is not None for p in got)
    assert sorted(got) == list(range(pc.capacity))
    assert len(pc) == 0
    live = {pc._path_of(n) for n in pc._nodes}
    assert live == set()
    assert len(pc.l2) > 0           # drain demoted, never destroyed


def test_lifecycle_conservation_under_churn(params, prompts):
    """Randomized churn: submit/cancel/step interleavings keep the
    conservation identity at every tick."""
    rng = np.random.default_rng(7)
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8, decode_block=2, max_queue=3,
                      deadline_ticks=12)
    uids = []
    for i in range(40):
        op = rng.integers(3)
        if op == 0:
            p = prompts[int(rng.integers(len(prompts)))]
            uids.append(eng.submit(p, max_new_tokens=int(rng.integers(1, 8))))
        elif op == 1 and uids:
            eng.cancel(int(rng.choice(uids)))
        else:
            eng.step()
        _check_conservation(eng)
    eng.run_to_completion()
    _check_conservation(eng)
    assert eng.in_flight == 0
    terminal = {"finished", "rejected", "cancelled", "expired", "failed"}
    assert all(eng.status(u) in terminal for u in uids)


# ---------------------------------------------------------------------------
# bounded retention (retain_results)
# ---------------------------------------------------------------------------

def test_retention_result_pops_on_read(params, prompts):
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8, retain_results=8)
    u = eng.submit(prompts[0], max_new_tokens=3)
    eng.run_to_completion()
    toks = eng.result(u)
    assert toks is not None and len(toks) == 3
    # first read released the engine's copy
    assert eng.result(u) is None
    _check_conservation(eng)


def test_retention_evicts_oldest_terminal(params, prompts):
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8, retain_results=2)
    uids = [eng.submit(p, max_new_tokens=2) for p in prompts[:4]]
    eng.run_to_completion()
    # only the newest 2 terminal entries survive; evicted uids forget
    # both their status and their result
    kept = [u for u in uids if u in eng._status]
    assert len(kept) == 2 and kept == sorted(uids)[-2:]
    assert eng.result(uids[0]) is None
    with pytest.raises(KeyError):
        eng.status(uids[0])
    assert eng.result(kept[-1]) is not None
    # conservation is counter-based, so eviction does not break it
    _check_conservation(eng)
    assert eng.stats["finished"] == 4


def test_retention_conservation_over_10k_request_churn(params):
    """Long-running-service memory bound: 10k one-token requests
    through a retain_results window keep the engine's per-request maps
    at O(window), conserve every lifecycle counter, and (with
    telemetry on) drain the request-tracking map — nothing grows with
    total requests served."""
    retain = 64
    eng = ServeEngine(params, TINY, slots=8, max_len=MAX_LEN,
                      prefill_chunk=16, retain_results=retain,
                      telemetry=True, trace_events=256)
    rng = np.random.default_rng(3)
    total, waves = 10_000, 10
    for w in range(waves):
        uids = [eng.submit(rng.integers(0, TINY.vocab_size,
                                        size=int(rng.integers(2, 6))),
                           max_new_tokens=1)
                for _ in range(total // waves)]
        eng.run_to_completion()
        # sample a few results: present exactly once, then popped
        for u in uids[-4:]:
            assert len(eng.result(u)) == 1
            assert eng.result(u) is None
        _check_conservation(eng)
        assert len(eng._status) <= retain
        assert len(eng._done) <= retain
        assert len(eng._terminal_order) <= retain
        assert not eng.tm._reqs            # per-request tracks drained
        assert len(eng.tm.tracer.events) <= 256
    s = eng.stats
    assert s["submitted"] == s["finished"] == total
    assert eng.in_flight == 0
    # the metrics plane kept the full count even though the result
    # maps only ever held the serving window
    snap = eng.tm.metrics_snapshot()
    assert snap["counters"]["serve_finished"] == total
    ttft = snap["histograms"]["serve_ttft_ns{terminal=finished}"]
    assert ttft["total"] == total
