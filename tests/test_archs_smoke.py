"""Per-architecture smoke tests (assignment requirement): a REDUCED
same-family config per arch runs one forward + one train step on CPU,
asserting output shapes and no NaNs; plus prefill/decode-step exactness
against the full-sequence forward.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    ShapeKind,
    ShardingConfig,
    get_arch,
    list_archs,
    smoke_variant,
)
from repro.models import decoder
from repro.models.frontend import audio_frame_embeds, vision_patch_embeds
from repro.train.step import init_train_state, make_train_step

ARCHS = list_archs()
B, S = 2, 32


def _smoke_inputs(cfg, key):
    if cfg.frontend == "audio_frames":
        return None, audio_frame_embeds(key, B, S, cfg)
    if cfg.frontend == "vision_patches":
        return None, vision_patch_embeds(key, B, S, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return toks, None


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = smoke_variant(get_arch(arch))
    params = decoder.init_params(jax.random.PRNGKey(0), cfg)
    toks, embeds = _smoke_inputs(cfg, jax.random.PRNGKey(1))
    logits, aux = decoder.forward(params, cfg, toks, embeds)
    vp = decoder.padded_vocab(cfg.vocab_size)
    assert logits.shape == (B, S, vp)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = smoke_variant(get_arch(arch))
    # f32 smoke training for numerics
    cfg = dataclasses.replace(cfg, dtype="float32")
    shape = ShapeConfig("smoke", ShapeKind.TRAIN, S, B)
    run = RunConfig(model=cfg, shape=shape,
                    optimizer=OptimizerConfig(lr=1e-3, total_steps=4,
                                              warmup_steps=1),
                    sharding=ShardingConfig(remat="none"))
    state = init_train_state(jax.random.PRNGKey(0), run)
    step = make_train_step(run, None)
    key = jax.random.PRNGKey(1)
    toks, embeds = _smoke_inputs(cfg, key)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    batch = ({"embeds": embeds, "labels": labels} if embeds is not None
             else {"tokens": toks, "labels": labels})
    losses = []
    for _ in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], f"loss did not fall: {losses}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_match_forward(arch):
    cfg = dataclasses.replace(smoke_variant(get_arch(arch)),
                              dtype="float32")
    params = decoder.init_params(jax.random.PRNGKey(0), cfg)
    toks, embeds = _smoke_inputs(cfg, jax.random.PRNGKey(1))
    if embeds is not None:
        logits, _ = decoder.forward(params, cfg, inputs_embeds=embeds)
        lp, cache = decoder.prefill(params, cfg,
                                    inputs_embeds=embeds[:, :S - 1],
                                    max_len=S + 4)
        ld, _ = decoder.decode_step(params, cfg, cache, None,
                                    jnp.int32(S - 1),
                                    input_embed=embeds[:, S - 1])
    else:
        logits, _ = decoder.forward(params, cfg, toks)
        lp, cache = decoder.prefill(params, cfg, toks[:, :S - 1],
                                    max_len=S + 4)
        ld, _ = decoder.decode_step(params, cfg, cache, toks[:, S - 1],
                                    jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(logits[:, S - 2]),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(logits[:, S - 1]),
                               atol=2e-4, rtol=2e-4)


def test_all_archs_registered():
    assert len(ARCHS) == 10
    for arch in ARCHS:
        cfg = get_arch(arch)
        assert cfg.param_count() > 0
        assert cfg.active_param_count() <= cfg.param_count()


def test_full_param_counts_plausible():
    """Full configs should be in the advertised parameter range."""
    expect = {
        "deepseek-moe-16b": (14e9, 20e9),
        "grok-1-314b": (280e9, 340e9),
        "gemma3-4b": (2.5e9, 5.5e9),
        "phi4-mini-3.8b": (3e9, 4.8e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "internlm2-1.8b": (1.5e9, 2.3e9),
        "recurrentgemma-2b": (2e9, 3.4e9),
        "xlstm-350m": (0.2e9, 0.6e9),
        "musicgen-medium": (1e9, 2.2e9),
        "internvl2-2b": (1.5e9, 2.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_arch(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
