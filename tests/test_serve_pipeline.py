"""Pipelined tick-loop conformance: deferred harvest is a pure
scheduling change.

The engine's ``pipeline_depth`` knob defers the per-block ring harvest
behind the dispatch stream: tick N's ``[slots, 1+T]`` harvest array is
read back only after up to ``depth`` newer dispatches have issued, the
next block's input tokens ride the device-resident cross-block carry,
and host bookkeeping acts on the one-tick-delayed view (optimistic
``pos``/``budget`` advance at dispatch, uid-guarded finish/poison
accounting at harvest). None of that may change WHAT is generated:

* ``pipeline_depth=1`` (and 2) is token-for-token identical to the
  synchronous ``pipeline_depth=0`` engine across all four mixer kinds
  the engine serves (attention, A^3 attention, RG-LRU hybrid, pure
  xLSTM) and across admission orders,
* ``pipeline_depth=0`` is bit-identical — tokens AND scheduling
  counters — to the default-constructed engine (the knob is opt-in;
  the historical engine is the ``depth=0`` special case),
* the lifecycle edges that now act on the delayed view stay correct:
  deadline expiry, cancel, and chaos poison quarantine under
  ``pipeline_depth=1`` terminate exactly one victim and leave every
  other request's stream untouched,
* the conservation identity ``submitted == finished + rejected +
  cancelled + expired + failed + in_flight`` closes after EVERY tick
  with harvests in flight,
* crash/restore with a deferred harvest in flight resumes
  token-for-token (checkpoints drain pending harvests first),
* and the perf counters move the right way: strictly fewer blocking
  ``host_syncs`` at depth 1 on a decode-heavy workload, sane
  ``tick_ns_*`` phase timings, and the carry-returning decode block
  lowering on the 8-device CI mesh.
"""
from __future__ import annotations

import time

import numpy as np
import pytest

import jax

from helpers import check, run_with_devices

from repro.config import A3Config, AttentionKind, BlockKind, ModelConfig
from repro.models import decoder as dec
from repro.serve.chaos import ChaosConfig, ChaosInjector, EngineCrash
from repro.serve.engine import ServeEngine

TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                   dtype="float32")
TINY_RG = ModelConfig("tiny-rg", "hybrid", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, head_dim=16,
                      attention_kind=AttentionKind.SLIDING, window_size=24,
                      block_pattern=(BlockKind.RGLRU, BlockKind.RGLRU,
                                     BlockKind.ATTENTION),
                      act="gelu", dtype="float32")
TINY_XL = ModelConfig("tiny-xl", "ssm", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=256,
                      head_dim=16,
                      block_pattern=(BlockKind.MLSTM, BlockKind.MLSTM,
                                     BlockKind.SLSTM),
                      dtype="float32")
MAX_LEN = 96
MAX_NEW = 6
PROMPT_LENS = (5, 12, 23, 9)

KINDS = {"attention": (TINY, A3Config()),
         "a3": (TINY, A3Config.conservative()),
         "rglru": (TINY_RG, A3Config()),
         "xlstm": (TINY_XL, A3Config())}


@pytest.fixture(scope="module")
def all_params():
    return {
        "tiny": dec.init_params(jax.random.PRNGKey(0), TINY),
        "tiny-rg": dec.init_params(jax.random.PRNGKey(1), TINY_RG),
        "tiny-xl": dec.init_params(jax.random.PRNGKey(2), TINY_XL),
    }


def _prompts(vocab, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n) for n in PROMPT_LENS]


def _check_conservation(eng):
    s = eng.stats
    assert s["submitted"] == (s["finished"] + s["rejected"]
                              + s["cancelled"] + s["expired"]
                              + s["failed"] + eng.in_flight), s


def _run(params, cfg, prompts, *, a3=A3Config(), order="upfront",
         depth=0, decode_block=2, max_new=MAX_NEW, chaos=None, **kw):
    eng = ServeEngine(params, cfg, slots=2, max_len=MAX_LEN, a3=a3,
                      prefill_chunk=8, decode_block=decode_block,
                      pipeline_depth=depth, chaos=chaos, **kw)
    uids = {}
    if order == "upfront":
        for i, p in enumerate(prompts):
            uids[i] = eng.submit(p, max_new_tokens=max_new)
        eng.run_to_completion()
    elif order == "staggered":
        pending = list(enumerate(prompts))
        while pending or eng._queue or any(s.active for s in eng.slots):
            if pending and eng.stats["ticks"] % 2 == 0:
                i, p = pending.pop(0)
                uids[i] = eng.submit(p, max_new_tokens=max_new)
            eng.step()
    else:
        raise ValueError(order)
    return {i: eng.result(u) for i, u in uids.items()}, eng, uids


# ---------------------------------------------------------------------------
# headline parity: deferred harvest never changes tokens
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", ["upfront", "staggered"])
@pytest.mark.parametrize("kind", ["attention", "a3", "rglru", "xlstm"])
def test_pipeline_depth_parity_all_kinds(all_params, kind, order):
    cfg, a3 = KINDS[kind]
    params = all_params[cfg.name]
    prompts = _prompts(cfg.vocab_size)
    ref, e0, _ = _run(params, cfg, prompts, a3=a3, order=order, depth=0)
    got, e1, _ = _run(params, cfg, prompts, a3=a3, order=order, depth=1)
    assert got == ref
    assert all(r is not None for r in ref.values())
    # scheduling MAY legitimately shift (a slot whose last ring is in
    # flight frees one tick later, delaying the next admission by a
    # tick), but every request finishes, per-lane A^3 resort counts are
    # pos-driven and schedule-independent, and deferral never ADDS
    # blocking syncs
    assert e1.stats["finished"] == e0.stats["finished"]
    assert e1.stats["resorts"] == e0.stats["resorts"]
    assert e1.stats["host_syncs"] <= e0.stats["host_syncs"]
    _check_conservation(e0)
    _check_conservation(e1)


def test_pipeline_depth_two_parity(all_params):
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size)
    ref, _, _ = _run(params, TINY, prompts, depth=0)
    got, eng, _ = _run(params, TINY, prompts, depth=2)
    assert got == ref
    _check_conservation(eng)


def test_pipeline_depth_parity_with_sampling(all_params):
    """temperature > 0: the (seed, uid, pos)-keyed in-graph sampler
    draws the same stream regardless of harvest depth."""
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size)
    kw = dict(temperature=0.8, sample_seed=5)
    ref, _, _ = _run(params, TINY, prompts, depth=0, **kw)
    got, _, _ = _run(params, TINY, prompts, depth=1, **kw)
    assert got == ref


def test_pipeline_depth_zero_pins_default_engine(all_params):
    """depth=0 IS the historical engine: token streams and every
    counter (modulo wall-clock timings) match a default-constructed
    engine bit-for-bit."""
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size)
    eng_default = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                              prefill_chunk=8, decode_block=2)
    uids = [eng_default.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
    eng_default.run_to_completion()
    got, e0, u0 = _run(params, TINY, prompts, depth=0)
    assert [e0.result(u0[i]) for i in range(len(prompts))] == \
        [eng_default.result(u) for u in uids]
    # tick_ns_* are wall-clock; host_sync_stalls depends on whether the
    # device finished before the drain checked is_ready() — a race
    # against real time, not part of the deterministic contract
    strip = lambda st: {k: v for k, v in st.items()
                        if not k.startswith("tick_ns")
                        and k != "host_sync_stalls"}
    assert strip(e0.stats) == strip(eng_default.stats)


def test_pipeline_rejects_negative_depth(all_params):
    with pytest.raises(ValueError, match="pipeline_depth"):
        ServeEngine(all_params["tiny"], TINY, slots=2, max_len=MAX_LEN,
                    pipeline_depth=-1)


# ---------------------------------------------------------------------------
# conservation closes every tick with harvests in flight
# ---------------------------------------------------------------------------

def test_pipeline_conservation_closes_every_tick(all_params):
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size)
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8, decode_block=2, pipeline_depth=1)
    uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
    saw_pending = False
    for _ in range(200):
        if eng.in_flight == 0:
            break
        eng.step()
        saw_pending = saw_pending or len(eng._pending) > 0
        _check_conservation(eng)
    assert eng.in_flight == 0
    assert saw_pending, "depth=1 must actually defer harvests"
    for u in uids:
        assert eng.status(u) == "finished"


# ---------------------------------------------------------------------------
# lifecycle edges on the one-tick-delayed view
# ---------------------------------------------------------------------------

def test_pipeline_cancel_acts_on_delayed_view(all_params):
    """Cancelling a DECODING request whose latest ring is still in
    flight releases the slot immediately; the stale harvest rows are
    uid-dropped, every other stream is untouched."""
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size)
    ref, _, _ = _run(params, TINY, prompts, depth=0)

    for depth in (0, 1):
        eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                          prefill_chunk=8, decode_block=2,
                          pipeline_depth=depth)
        uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
        # step until the first submitted request is decoding, then
        # cancel it (at depth 1 its last ring is typically pending)
        for _ in range(200):
            st = [s for s in eng.slots if s.uid == uids[0]]
            if st and st[0].decoding:
                break
            eng.step()
        assert eng.cancel(uids[0])
        eng.run_to_completion()
        assert eng.status(uids[0]) == "cancelled"
        assert eng.result(uids[0]) is None
        for i in (1, 2, 3):
            assert eng.status(uids[i]) == "finished"
            assert eng.result(uids[i]) == ref[i], (depth, i)
        _check_conservation(eng)


def test_pipeline_deadline_expiry_on_delayed_view(all_params):
    """Deadlines act on the optimistic host view: an expiry landing in
    the harvest gap terminates the request deterministically (the
    delayed view may legitimately expire a request whose final tokens
    were still in flight — one tick later than the synchronous engine
    would have finished it — but the decision is a pure function of
    the tick count, so identical runs agree exactly), and the books
    close either way."""
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size)
    outcomes = {}
    for depth, tag in ((0, "d0"), (1, "d1a"), (1, "d1b")):
        eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                          prefill_chunk=8, decode_block=2,
                          pipeline_depth=depth, deadline_ticks=4)
        uids = [eng.submit(p, max_new_tokens=32) for p in prompts]
        eng.run_to_completion()
        statuses = [eng.status(u) for u in uids]
        assert set(statuses) <= {"finished", "expired"}, tag
        assert "expired" in statuses, "deadline must bite"
        _check_conservation(eng)
        outcomes[tag] = (statuses, [eng.result(u) for u in uids])
    # pinned determinism: two depth-1 runs agree bit-for-bit
    assert outcomes["d1a"] == outcomes["d1b"]
    # requests that finish under BOTH views generated identical tokens
    for (s0, r0), (s1, r1) in [(outcomes["d0"], outcomes["d1a"])]:
        for i in range(len(prompts)):
            if s0[i] == "finished" and s1[i] == "finished":
                assert r0[i] == r1[i], i


def test_pipeline_poison_quarantine_on_delayed_harvest(all_params):
    """Chaos-corrupted lanes poison through the deferred ring: the
    victim fails (one request), the sentinel never reaches a result,
    and un-injected requests match the chaos-free synchronous run."""
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size)
    ref, _, _ = _run(params, TINY, prompts, depth=0)
    chaos = ChaosInjector(ChaosConfig(seed=0, rate=0.5,
                                      raise_mid_tick=False,
                                      fail_gather=False,
                                      max_injections=1))
    got, eng, uids = _run(params, TINY, prompts, depth=1, chaos=chaos)
    victims = chaos.injected_uids
    assert victims, "the pinned (seed, rate) schedule must inject"
    for i, u in uids.items():
        if u in victims:
            assert eng.status(u) == "failed"
            assert eng.result(u) is None
        else:
            assert eng.status(u) == "finished"
            assert eng.result(u) == ref[i]
    for r in got.values():
        assert r is None or dec.POISON not in r
    _check_conservation(eng)


# ---------------------------------------------------------------------------
# crash / restore with a harvest in flight
# ---------------------------------------------------------------------------

def test_pipeline_crash_restore_with_harvest_in_flight(all_params,
                                                       tmp_path):
    """EngineCrash with deferred harvests pending: the per-tick
    checkpoint drains them first (host-consistent snapshot), so
    restore + continue emits exactly the crash-free depth-0 tokens."""
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size)
    ref, _, _ = _run(params, TINY, prompts, depth=0)

    chaos = ChaosInjector(ChaosConfig(seed=3, rate=0.3,
                                      corrupt_logits=False,
                                      fail_gather=False,
                                      raise_mid_tick=False,
                                      crash_mid_tick=True))
    eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8, decode_block=2, pipeline_depth=1,
                      chaos=chaos)
    uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
    ck = str(tmp_path / "ckpt")
    eng.checkpoint(ck)
    crashes, saw_pending = 0, False
    while eng.in_flight > 0:
        try:
            eng.step()
            saw_pending = saw_pending or len(eng._pending) > 0
            eng.checkpoint(ck)
            assert len(eng._pending) == 0  # checkpoint drained them
        except EngineCrash:
            crashes += 1
            eng = ServeEngine.restore(ck, params, TINY)
            assert eng.pipeline_depth == 1  # depth survives the trip
    assert crashes >= 1, "the pinned schedule must crash at least once"
    assert saw_pending, "a harvest must have been in flight pre-crash"
    for i, u in enumerate(uids):
        assert eng.status(u) == "finished"
        assert eng.result(u) == ref[i]
    _check_conservation(eng)


# ---------------------------------------------------------------------------
# perf counters: syncs fall, timings are sane
# ---------------------------------------------------------------------------

def test_pipeline_host_syncs_strictly_lower(all_params):
    """The acceptance criterion: on a decode-heavy workload the depth-1
    engine issues strictly fewer blocking host syncs than the
    synchronous engine at the same decode_block — for block=1 AND
    block=8."""
    params = all_params["tiny"]
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, TINY.vocab_size, size=8) for _ in range(2)]
    for block in (1, 8):
        ref, e0, _ = _run(params, TINY, prompts, depth=0,
                          decode_block=block, max_new=24)
        got, e1, _ = _run(params, TINY, prompts, depth=1,
                          decode_block=block, max_new=24)
        assert got == ref, block
        assert e1.stats["host_syncs"] < e0.stats["host_syncs"], (
            block, e1.stats["host_syncs"], e0.stats["host_syncs"])
        # stalls only count harvests that actually blocked
        assert 0 <= e1.stats["host_sync_stalls"] <= e1.stats["host_syncs"]


def test_pipeline_timing_stats_sane(all_params):
    """tick_ns_* phase timings: non-negative, present at every depth,
    and their sum never exceeds the wall time of the run."""
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size)
    for depth in (0, 1):
        eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                          prefill_chunk=8, decode_block=2,
                          pipeline_depth=depth)
        uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
        t0 = time.monotonic_ns()
        eng.run_to_completion()
        wall = time.monotonic_ns() - t0
        keys = ["tick_ns_prefill", "tick_ns_decode", "tick_ns_harvest",
                "tick_ns_host"]
        for k in keys:
            assert eng.stats[k] >= 0, (depth, k)
        assert sum(eng.stats[k] for k in keys) <= wall, depth
        # the engine did real per-phase work: decode + host are nonzero
        assert eng.stats["tick_ns_decode"] > 0
        assert eng.stats["tick_ns_host"] > 0
        for u in uids:
            assert eng.status(u) == "finished"


# ---------------------------------------------------------------------------
# sharded lowering of the carry-returning decode block (8-dev CI mesh)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_pipeline_carry_decode_block_lowers_sharded():
    """The carry-returning decode block lowers under GSPMD on the
    8-device CI mesh: outputs are (ring [B, T], carry [B], cache) with
    the cache donated — the device-resident token chain the pipelined
    engine rides exists on the production mesh, not just on one CPU
    device."""
    out = check(run_with_devices("""
import jax
from repro.config import A3Config, ShapeConfig, ShapeKind, ShardingConfig, \\
    get_arch, smoke_variant
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import lower_decode_block

cfg = smoke_variant(get_arch("phi4-mini-3.8b"))
dshape = ShapeConfig("decode_smoke", ShapeKind.DECODE, 256, 8)
mesh = make_mesh((2, 4), ("data", "model"))
scfg = ShardingConfig(remat="none")
with mesh:
    c = lower_decode_block(cfg, dshape, mesh, scfg, steps=8,
                           a3=A3Config.conservative(),
                           resort_every=64).compile()
assert c.memory_analysis().alias_size_in_bytes > 0   # donation held
print("OK")
""", devices=8, timeout=900))
    assert "OK" in out


# ---------------------------------------------------------------------------
# virtual-device emulation: the pipeline hides emulated completion latency
# ---------------------------------------------------------------------------

def test_pipeline_hides_virtual_device_latency(all_params):
    """Under ``virtual_device_latency_s`` — each decode block's ring
    readable only L after dispatch, a GIL-releasing readiness floor
    emulating an accelerator completing off-host — the synchronous
    engine serializes on L once per block (its drain reads the block
    it just dispatched, so the sleep intervals are disjoint by
    construction), while a primed pipeline keeps blocks in flight and
    amortizes L across the ticks it spends planning ahead. That makes
    the overlap a deterministic wall-clock win even on a single-core
    host, where real XLA compute timeshares the tick loop's core and
    raw overlap is otherwise invisible. The knob never changes
    tokens."""
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size)[:2]
    L = 0.004

    def timed(depth, lat):
        eng = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                          prefill_chunk=8, decode_block=1,
                          pipeline_depth=depth,
                          virtual_device_latency_s=lat)
        w = eng.submit(prompts[0], max_new_tokens=2)   # compile warmup
        eng.run_to_completion()
        assert eng.result(w) is not None
        eng.stats = {k: 0 for k in eng.stats}
        uids = [eng.submit(p, max_new_tokens=24) for p in prompts]
        eng.step()                                     # admission tick
        jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
        t0 = time.perf_counter()
        eng.run_to_completion()
        wall = time.perf_counter() - t0
        return [eng.result(u) for u in uids], eng, wall

    base, _, _ = timed(0, 0.0)
    ref, e0, wall0 = timed(0, L)
    got, e2, wall2 = timed(2, L)
    assert ref == base              # emulation is scheduling only
    assert got == ref               # deferral is scheduling only
    # every synchronous drain stalls out the emulated latency; the
    # primed pipeline's forced reads find blocks past their readiness
    # floor after warmup
    assert e2.stats["host_sync_stalls"] < e0.stats["host_sync_stalls"]
    assert e2.stats["host_syncs"] < e0.stats["host_syncs"]
    # depth 0 pays >= decode_dispatches * L serially (disjoint
    # sleeps): wall0 has a hard floor no load can shrink. Depth 2
    # amortizes each L over 3 ticks of useful host work. 0.75 leaves
    # a wide margin for a loaded CI host.
    assert wall0 >= (e0.stats["decode_dispatches"] - 1) * L
    assert wall2 < 0.75 * wall0, (wall0, wall2, dict(e2.stats))
