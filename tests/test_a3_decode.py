"""A^3 decode integration: cached sorted keys (prefill comprehension),
compact sharded selection, fresh-tail exactness, and logits fidelity
against exact decode."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import A3Config, ModelConfig
from repro.models import decoder as dec

CFG = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=300, head_dim=16,
                  dtype="float32")


@pytest.fixture(scope="module")
def setup():
    params = dec.init_params(jax.random.PRNGKey(0), CFG)
    B, S = 2, 63
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, 300)
    logits, _ = dec.forward(params, CFG, toks)
    return params, toks, logits, B, S


def _cos(a, b):
    return float(jnp.mean(jnp.sum(a * b, -1) /
                          (jnp.linalg.norm(a, axis=-1)
                           * jnp.linalg.norm(b, axis=-1))))


@pytest.mark.parametrize("ns", [1, 4])
@pytest.mark.parametrize("mode", ["conservative", "aggressive"])
def test_compact_decode_close_to_exact(setup, ns, mode):
    params, toks, logits, B, S = setup
    base = (A3Config.conservative() if mode == "conservative"
            else A3Config.aggressive())
    a3 = dataclasses.replace(base, select_shards=ns)
    lp, cache = dec.prefill(params, CFG, toks[:, :S], max_len=64,
                            a3=True, select_shards=ns)
    ld, _ = dec.decode_step(params, CFG, cache, toks[:, S], jnp.int32(S),
                            a3=a3)
    ref = logits[:, S, :300]
    assert _cos(ld[:, :300], ref) > 0.98
    # greedy next token agrees
    np.testing.assert_array_equal(np.asarray(jnp.argmax(ld[:, :300], -1)),
                                  np.asarray(jnp.argmax(ref, -1)))


def test_a3_cache_exact_path_unchanged(setup):
    """With a3 cache present but mode OFF, decode is bit-identical to the
    plain exact path (read-only leaves never perturb the computation)."""
    params, toks, logits, B, S = setup
    _, cache_a3 = dec.prefill(params, CFG, toks[:, :S], max_len=64, a3=True)
    _, cache = dec.prefill(params, CFG, toks[:, :S], max_len=64)
    l1, _ = dec.decode_step(params, CFG, cache_a3, toks[:, S], jnp.int32(S))
    l2, _ = dec.decode_step(params, CFG, cache, toks[:, S], jnp.int32(S))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_fresh_tail_rows_always_candidates(setup):
    """Tokens decoded after the prefill sort must be attended exactly:
    decode several steps without re-sorting and compare with exact."""
    params, toks, logits, B, S = setup
    a3 = A3Config.conservative()
    _, cache_a = dec.prefill(params, CFG, toks[:, :48], max_len=64, a3=True)
    _, cache_e = dec.prefill(params, CFG, toks[:, :48], max_len=64)
    pos = 48
    for t in range(4):
        tok = toks[:, 48 + t]
        la, cache_a = dec.decode_step(params, CFG, cache_a, tok,
                                      jnp.int32(pos), a3=a3)
        le, cache_e = dec.decode_step(params, CFG, cache_e, tok,
                                      jnp.int32(pos))
        assert _cos(la[:, :300], le[:, :300]) > 0.98, t
        pos += 1


def test_compact_selection_recall():
    """The budgeted (prefix-capped, heuristic-free) selection keeps the
    high-weight keys on *structured* data (keys clustered, query near a
    cluster — real attention's regime and the paper's: its bAbI
    embeddings are content-correlated). On isotropic gaussian data
    single-component products carry little signal and recall degrades
    toward the budget fraction — measured and recorded in
    EXPERIMENTS.md; the accuracy-bearing claim is the Fig. 13 benchmark
    (0.95 top-2 recall, conservative, trained MemN2N)."""
    from repro.core.candidate_selection import select_candidates, \
        sort_key_columns
    key = jax.random.PRNGKey(3)
    n, d = 256, 32
    hits = 0
    for i in range(20):
        k1, k2, k3, key = jax.random.split(key, 4)
        cents = jax.random.normal(k1, (8, d))
        assign = jax.random.randint(k2, (n,), 0, 8)
        kmat = cents[assign] * 0.5 + 0.1 * jax.random.normal(k3, (n, d))
        q = cents[0] * 0.5 + 0.1 * jax.random.normal(k2, (d,))
        sk = sort_key_columns(kmat)
        m = n // 2                              # conservative
        cap = max(16, 4 * m // d)
        cand, greedy = select_candidates(sk, q, m, prefix_cap=cap,
                                         use_heuristic=False)
        scores = kmat @ q
        top2 = jnp.argsort(scores)[-2:]
        sel = jnp.argsort(greedy)[-(m // 2):]
        hits += int(jnp.isin(top2, sel).sum())
    assert hits >= 32, hits          # >= 80% top-2 recall
