"""Substrate tests: optimizer, data pipeline, checkpointing, fault
tolerance, serving engine."""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (
    CheckpointConfig,
    ModelConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    ShapeKind,
    ShardingConfig,
)
from repro.data.babi import generate_babi, make_task
from repro.data.synthetic import SyntheticLM, make_lm_batch
from repro.models import decoder
from repro.optim.adamw import adamw_init, adamw_update, cosine_schedule, \
    global_norm
from repro.serve.engine import ServeEngine
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import Watchdog, WatchdogTimeout, run_with_restarts
from repro.train.loop import train_loop, train_with_recovery
from repro.train.step import TrainState, init_train_state, make_train_step

TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                   dtype="float32")
SHAPE = ShapeConfig("t", ShapeKind.TRAIN, seq_len=32, global_batch=4)


def _run(tmp, **kw):
    return RunConfig(
        model=TINY, shape=SHAPE,
        optimizer=OptimizerConfig(lr=1e-3, total_steps=10, warmup_steps=2),
        sharding=ShardingConfig(remat="none"),
        checkpoint=CheckpointConfig(directory=tmp, **kw))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_reference():
    """One AdamW step against a hand-rolled numpy reference."""
    cfg = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                          weight_decay=0.1, grad_clip_norm=1e9)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    st = adamw_init(p, cfg)
    newp, st2, _ = adamw_update(g, st, p, cfg)

    lr = float(cosine_schedule(cfg, jnp.asarray(1)))
    m = 0.1 * np.asarray(g["w"])
    v = 0.05 * np.asarray(g["w"]) ** 2
    mh, vh = m / 0.1, v / 0.05
    ref = np.asarray(p["w"]) - lr * (mh / (np.sqrt(vh) + cfg.eps)
                                     + 0.1 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(newp["w"]), ref, rtol=1e-5)


def test_grad_clipping():
    cfg = OptimizerConfig(lr=1e-2, warmup_steps=0, grad_clip_norm=0.1)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    st = adamw_init(p, cfg)
    _, _, metrics = adamw_update(g, st, p, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_cosine_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s)))
           for s in [0, 5, 10, 60, 110, 200]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)
    assert lrs[5] == pytest.approx(0.1, abs=1e-6)


def test_bf16_m_state_dtype():
    cfg = OptimizerConfig(m_dtype="bfloat16")
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = adamw_init(p, cfg)
    assert st.m["w"].dtype == jnp.bfloat16
    assert st.v["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_lm_batch_deterministic_and_restartable():
    b1 = make_lm_batch(7, 4, 64, 1000, seed=3)
    b2 = make_lm_batch(7, 4, 64, 1000, seed=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()

    pipe = SyntheticLM(4, 64, 1000, seed=3)
    first = next(pipe)
    state = pipe.state
    pipe.close()
    pipe2 = SyntheticLM.restore(state, 4, 64, 1000)
    second = next(pipe2)
    pipe2.close()
    expect = make_lm_batch(1, 4, 64, 1000, seed=3)
    np.testing.assert_array_equal(second["tokens"], expect["tokens"])
    np.testing.assert_array_equal(first["tokens"],
                                  make_lm_batch(0, 4, 64, 1000, 3)["tokens"])


def test_babi_generator_answers_consistent():
    task = make_task()
    data = generate_babi(task, 16, 20, seed=1)
    for b in range(16):
        actor = data["question"][b, 2]
        # find last statement about that actor
        place = None
        for s in range(20):
            if data["sentences"][b, s, 0] == actor:
                place = data["sentences"][b, s, 2]
        assert place is not None and place == data["answer"][b]


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(CheckpointConfig(directory=d, keep=2,
                                                 async_save=False))
        state = {"a": jnp.arange(6).reshape(2, 3),
                 "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
        for step in [5, 10, 15]:
            mgr.save(step, state, extra={"step": step})
        assert mgr.all_steps() == [10, 15]        # keep=2
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        restored, extra = mgr.restore(target)
        assert extra["step"] == 15
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(state["a"]))
        assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_async():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(CheckpointConfig(directory=d,
                                                 async_save=True))
        state = {"a": jnp.zeros((128, 128))}
        mgr.save(1, state)
        mgr.wait()
        assert mgr.latest_step() == 1


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(CheckpointConfig(directory=d,
                                                 async_save=False))
        mgr.save(1, {"a": jnp.zeros((4,))})
        with pytest.raises(ValueError):
            mgr.restore({"a": jax.ShapeDtypeStruct((5,), jnp.float32)})


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_watchdog_timeout():
    wd = Watchdog(0.2)
    with pytest.raises(WatchdogTimeout):
        wd.run(lambda: time.sleep(2.0))
    assert wd.run(lambda: 42) == 42


def test_run_with_restarts():
    calls = []

    def body(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise RuntimeError("boom")
        return "ok"

    assert run_with_restarts(body, max_restarts=5) == "ok"
    assert calls == [0, 1, 2]

    with pytest.raises(RuntimeError):
        run_with_restarts(lambda a: (_ for _ in ()).throw(RuntimeError()),
                          max_restarts=1)


def test_train_recovery_resumes_from_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        run = _run(d, save_every=2, async_save=False)
        out = train_with_recovery(run, num_steps=6, fail_at_step=4)
        assert out["restarts"] == [1]
        # after recovery the run completed all 6 steps; the post-restart
        # segment starts from step 4 (checkpoint at 4)
        assert out["final_step"] == 6
        mgr = CheckpointManager(run.checkpoint)
        assert mgr.latest_step() == 6


def test_train_recovery_matches_uninterrupted():
    """Recovered run must produce the same final loss as an unbroken
    one (determinism of data pipeline + checkpointed state)."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        base = train_loop(_run(d1, save_every=100), num_steps=6)
        rec = train_with_recovery(_run(d2, save_every=3, async_save=False),
                                  num_steps=6, fail_at_step=4)
        assert base["losses"][-1] == pytest.approx(rec["losses"][-1],
                                                   rel=1e-5)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_serve_engine_greedy_matches_manual():
    params = decoder.init_params(jax.random.PRNGKey(0), TINY)
    toks = np.arange(9) % TINY.vocab_size
    lp, cache = decoder.prefill(params, TINY, jnp.asarray(toks)[None],
                                max_len=64)
    cur = int(jnp.argmax(lp[0]))
    outs = [cur]
    pos = 9
    for _ in range(5):
        lg, cache = decoder.decode_step(params, TINY, cache,
                                        jnp.asarray([cur]), jnp.int32(pos))
        cur = int(jnp.argmax(lg[0]))
        outs.append(cur)
        pos += 1
    eng = ServeEngine(params, TINY, slots=2, max_len=64)
    u = eng.submit(toks, max_new_tokens=6)
    eng.run_to_completion()
    assert eng.result(u) == outs


def test_serve_engine_many_requests_slots():
    params = decoder.init_params(jax.random.PRNGKey(0), TINY)
    eng = ServeEngine(params, TINY, slots=3, max_len=64)
    uids = [eng.submit(np.arange(4 + i) % TINY.vocab_size,
                       max_new_tokens=3 + i % 3) for i in range(7)]
    eng.run_to_completion()
    for i, u in enumerate(uids):
        r = eng.result(u)
        assert r is not None and len(r) == 3 + i % 3
    # token ids must be within the real vocab (padding masked)
    for u in uids:
        assert max(eng.result(u)) < TINY.vocab_size
