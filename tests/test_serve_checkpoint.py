"""Crash-consistent engine checkpoint/restore conformance.

The durability contract (engine module docstring): ``checkpoint()``
snapshots the COMPLETE serving state — slots mid-prefill/mid-decode,
queue, statuses, budgets, sampling seed, stats, device cache, prefix
trie + pool + L2 blobs — with an atomic rename commit, and ``restore``
resumes token-for-token: ticking the restored engine emits exactly the
tokens the uninterrupted run would have. Coverage:

* mid-stream checkpoint/restore token parity across all four mixer
  kinds the engine serves: attention, A^3 attention, RG-LRU hybrid,
  pure xLSTM — with requests caught queued, prefilling, and decoding,
* the chaos ``crash`` site: kill mid-tick (EngineCrash propagates out
  of ``run_to_completion``), restore from the last per-tick
  checkpoint, continue — final tokens identical to a crash-free run,
* torn/corrupt checkpoints fail LOUDLY (:class:`CheckpointError` on a
  flipped state byte, truncated arrays, wrong model, wrong A^3 mode —
  never a silently wrong resume), and an interrupted commit falls back
  to the ``.old`` previous-complete checkpoint,
* bookkeeping round trip: statuses, queue order, results of finished
  requests, stats counters, and the L2 blob store all survive,
* sampling state: a temperature>0 engine restores the same seed and
  continues the same stochastic stream.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.config import A3Config, AttentionKind, BlockKind, ModelConfig
from repro.models import decoder as dec
from repro.serve.chaos import ChaosConfig, ChaosInjector, EngineCrash
from repro.serve.engine import ServeEngine
from repro.serve.page_store import CheckpointError

TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                   dtype="float32")
TINY_RG = ModelConfig("tiny-rg", "hybrid", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, head_dim=16,
                      attention_kind=AttentionKind.SLIDING, window_size=24,
                      block_pattern=(BlockKind.RGLRU, BlockKind.RGLRU,
                                     BlockKind.ATTENTION),
                      act="gelu", dtype="float32")
TINY_XL = ModelConfig("tiny-xl", "ssm", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=256,
                      head_dim=16,
                      block_pattern=(BlockKind.MLSTM, BlockKind.MLSTM,
                                     BlockKind.SLSTM),
                      dtype="float32")
MAX_LEN = 96
MAX_NEW = 6


@pytest.fixture(scope="module")
def all_params():
    return {
        "tiny": dec.init_params(jax.random.PRNGKey(0), TINY),
        "tiny-rg": dec.init_params(jax.random.PRNGKey(1), TINY_RG),
        "tiny-xl": dec.init_params(jax.random.PRNGKey(2), TINY_XL),
    }


def _prompts(vocab, seed=7, n=3):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, size=24)
    return [np.concatenate([shared,
                            rng.integers(0, vocab, size=4 + 3 * i)])
            for i in range(n)]


def _uninterrupted_tokens(params, cfg, prompts, a3=A3Config(), **kw):
    eng = ServeEngine(params, cfg, a3=a3, **kw)
    uids = [eng.submit(p, MAX_NEW) for p in prompts]
    eng.run_to_completion()
    return [eng.result(u) for u in uids]


# ---------------------------------------------------------------------------
# mid-stream restore: token parity across all four mixer kinds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["attention", "a3", "rglru", "xlstm"])
def test_checkpoint_restore_midstream_token_parity(all_params, tmp_path,
                                                   kind):
    cfg = {"attention": TINY, "a3": TINY, "rglru": TINY_RG,
           "xlstm": TINY_XL}[kind]
    a3 = A3Config.conservative() if kind == "a3" else A3Config()
    params = all_params[cfg.name]
    prompts = _prompts(cfg.vocab_size)
    kw = dict(slots=2, max_len=MAX_LEN, prefill_chunk=8, page_size=8,
              cache_pages=24, l2_bytes=1 << 22)
    free = _uninterrupted_tokens(params, cfg, prompts, a3=a3, **kw)

    eng = ServeEngine(params, cfg, a3=a3, **kw)
    uids = [eng.submit(p, MAX_NEW) for p in prompts]
    # catch the engine mid-flight: slots prefilling/decoding, one
    # request still queued (3 requests, 2 slots)
    for _ in range(3):
        eng.step()
    ck = str(tmp_path / "ckpt")
    eng.checkpoint(ck)
    restored = ServeEngine.restore(ck, params, cfg, a3=a3)
    restored.run_to_completion()
    for u, ref in zip(uids, free):
        assert restored.status(u) == "finished"
        assert restored.result(u) == ref
    # the original continues identically too (checkpoint is read-only)
    eng.run_to_completion()
    for u, ref in zip(uids, free):
        assert eng.result(u) == ref
    assert restored.stats["restores"] == 1
    assert restored._pc.referenced_nodes == 0


def test_checkpoint_restore_preserves_sampling_stream(all_params,
                                                      tmp_path):
    """temperature > 0: the restored engine rebuilds the same PRNG key
    from the saved seed, so the stochastic stream continues exactly."""
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size, n=2)
    kw = dict(slots=2, max_len=MAX_LEN, prefill_chunk=8,
              temperature=0.8, sample_seed=5)
    free = _uninterrupted_tokens(params, TINY, prompts, **kw)
    eng = ServeEngine(params, TINY, **kw)
    uids = [eng.submit(p, MAX_NEW) for p in prompts]
    for _ in range(4):
        eng.step()
    ck = str(tmp_path / "ckpt")
    eng.checkpoint(ck)
    restored = ServeEngine.restore(ck, params, TINY)
    restored.run_to_completion()
    for u, ref in zip(uids, free):
        assert restored.result(u) == ref


# ---------------------------------------------------------------------------
# chaos crash -> restore -> continue
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["attention", "a3", "rglru", "xlstm"])
def test_checkpoint_crash_mid_tick_continuation(all_params, tmp_path,
                                                kind):
    """Kill the engine mid-tick (EngineCrash at a pinned (seed, rate)
    schedule), restore from the last per-tick checkpoint, continue —
    the surviving process emits exactly the crash-free tokens."""
    cfg = {"attention": TINY, "a3": TINY, "rglru": TINY_RG,
           "xlstm": TINY_XL}[kind]
    a3 = A3Config.conservative() if kind == "a3" else A3Config()
    params = all_params[cfg.name]
    prompts = _prompts(cfg.vocab_size)
    kw = dict(slots=2, max_len=MAX_LEN, prefill_chunk=8, page_size=8,
              cache_pages=24, l2_bytes=1 << 22)
    free = _uninterrupted_tokens(params, cfg, prompts, a3=a3, **kw)

    chaos = ChaosInjector(ChaosConfig(seed=3, rate=0.3,
                                      corrupt_logits=False,
                                      fail_gather=False,
                                      raise_mid_tick=False,
                                      crash_mid_tick=True))
    eng = ServeEngine(params, cfg, a3=a3, chaos=chaos, **kw)
    uids = [eng.submit(p, MAX_NEW) for p in prompts]
    ck = str(tmp_path / "ckpt")
    eng.checkpoint(ck)
    crashes = 0
    while eng.in_flight > 0:
        try:
            eng.step()
            eng.checkpoint(ck)
        except EngineCrash:
            crashes += 1
            # the restarted process runs chaos-free (the faulty host
            # was replaced); state comes from the last durable commit
            eng = ServeEngine.restore(ck, params, cfg, a3=a3)
    assert crashes >= 1, "the pinned schedule must crash at least once"
    for u, ref in zip(uids, free):
        assert eng.status(u) == "finished"
        assert eng.result(u) == ref
    assert eng.stats["restores"] >= crashes


def test_checkpoint_crash_propagates_out_of_run_to_completion(
        all_params):
    """EngineCrash is NOT absorbed the way tick-abort ChaosError is:
    run_to_completion re-raises it (process death has no in-process
    recovery — the recovery story is restore())."""
    params = all_params["tiny"]
    chaos = ChaosInjector(ChaosConfig(seed=0, rate=1.0,
                                      corrupt_logits=False,
                                      fail_gather=False,
                                      raise_mid_tick=False,
                                      crash_mid_tick=True))
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8, chaos=chaos)
    eng.submit(_prompts(TINY.vocab_size, n=1)[0], 2)
    with pytest.raises(EngineCrash):
        eng.run_to_completion()


# ---------------------------------------------------------------------------
# torn / mismatched checkpoints fail loudly; .old fallback
# ---------------------------------------------------------------------------

def test_checkpoint_corruption_raises_never_resumes_wrong(all_params,
                                                          tmp_path):
    params = all_params["tiny"]
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8, page_size=8, cache_pages=16)
    eng.submit(_prompts(TINY.vocab_size, n=1)[0], MAX_NEW)
    eng.step()
    ck = str(tmp_path / "ckpt")
    eng.checkpoint(ck)

    # flipped byte in state.json -> checksum mismatch
    sp = os.path.join(ck, "state.json")
    raw = open(sp, "rb").read()
    open(sp, "wb").write(raw[:-2] + bytes([raw[-2] ^ 0xFF]) + raw[-1:])
    with pytest.raises(CheckpointError):
        ServeEngine.restore(ck, params, TINY)
    open(sp, "wb").write(raw)

    # truncated arrays.bin -> IntegrityError surfaced as CheckpointError
    ap = os.path.join(ck, "arrays.bin")
    araw = open(ap, "rb").read()
    open(ap, "wb").write(araw[:-7])
    with pytest.raises(CheckpointError):
        ServeEngine.restore(ck, params, TINY)
    open(ap, "wb").write(araw)

    # wrong model / wrong A^3 mode -> refused, not garbled
    other = ModelConfig("tiny2", "dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128,
                        vocab_size=256, head_dim=16, dtype="float32")
    with pytest.raises(CheckpointError):
        ServeEngine.restore(ck, params, other)
    with pytest.raises(CheckpointError):
        ServeEngine.restore(ck, params, TINY,
                            a3=A3Config.conservative())

    # missing directory entirely
    with pytest.raises(CheckpointError):
        ServeEngine.restore(str(tmp_path / "nowhere"), params, TINY)

    # intact checkpoint still restores after the round of vandalism
    ServeEngine.restore(ck, params, TINY).run_to_completion()


def test_checkpoint_interrupted_commit_falls_back_to_old(all_params,
                                                         tmp_path):
    """A crash between the two commit renames leaves only ``.old`` —
    restore must pick up the previous complete checkpoint."""
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size, n=2)
    kw = dict(slots=1, max_len=MAX_LEN, prefill_chunk=8)
    free = _uninterrupted_tokens(params, TINY, prompts, **kw)
    eng = ServeEngine(params, TINY, **kw)
    uids = [eng.submit(p, MAX_NEW) for p in prompts]
    eng.step()
    ck = str(tmp_path / "ckpt")
    eng.checkpoint(ck)
    # simulate the torn window: the old checkpoint was shuffled aside
    # and the process died before the new one was renamed into place
    os.rename(ck, ck + ".old")
    restored = ServeEngine.restore(ck, params, TINY)
    restored.run_to_completion()
    for u, ref in zip(uids, free):
        assert restored.result(u) == ref


# ---------------------------------------------------------------------------
# bookkeeping round trip
# ---------------------------------------------------------------------------

def test_checkpoint_preserves_statuses_queue_results_and_l2(all_params,
                                                            tmp_path):
    params = all_params["tiny"]
    prompts = _prompts(TINY.vocab_size, n=4)
    eng = ServeEngine(params, TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8, page_size=8, cache_pages=24,
                      l2_bytes=1 << 22)
    u_done = eng.submit(prompts[0], 3)
    eng.run_to_completion()
    done_toks = eng.result(u_done)
    u_cancel = eng.submit(prompts[1], 3)
    assert eng.cancel(u_cancel)
    eng._pc.spill(10 ** 6)              # park blobs in L2
    assert len(eng._pc.l2) > 0
    # the in-flight pair must NOT share the spilled prefix, or admission
    # would promote the blobs back out of L2 before the checkpoint
    fresh = _prompts(TINY.vocab_size, seed=11, n=2)
    u_a, u_b = eng.submit(fresh[0], 3), eng.submit(fresh[1], 3)
    eng.step()
    n_blobs = len(eng._pc.l2)           # measured AT checkpoint time
    assert n_blobs > 0

    ck = str(tmp_path / "ckpt")
    eng.checkpoint(ck)
    restored = ServeEngine.restore(ck, params, TINY)
    # terminal bookkeeping survives
    assert restored.status(u_done) == "finished"
    assert restored.result(u_done) == done_toks
    assert restored.status(u_cancel) == "cancelled"
    # in-flight set survives (one on the slot, one queued)
    assert restored.in_flight == eng.in_flight == 2
    assert restored.status(u_a) == eng.status(u_a)
    assert restored.status(u_b) == eng.status(u_b)
    # L2 blobs survive byte-for-byte (they carry their own checksums)
    assert len(restored._pc.l2) == n_blobs
    assert dict(restored._pc.l2.raw_items()) == dict(eng._pc.l2.raw_items())
    # conservation identity holds on the restored engine
    s = restored.stats
    assert s["submitted"] == (s["finished"] + s["rejected"]
                              + s["cancelled"] + s["expired"]
                              + s["failed"] + restored.in_flight)
    restored.run_to_completion()
    assert restored.status(u_a) == restored.status(u_b) == "finished"
