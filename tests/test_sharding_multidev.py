"""Multi-device tests (subprocess with 8 host devices): sharding rules
produce valid layouts, the sharded train step runs and matches the
single-device result, int8 gradient compression converges, and the
pipeline-parallel schedule is exact.
"""
from __future__ import annotations

import pytest

from tests.helpers import check, run_with_devices


def test_param_specs_valid_and_sharded():
    out = check(run_with_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.config import ShardingConfig, get_arch, smoke_variant
from repro.launch.mesh import make_mesh
from repro.models import decoder
from repro.sharding.rules import param_specs, shardings_for

mesh = make_mesh((2, 4), ("data", "model"))
for arch in ["internlm2-1.8b", "deepseek-moe-16b", "recurrentgemma-2b",
             "xlstm-350m"]:
    cfg = get_arch(arch)
    shapes = decoder.init_params_shape(cfg)
    specs = shardings_for(param_specs(shapes, ShardingConfig(), mesh), mesh)
    n_sharded = 0
    for (path, s), (_, shp) in zip(
            jax.tree_util.tree_flatten_with_path(specs)[0],
            jax.tree_util.tree_flatten_with_path(shapes)[0]):
        assert isinstance(s, NamedSharding)
        # every spec must be shard-compatible with its array
        for dim, ax in zip(shp.shape, s.spec + (None,) * 10):
            if ax is not None:
                sz = mesh.shape[ax] if isinstance(ax, str) else 1
                assert dim % sz == 0, (path, shp.shape, s.spec)
        if any(a is not None for a in s.spec):
            n_sharded += 1
    assert n_sharded > 4, arch
print("OK")
"""))
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    out = check(run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.config import (ModelConfig, OptimizerConfig, RunConfig,
                          ShapeConfig, ShapeKind, ShardingConfig)
from repro.launch.mesh import make_mesh
from repro.train.step import init_train_state, make_train_step
from repro.data.synthetic import make_lm_batch

cfg = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
                  dtype="float32")
shape = ShapeConfig("t", ShapeKind.TRAIN, seq_len=64, global_batch=8)
run = RunConfig(model=cfg, shape=shape,
                optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1),
                sharding=ShardingConfig(remat="none"))
batch = {k: jnp.asarray(v) for k, v in
         make_lm_batch(0, 8, 64, 256).items()}

state1 = init_train_state(jax.random.PRNGKey(0), run)
step1 = make_train_step(run, None, donate=False)
_, m1 = step1(state1, batch)

mesh = make_mesh((2, 4), ("data", "model"))
state2 = init_train_state(jax.random.PRNGKey(0), run)
step2 = make_train_step(run, mesh, donate=False)
_, m2 = step2(state2, batch)
np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                           rtol=2e-5)
print("OK", float(m1["loss"]), float(m2["loss"]))
"""))
    assert "OK" in out


def test_grad_compression_psum():
    out = check(run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.launch.mesh import make_mesh
from repro.sharding.compression import psum_compressed

mesh = make_mesh((8,), ("pod",))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))  # per-pod grads

@partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
         check_rep=False)
def reduce_once(gs):
    mean, err = psum_compressed({"g": gs[0]}, "pod")
    return (mean["g"] + err["g"] * 0)[None]

out = reduce_once(g)
true_mean = jnp.mean(g, axis=0)
# int8 quantization error bounded by scale = max|g|/127
bound = float(jnp.max(jnp.abs(g))) / 127 + 1e-6
err = float(jnp.max(jnp.abs(out[0] - true_mean)))
assert err <= bound, (err, bound)

# error feedback: averaging the SAME gradient repeatedly converges
est, err_state = None, None
gs = {"g": None}
@partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
         out_specs=(P("pod"), P("pod")), check_rep=False)
def step(gs, errs):
    mean, new_err = psum_compressed({"g": gs[0]}, "pod",
                                    {"g": errs[0]})
    return mean["g"][None], new_err["g"][None]

errs = jnp.zeros_like(g)
means = []
for _ in range(8):
    mean, errs = step(g, errs)
    means.append(mean[0])
avg = jnp.mean(jnp.stack(means), axis=0)
err2 = float(jnp.max(jnp.abs(avg - true_mean)))
assert err2 < err * 0.7, (err2, err)  # feedback reduces bias
print("OK", err, err2)
"""))
    assert "OK" in out


def test_grad_compression_quant_error_drains():
    """Error-feedback drain property: on a CONSTANT gradient stream the
    residual never accumulates — after T steps the summed emitted means
    differ from T x the true mean by at most the residual itself (the
    telescoping identity sum(out_t) = T*g + e_0 - e_T), and |e_T| stays
    under half a quantization step. This pins the amax-AFTER-feedback
    ordering in psum_compressed: computing the scale from g alone would
    let feedback larger than the grid clip and re-enter the residual
    every step instead of draining."""
    out = check(run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.launch.mesh import make_mesh
from repro.sharding.compression import psum_compressed

mesh = make_mesh((8,), ("pod",))
g = jax.random.normal(jax.random.PRNGKey(3), (8, 64)) * 5.0
true_mean = jnp.mean(g, axis=0)

@partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
         out_specs=(P("pod"), P("pod")), check_rep=False)
def step(gs, errs):
    mean, new_err = psum_compressed({"g": gs[0]}, "pod", {"g": errs[0]})
    return mean["g"][None], new_err["g"][None]

T = 32
errs = jnp.zeros_like(g)
acc = jnp.zeros_like(true_mean)
step_bound = float(jnp.max(jnp.abs(g))) / 127 / 2  # half a quant step
for t in range(T):
    mean, errs = step(g, errs)
    acc = acc + mean[0]
    # the residual drains: bounded by half a step at EVERY t, with
    # feedback folded before amax the scale always covers g + e
    e_norm = float(jnp.max(jnp.abs(errs)))
    assert e_norm <= 2.1 * step_bound + 1e-6, (t, e_norm, step_bound)
# telescoping: cumulative bias is the (bounded) final residual, not
# O(T) — the average converges to the true mean at rate 1/T
drift = float(jnp.max(jnp.abs(acc / T - true_mean)))
assert drift <= (2.1 * step_bound + 1e-6) / T + 1e-6, (drift, step_bound)
print("OK", drift, step_bound)
"""))
    assert "OK" in out


def test_pipeline_schedule_exact():
    out = check(run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.sharding.pipeline import pipeline_forward

mesh = make_mesh((4,), ("pipe",))
P_st, M, mb, S, D = 4, 8, 2, 4, 16
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (P_st, D, D)) * 0.3

def stage_fn(params, x):
    return jnp.tanh(x @ params["w"])

x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, S, D))
out = pipeline_forward(stage_fn, {"w": w}, x, mesh, axis="pipe")

# reference: apply the 4 stages in order
ref = x
for i in range(P_st):
    ref = jnp.tanh(ref @ w[i])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           atol=1e-5, rtol=1e-4)
print("OK")
""", devices=4))
    assert "OK" in out


def test_elastic_checkpoint_restore_other_mesh():
    out = check(run_with_devices("""
import tempfile, jax, jax.numpy as jnp, numpy as np
from repro.config import CheckpointConfig, ShardingConfig
from repro.launch.mesh import make_mesh
from repro.train.checkpoint import CheckpointManager
from repro.sharding.rules import param_specs, shardings_for

state = {"w": jnp.arange(64.0).reshape(8, 8)}
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(CheckpointConfig(directory=d, async_save=False))
    # save from a (4, 2) mesh layout
    mesh1 = make_mesh((4, 2), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    s1 = jax.device_put(state["w"], NamedSharding(mesh1, P("data", "model")))
    mgr.save(1, {"w": s1})
    # restore onto a (2, 4) mesh -- elastic resharding
    mesh2 = make_mesh((2, 4), ("data", "model"))
    tgt = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    shd = {"w": NamedSharding(mesh2, P("model", "data"))}
    restored, _ = mgr.restore(tgt, shardings=shd)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding.mesh.shape["model"] == 2 or True
print("OK")
"""))
    assert "OK" in out
