"""Per-kernel validation: shape/dtype sweeps vs pure-jnp oracles.

All Pallas kernels run in interpret mode (CPU) and must match their ref.py
to tight tolerances in f32 and loose tolerances in bf16.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import A3Config, A3Mode
from repro.kernels.a3_attention.kernel import a3_sparse_attention, build_block_map
from repro.kernels.a3_attention.ops import a3_attention, candidate_block_map_for_heads
from repro.kernels.a3_attention.ref import a3_sparse_attention_ref
from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def _qkv(rng, b, hq, hkv, sq, sk, d, dv, dtype):
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dtype=dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, sk, dv)), dtype=dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,dv", [
    (1, 1, 1, 128, 128, 64, 64),
    (2, 4, 2, 256, 256, 64, 64),
    (1, 8, 1, 128, 384, 32, 32),     # MQA + prefill-continuation offset
    (1, 2, 2, 256, 256, 128, 64),    # dv != d
])
def test_flash_attention_sweep(b, hq, hkv, sq, sk, d, dv, dtype):
    rng = np.random.default_rng(hash((b, hq, sk, d)) % 2**31)
    q, k, v = _qkv(rng, b, hq, hkv, sq, sk, d, dv, dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [64, 128, 1024])
def test_flash_attention_window(window):
    rng = np.random.default_rng(window)
    q, k, v = _qkv(rng, 1, 2, 2, 256, 256, 32, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    sq_blocks=st.integers(1, 3),
    sk_extra=st.integers(0, 2),
    hkv=st.sampled_from([1, 2]),
    causal=st.booleans(),
)
def test_flash_attention_property(sq_blocks, sk_extra, hkv, causal):
    rng = np.random.default_rng(42)
    sq = 128 * sq_blocks
    sk = sq + 128 * sk_extra
    q, k, v = _qkv(rng, 1, 2 * hkv, hkv, sq, sk, 32, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# a3_sparse_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("threshold", [None, 1.0, 3.0])
@pytest.mark.parametrize("density", [0.25, 0.75, 1.0])
def test_a3_sparse_sweep(dtype, threshold, density):
    rng = np.random.default_rng(int(density * 100) + (0 if threshold is None
                                                      else int(threshold)))
    b, hq, hkv, s, d = 1, 2, 1, 512, 32
    q, k, v = _qkv(rng, b, hq, hkv, s, s, d, d, dtype)
    nq = nk = s // 128
    bm = jnp.asarray(rng.random((b, hq, nq, nk)) < density)
    # every q block keeps its diagonal block so no row is fully masked
    eye = jnp.eye(nq, nk, dtype=bool)[None, None]
    bm = bm | eye
    idx, cnt = build_block_map(bm)
    out = a3_sparse_attention(q, k, v, idx, cnt, threshold=threshold,
                              causal=True, interpret=True)
    ref = a3_sparse_attention_ref(q, k, v, idx, cnt, threshold=threshold,
                                  causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


def test_a3_sparse_full_map_equals_flash():
    """With every block live and no threshold, the sparse kernel must equal
    dense flash attention."""
    rng = np.random.default_rng(7)
    q, k, v = _qkv(rng, 1, 2, 2, 256, 256, 32, 32, jnp.float32)
    bm = jnp.ones((1, 2, 2, 2), dtype=bool)
    idx, cnt = build_block_map(bm)
    out = a3_sparse_attention(q, k, v, idx, cnt, threshold=None,
                              causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_a3_attention_end_to_end_close_to_exact():
    """Full pipeline (selection -> block map -> sparse kernel) approximates
    exact attention on retrieval-style data."""
    rng = np.random.default_rng(8)
    b, h, s, d = 1, 2, 256, 32
    q, k, v = _qkv(rng, b, h, h, s, s, d, d, jnp.float32)
    cfg = A3Config(mode=A3Mode.CUSTOM, m_fraction=0.5, threshold_pct=1.0)
    approx = a3_attention(q, k, v, cfg, causal=True, use_kernel=True,
                          interpret=True)
    exact = attention_ref(q, k, v, causal=True)
    rel = (np.linalg.norm(np.asarray(approx) - np.asarray(exact)) /
           np.linalg.norm(np.asarray(exact)))
    assert rel < 0.25, rel
    # kernel and ref paths agree on identical masks
    ref_path = a3_attention(q, k, v, cfg, causal=True, use_kernel=False)
    np.testing.assert_allclose(np.asarray(approx), np.asarray(ref_path),
                               rtol=2e-4, atol=2e-4)


def test_block_map_roundtrip():
    rng = np.random.default_rng(9)
    bm = jnp.asarray(rng.random((2, 3, 4, 8)) < 0.5)
    idx, cnt = build_block_map(bm)
    assert idx.shape == (2, 3, 4, 8)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(bm.sum(-1)))
    # reconstruct and compare
    rec = np.zeros(bm.shape, dtype=bool)
    idx_n, cnt_n = np.asarray(idx), np.asarray(cnt)
    for b in range(2):
        for h in range(3):
            for qb in range(4):
                rec[b, h, qb, idx_n[b, h, qb, :cnt_n[b, h, qb]]] = True
    np.testing.assert_array_equal(rec, np.asarray(bm))


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,s,d,block_k", [
    (1, 4, 1, 512, 64, 256),
    (2, 8, 2, 1024, 64, 512),
    (1, 16, 16, 256, 32, 128),      # MHA
    (4, 8, 4, 2048, 128, 512),
])
def test_decode_attention_sweep(b, hq, hkv, s, d, block_k, dtype):
    rng = np.random.default_rng(hash((b, hq, s)) % 2**31)
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dtype=dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype=dtype)
    mask = jnp.asarray(rng.random((b, hq, s)) < 0.6)
    mask = mask.at[..., 0].set(True)
    out = decode_attention(q, k, v, mask, threshold=2.0, block_k=block_k,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, mask, threshold=2.0)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_attention_empty_mask_row_is_zero():
    rng = np.random.default_rng(10)
    q = jnp.asarray(rng.standard_normal((1, 2, 32)), dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 128, 32)), dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 128, 32)), dtype=jnp.float32)
    mask = jnp.zeros((1, 2, 128), dtype=bool).at[0, 1].set(True)
    out = decode_attention(q, k, v, mask, interpret=True, block_k=128)
    assert float(jnp.abs(out[0, 0]).max()) == 0.0
    assert float(jnp.abs(out[0, 1]).max()) > 0.0
