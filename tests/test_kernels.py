"""Per-kernel validation: shape/dtype sweeps vs pure-jnp oracles.

All Pallas kernels run in interpret mode (CPU) and must match their ref.py
to tight tolerances in f32 and loose tolerances in bf16.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.config import A3Config, A3Mode
from repro.kernels.a3_attention.kernel import a3_sparse_attention, build_block_map
from repro.kernels.a3_attention.ops import a3_attention, candidate_block_map_for_heads
from repro.kernels.a3_attention.ref import a3_sparse_attention_ref
from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def _qkv(rng, b, hq, hkv, sq, sk, d, dv, dtype):
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dtype=dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, sk, dv)), dtype=dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,dv", [
    (1, 1, 1, 128, 128, 64, 64),
    (2, 4, 2, 256, 256, 64, 64),
    (1, 8, 1, 128, 384, 32, 32),     # MQA + prefill-continuation offset
    (1, 2, 2, 256, 256, 128, 64),    # dv != d
])
def test_flash_attention_sweep(b, hq, hkv, sq, sk, d, dv, dtype):
    rng = np.random.default_rng(hash((b, hq, sk, d)) % 2**31)
    q, k, v = _qkv(rng, b, hq, hkv, sq, sk, d, dv, dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [64, 128, 1024])
def test_flash_attention_window(window):
    rng = np.random.default_rng(window)
    q, k, v = _qkv(rng, 1, 2, 2, 256, 256, 32, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    sq_blocks=st.integers(1, 3),
    sk_extra=st.integers(0, 2),
    hkv=st.sampled_from([1, 2]),
    causal=st.booleans(),
)
def test_flash_attention_property(sq_blocks, sk_extra, hkv, causal):
    rng = np.random.default_rng(42)
    sq = 128 * sq_blocks
    sk = sq + 128 * sk_extra
    q, k, v = _qkv(rng, 1, 2 * hkv, hkv, sq, sk, 32, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# a3_sparse_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("threshold", [None, 1.0, 3.0])
@pytest.mark.parametrize("density", [0.25, 0.75, 1.0])
def test_a3_sparse_sweep(dtype, threshold, density):
    rng = np.random.default_rng(int(density * 100) + (0 if threshold is None
                                                      else int(threshold)))
    b, hq, hkv, s, d = 1, 2, 1, 512, 32
    q, k, v = _qkv(rng, b, hq, hkv, s, s, d, d, dtype)
    nq = nk = s // 128
    bm = jnp.asarray(rng.random((b, hq, nq, nk)) < density)
    # every q block keeps its diagonal block so no row is fully masked
    eye = jnp.eye(nq, nk, dtype=bool)[None, None]
    bm = bm | eye
    idx, cnt = build_block_map(bm)
    out = a3_sparse_attention(q, k, v, idx, cnt, threshold=threshold,
                              causal=True, interpret=True)
    ref = a3_sparse_attention_ref(q, k, v, idx, cnt, threshold=threshold,
                                  causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("group", [2, 4])
@pytest.mark.parametrize("threshold", [None, 2.0])
def test_a3_sparse_gqa_folded_matches_ref(group, threshold):
    """GQA-folded kernel (grid over kv heads, group in the q block) ==
    dense reference, for both per-kv-head and per-query-head (auto-
    unioned) candidate maps."""
    from repro.kernels.a3_attention.kernel import union_block_map_gqa
    rng = np.random.default_rng(group * 10 + (0 if threshold is None
                                              else int(threshold)))
    b, hkv, s, d = 2, 2, 256, 32
    hq = hkv * group
    q, k, v = _qkv(rng, b, hq, hkv, s, s, d, d, jnp.float32)
    nq = nk = s // 128
    # per-query-head random maps with the diagonal kept live
    bm_hq = jnp.asarray(rng.random((b, hq, nq, nk)) < 0.5)
    bm_hq = bm_hq | jnp.eye(nq, nk, dtype=bool)[None, None]
    idx_hq, cnt_hq = build_block_map(bm_hq)
    out_hq = a3_sparse_attention(q, k, v, idx_hq, cnt_hq,
                                 threshold=threshold, causal=True,
                                 interpret=True)
    ref_hq = a3_sparse_attention_ref(q, k, v, idx_hq, cnt_hq,
                                     threshold=threshold, causal=True)
    np.testing.assert_allclose(np.asarray(out_hq), np.asarray(ref_hq),
                               rtol=2e-5, atol=2e-5)
    # explicitly pre-unioned per-kv-head maps give the identical result
    idx_kv, cnt_kv = union_block_map_gqa(idx_hq, cnt_hq, group, nk)
    assert idx_kv.shape[1] == hkv and cnt_kv.shape[1] == hkv
    out_kv = a3_sparse_attention(q, k, v, idx_kv, cnt_kv,
                                 threshold=threshold, causal=True,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out_kv), np.asarray(out_hq),
                               rtol=2e-6, atol=2e-6)


def test_a3_sparse_gqa_full_map_equals_flash():
    """With every block live, the folded GQA kernel equals dense flash
    attention (union changes nothing when maps are already full)."""
    rng = np.random.default_rng(11)
    q, k, v = _qkv(rng, 1, 4, 2, 256, 256, 32, 32, jnp.float32)
    bm = jnp.ones((1, 2, 2, 2), dtype=bool)          # per-kv-head map
    idx, cnt = build_block_map(bm)
    out = a3_sparse_attention(q, k, v, idx, cnt, threshold=None,
                              causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_a3_sparse_full_map_equals_flash():
    """With every block live and no threshold, the sparse kernel must equal
    dense flash attention."""
    rng = np.random.default_rng(7)
    q, k, v = _qkv(rng, 1, 2, 2, 256, 256, 32, 32, jnp.float32)
    bm = jnp.ones((1, 2, 2, 2), dtype=bool)
    idx, cnt = build_block_map(bm)
    out = a3_sparse_attention(q, k, v, idx, cnt, threshold=None,
                              causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_a3_attention_end_to_end_close_to_exact():
    """Full pipeline (selection -> block map -> sparse kernel) approximates
    exact attention on retrieval-style data."""
    rng = np.random.default_rng(8)
    b, h, s, d = 1, 2, 256, 32
    q, k, v = _qkv(rng, b, h, h, s, s, d, d, jnp.float32)
    cfg = A3Config(mode=A3Mode.CUSTOM, m_fraction=0.5, threshold_pct=1.0)
    approx = a3_attention(q, k, v, cfg, causal=True, use_kernel=True,
                          interpret=True)
    exact = attention_ref(q, k, v, causal=True)
    rel = (np.linalg.norm(np.asarray(approx) - np.asarray(exact)) /
           np.linalg.norm(np.asarray(exact)))
    assert rel < 0.25, rel
    # kernel and ref paths agree on identical masks
    ref_path = a3_attention(q, k, v, cfg, causal=True, use_kernel=False)
    np.testing.assert_allclose(np.asarray(approx), np.asarray(ref_path),
                               rtol=2e-4, atol=2e-4)


def test_block_map_roundtrip():
    rng = np.random.default_rng(9)
    bm = jnp.asarray(rng.random((2, 3, 4, 8)) < 0.5)
    idx, cnt = build_block_map(bm)
    assert idx.shape == (2, 3, 4, 8)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(bm.sum(-1)))
    # reconstruct and compare
    rec = np.zeros(bm.shape, dtype=bool)
    idx_n, cnt_n = np.asarray(idx), np.asarray(cnt)
    for b in range(2):
        for h in range(3):
            for qb in range(4):
                rec[b, h, qb, idx_n[b, h, qb, :cnt_n[b, h, qb]]] = True
    np.testing.assert_array_equal(rec, np.asarray(bm))


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

def _decode_inputs(rng, b, hq, hkv, s, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dtype=dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype=dtype)
    mask = jnp.asarray(rng.random((b, hq, s)) < 0.6)
    mask = mask.at[..., 0].set(True)
    return q, k, v, mask


DECODE_SHAPES = [
    (1, 4, 1, 512, 64, 256),
    (2, 8, 2, 1024, 64, 512),
    (1, 16, 16, 256, 32, 128),      # MHA
    (4, 8, 4, 2048, 128, 512),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,s,d,block_k", DECODE_SHAPES)
def test_decode_attention_two_pass_sweep(b, hq, hkv, s, d, block_k, dtype):
    """exact_two_pass=True reproduces the literal SSIV-D threshold."""
    rng = np.random.default_rng(hash((b, hq, s)) % 2**31)
    q, k, v, mask = _decode_inputs(rng, b, hq, hkv, s, d, dtype)
    out = decode_attention(q, k, v, mask, threshold=2.0, block_k=block_k,
                           interpret=True, exact_two_pass=True)
    ref = decode_attention_ref(q, k, v, mask, threshold=2.0)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,s,d,block_k", DECODE_SHAPES)
def test_decode_attention_fused_no_threshold_exact(b, hq, hkv, s, d,
                                                   block_k, dtype):
    """The fused single-pass kernel is exact when no threshold is set."""
    rng = np.random.default_rng(hash((b, hq, s)) % 2**31)
    q, k, v, mask = _decode_inputs(rng, b, hq, hkv, s, d, dtype)
    out = decode_attention(q, k, v, mask, threshold=None, block_k=block_k,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, mask, threshold=None)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


def _fused_threshold_ref(q, k, v, mask, threshold, block_k):
    """jnp simulation of the fused kernel's running-max threshold
    semantics: blocks stream in order, each tested against the max seen
    so far — the documented superset relaxation of SSIV-D."""
    b, hq, d = q.shape
    _, hkv, s, dv = v.shape
    group = hq // hkv
    scale = d ** -0.5
    kq = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vq = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    sc = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kq) * scale
    sc = jnp.where(mask, sc, -jnp.inf)
    nb = s // block_k
    blocks = sc.reshape(b, hq, nb, block_k)
    run_max = jax.lax.cummax(jnp.max(blocks, axis=-1), axis=2)  # [B,H,nb]
    keep = mask.reshape(b, hq, nb, block_k) & \
        (blocks >= run_max[..., None] - threshold)
    keep = keep.reshape(b, hq, s)
    m = jnp.max(sc, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(keep, jnp.exp(sc - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    w = p / jnp.maximum(l, 1e-30)
    return jnp.einsum("bhk,bhkd->bhd", w, vq).astype(q.dtype), keep


@pytest.mark.parametrize("b,hq,hkv,s,d,block_k", DECODE_SHAPES[:2])
def test_decode_attention_fused_threshold_semantics(b, hq, hkv, s, d,
                                                    block_k):
    """Fused threshold path == the running-max simulation, keeps a
    superset of the exact-threshold entries, and its output delta vs the
    exact pass is bounded by the relaxation band's weight mass."""
    rng = np.random.default_rng(hash((b, s)) % 2**31)
    thr = 2.0
    q, k, v, mask = _decode_inputs(rng, b, hq, hkv, s, d, jnp.float32)
    out = decode_attention(q, k, v, mask, threshold=thr, block_k=block_k,
                           interpret=True)
    sim, keep_relaxed = _fused_threshold_ref(q, k, v, mask, thr, block_k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(sim),
                               rtol=2e-5, atol=2e-5)

    # superset property: every entry the exact pass keeps is kept
    group = hq // hkv
    kq = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    sc = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kq) * d ** -0.5
    sc = jnp.where(mask, sc, -jnp.inf)
    m = jnp.max(sc, axis=-1, keepdims=True)
    keep_exact = mask & (sc >= m - thr)
    assert bool(jnp.all(keep_relaxed | ~keep_exact))

    # bounded delta: extra entries each carry relative weight < exp(-thr),
    # so ||fused - exact||_inf <= extra_mass / (exact_mass) * 2 * |v|_max
    exact = decode_attention_ref(q, k, v, mask, threshold=thr)
    p = jnp.exp(sc - m)
    extra = jnp.sum(jnp.where(keep_relaxed & ~keep_exact, p, 0.0), -1)
    base = jnp.sum(jnp.where(keep_exact, p, 0.0), -1)
    bound = (2.0 * extra / base)[..., None] * float(jnp.abs(v).max())
    delta = jnp.abs(out.astype(jnp.float32) - exact.astype(jnp.float32))
    assert bool(jnp.all(delta <= bound + 1e-5))


# ---------------------------------------------------------------------------
# cross-family conformance sweep: every kernel family vs its oracle over
# GQA head ratios and odd (non-block-aligned) sequence lengths
# ---------------------------------------------------------------------------

def _mlstm_gates(rng, b, h, s):
    li = jnp.asarray(rng.standard_normal((b, h, s)) - 0.5, jnp.float32)
    lf = jnp.asarray(jax.nn.log_sigmoid(
        jnp.asarray(rng.standard_normal((b, h, s)) + 1.0)), jnp.float32)
    return li, lf


FAMILY_SWEEP = [
    # (hq, hkv, s): GQA ratios 1/2/4/8 x odd + non-128-multiple lengths
    (8, 8, 64),      # MHA, small
    (4, 2, 96),      # GQA 2, non-block-multiple
    (4, 1, 97),      # MQA, genuinely odd length
    (8, 2, 160),     # GQA 4, non-128-multiple
    (8, 1, 33),      # MQA 8, odd
]


@pytest.mark.parametrize("family", ["flash", "a3", "decode", "mlstm_chunk"])
@pytest.mark.parametrize("hq,hkv,s", FAMILY_SWEEP)
def test_kernel_family_matches_ref(family, hq, hkv, s):
    """One conformance contract for all four kernel families: the Pallas
    kernel (interpret mode) equals its pure-jnp oracle at every GQA
    ratio and at sequence lengths that do not align with the default
    block sizes (the kernels clamp their blocks to the sequence)."""
    import zlib
    # crc32, not hash(): string hashing is salted per process, and the
    # test data must be reproducible across CI runs
    rng = np.random.default_rng(
        zlib.crc32(f"{family}:{hq}:{hkv}:{s}".encode()) % 2**31)
    d = 32
    tol = dict(rtol=3e-5, atol=3e-5)
    if family == "flash":
        q, k, v = _qkv(rng, 1, hq, hkv, s, s, d, d, jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=256,
                              block_k=256, interpret=True)
        ref = attention_ref(q, k, v, causal=True)
    elif family == "a3":
        q, k, v = _qkv(rng, 1, hq, hkv, s, s, d, d, jnp.float32)
        bm = jnp.ones((1, hkv, 1, 1), dtype=bool)   # whole-seq block pair
        idx, cnt = build_block_map(bm)
        out = a3_sparse_attention(q, k, v, idx, cnt, threshold=2.0,
                                  causal=True, block_q=256, block_k=256,
                                  interpret=True)
        ref = a3_sparse_attention_ref(q, k, v, idx, cnt, threshold=2.0,
                                      causal=True, block_q=256,
                                      block_k=256)
    elif family == "decode":
        q, k, v, mask = _decode_inputs(rng, 2, hq, hkv, s, d, jnp.float32)
        out = decode_attention(q, k, v, mask, threshold=None, block_k=512,
                               interpret=True)
        ref = decode_attention_ref(q, k, v, mask, threshold=None)
    else:                                           # mlstm_chunk
        from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_kernel
        from repro.kernels.mlstm_chunk.ref import mlstm_chunk_ref
        h = hq                                      # no GQA in mLSTM
        q = jnp.asarray(rng.standard_normal((1, h, s, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, h, s, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, h, s, d)), jnp.float32)
        li, lf = _mlstm_gates(rng, 1, h, s)
        out = mlstm_chunk_kernel(q, k, v, li, lf, chunk=512,
                                 scale=d ** -0.5, interpret=True)
        ref = mlstm_chunk_ref(q, k, v, li, lf, scale=d ** -0.5)
        tol = dict(rtol=2e-4, atol=2e-4)            # sequential vs chunked
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


@pytest.mark.parametrize("b,hq,hkv,s,d,block_k", DECODE_SHAPES[:2])
def test_decode_fused_vs_exact_two_pass_bounded_delta(b, hq, hkv, s, d,
                                                      block_k):
    """Kernel-vs-kernel: the fused single-pass path (running-max
    threshold relaxation) deviates from the exact two-pass kernel by at
    most the softmax mass of the relaxation band — every extra entry the
    fused pass keeps carries relative weight < exp(-t)."""
    rng = np.random.default_rng(hash((b, s, d)) % 2**31)
    thr = 2.0
    q, k, v, mask = _decode_inputs(rng, b, hq, hkv, s, d, jnp.float32)
    fused = decode_attention(q, k, v, mask, threshold=thr, block_k=block_k,
                             interpret=True, exact_two_pass=False)
    two_pass = decode_attention(q, k, v, mask, threshold=thr,
                                block_k=block_k, interpret=True,
                                exact_two_pass=True)
    _, keep_relaxed = _fused_threshold_ref(q, k, v, mask, thr, block_k)
    group = hq // hkv
    kq = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    sc = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kq) * d ** -0.5
    sc = jnp.where(mask, sc, -jnp.inf)
    m = jnp.max(sc, axis=-1, keepdims=True)
    keep_exact = mask & (sc >= m - thr)
    p = jnp.exp(sc - m)
    extra = jnp.sum(jnp.where(keep_relaxed & ~keep_exact, p, 0.0), -1)
    base = jnp.sum(jnp.where(keep_exact, p, 0.0), -1)
    bound = (2.0 * extra / base)[..., None] * float(jnp.abs(v).max())
    delta = jnp.abs(fused.astype(jnp.float32) - two_pass.astype(jnp.float32))
    assert bool(jnp.all(delta <= bound + 1e-5))
    # and the band mass itself is small: relative extra weight < exp(-t)
    # per entry means the total deviation shrinks as t grows
    loose = decode_attention(q, k, v, mask, threshold=8.0, block_k=block_k,
                             interpret=True, exact_two_pass=False)
    loose2 = decode_attention(q, k, v, mask, threshold=8.0, block_k=block_k,
                              interpret=True, exact_two_pass=True)
    tight_delta = float(jnp.abs(loose - loose2).max())
    assert tight_delta <= float(delta.max()) + 1e-5


def test_decode_attention_empty_mask_row_is_zero():
    rng = np.random.default_rng(10)
    q = jnp.asarray(rng.standard_normal((1, 2, 32)), dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 128, 32)), dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 128, 32)), dtype=jnp.float32)
    mask = jnp.zeros((1, 2, 128), dtype=bool).at[0, 1].set(True)
    for two_pass in (False, True):
        out = decode_attention(q, k, v, mask, interpret=True, block_k=128,
                               exact_two_pass=two_pass)
        assert float(jnp.abs(out[0, 0]).max()) == 0.0
        assert float(jnp.abs(out[0, 1]).max()) > 0.0
