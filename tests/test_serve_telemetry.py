"""Telemetry-plane conformance suite.

The serving telemetry plane (``serve/telemetry.py``) promises to be a
pure observer: with ``telemetry=True`` the engine emits metrics,
request-lifecycle trace spans, and in-graph A^3 quality probes, and

* token streams are bit-identical to the untelemetered engine across
  every mixer kind it serves (attention, A^3, RG-LRU hybrid, xLSTM),
* the deterministic scheduling counters — including ``host_syncs``,
  the zero-new-syncs contract (probe arrays ride the already-landing
  deferred ring drain) — are identical,
* what the plane reports reconciles with the engine's own counters:
  TTFT observations match terminal counts, per-request attributed
  decode steps match ``decode_steps_advanced``, probed dispatches
  match ``ceil(decode_dispatches / telemetry_every)``,
* the Chrome-trace export round-trips through ``json`` and per-slot
  timelines are monotone,
* and histogram state survives the engine checkpoint/restore cycle.

Pure-host unit tests for the registry/histogram/tracer primitives run
first; they need no device dispatch at all.
"""
from __future__ import annotations

import json
import math

import numpy as np
import pytest

import jax

from repro.config import A3Config, ModelConfig, ServeConfig
from repro.models import decoder as dec
from repro.serve.engine import ServeEngine
from repro.serve.telemetry import (Histogram, MetricsRegistry, Tracer,
                                   _COUNT_BUCKET_BOUNDS)

from test_serve_pipeline import TINY, TINY_RG, TINY_XL, KINDS  # noqa: F401

MAX_LEN = 96
MAX_NEW = 6
PROMPT_LENS = (5, 12, 23, 9)

# Wall-clock-derived stats: these differ between ANY two runs (they
# time real host/device work), telemetry or not, so the bit-identity
# comparisons exclude them. Everything else must match exactly.
WALL_STATS = ("tick_ns_prefill", "tick_ns_decode", "tick_ns_harvest",
              "tick_ns_host", "host_sync_stalls")


@pytest.fixture(scope="module")
def all_params():
    return {
        "tiny": dec.init_params(jax.random.PRNGKey(0), TINY),
        "tiny-rg": dec.init_params(jax.random.PRNGKey(1), TINY_RG),
        "tiny-xl": dec.init_params(jax.random.PRNGKey(2), TINY_XL),
    }


def _prompts(vocab, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n) for n in PROMPT_LENS]


def _det_stats(eng):
    return {k: v for k, v in eng.stats.items() if k not in WALL_STATS}


def _run(params, cfg, prompts, *, a3=A3Config(), telemetry=False,
         max_new=MAX_NEW, **kw):
    eng = ServeEngine(params, cfg, slots=2, max_len=MAX_LEN, a3=a3,
                      prefill_chunk=8, decode_block=2,
                      telemetry=telemetry, **kw)
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run_to_completion()
    return [eng.result(u) for u in uids], eng


# ---------------------------------------------------------------------------
# registry / histogram / tracer unit tests (no device work)
# ---------------------------------------------------------------------------

def test_histogram_buckets_total_sum_quantile():
    h = Histogram("h", (10.0, 100.0, 1000.0))
    for v in (1, 10, 11, 100, 5000):
        h.observe(v)
    # upper-inclusive edges + one overflow bucket
    assert list(h.counts) == [2, 2, 0, 1]
    assert h.total == 5 and h.sum == 5122.0
    assert h.quantile(0.5) == 100.0
    assert h.quantile(1.0) == float("inf")      # overflow bucket
    assert Histogram("e", (1.0,)).quantile(0.99) == 0.0


def test_histogram_snapshot_load_roundtrip():
    h = Histogram("h", _COUNT_BUCKET_BOUNDS)
    for v in (1, 7, 300, 10 ** 9):
        h.observe(v)
    snap = h.snapshot()
    # snapshot is JSON-clean (checkpoints serialize it verbatim)
    snap = json.loads(json.dumps(snap))
    h2 = Histogram("h", _COUNT_BUCKET_BOUNDS)
    h2.load(snap)
    assert h2.snapshot() == h.snapshot()
    # a bounds mismatch refuses the load instead of mis-bucketing
    h3 = Histogram("h", (1.0, 2.0))
    h3.load(snap)
    assert h3.total == 0


def test_registry_idempotent_handles_and_stats_view():
    r = MetricsRegistry()
    c = r.counter("reqs")
    assert r.counter("reqs") is c
    c.inc()
    c.inc(2.5)
    stats = {"ticks": 3}
    r.attach_stats("serve_", stats)
    stats["ticks"] = 7          # live reference, not a copy
    snap = r.snapshot()
    assert snap["counters"]["reqs"] == 3.5
    assert snap["counters"]["serve_ticks"] == 7.0
    assert snap["schema"] == "a3-serve-metrics/v1"


def test_prometheus_exposition_format():
    r = MetricsRegistry()
    r.counter("reqs").inc(2)
    r.gauge("depth").set(1.5)
    h = r.histogram("lat_ns{terminal=finished}", (10.0, 100.0))
    h.observe(5)
    h.observe(50)
    h.observe(5000)
    text = r.to_prometheus()
    lines = text.splitlines()
    assert "# TYPE reqs counter" in lines and "reqs 2" in lines
    assert "depth 1.5" in lines
    # labeled histogram: values quoted, le merged, buckets cumulative
    assert 'lat_ns_bucket{terminal="finished",le="10"} 1' in lines
    assert 'lat_ns_bucket{terminal="finished",le="100"} 2' in lines
    assert 'lat_ns_bucket{terminal="finished",le="+Inf"} 3' in lines
    assert 'lat_ns_count{terminal="finished"} 3' in lines


def test_tracer_ring_drops_oldest_and_counts():
    tr = Tracer(max_events=4)
    for i in range(7):
        tr.instant(f"e{i}", ts_ns=i)
    assert tr.dropped == 3
    ct = tr.chrome_trace()
    assert [e["name"] for e in ct["traceEvents"]] == ["e3", "e4", "e5", "e6"]
    assert ct["otherData"]["dropped_events"] == 3
    json.dumps(ct)              # export is always JSON-serializable


# ---------------------------------------------------------------------------
# pure-observer contract: telemetry on == off, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(KINDS))
def test_telemetry_off_bit_identity(kind, all_params):
    cfg, a3 = KINDS[kind]
    prompts = _prompts(cfg.vocab_size)
    toks_off, eng_off = _run(all_params[cfg.name], cfg, prompts, a3=a3)
    toks_on, eng_on = _run(all_params[cfg.name], cfg, prompts, a3=a3,
                           telemetry=True, telemetry_every=2)
    assert toks_on == toks_off
    assert _det_stats(eng_on) == _det_stats(eng_off)
    # the headline of the zero-overhead contract, stated explicitly:
    # probes and spans added not one blocking device read
    assert eng_on.stats["host_syncs"] == eng_off.stats["host_syncs"]
    assert eng_on.tm is not None and eng_off.tm is None


def test_telemetry_off_is_default_and_hookless(all_params):
    eng = ServeEngine(all_params["tiny"], TINY, slots=1, max_len=MAX_LEN)
    assert eng.tm is None
    assert eng._decode_block_probe is None


# ---------------------------------------------------------------------------
# reconciliation: reported metrics vs engine counters
# ---------------------------------------------------------------------------

def test_ttft_and_decode_step_reconciliation(all_params):
    # slots=1 serializes lanes, so per-request attributed decode steps
    # must equal decode_steps_advanced EXACTLY (no padding ambiguity)
    eng = ServeEngine(all_params["tiny"], TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8, decode_block=3, telemetry=True)
    attributed = {}
    orig = eng.tm.on_decode_steps

    def record(uid, steps):
        attributed[uid] = attributed.get(uid, 0) + steps
        orig(uid, steps)

    eng.tm.on_decode_steps = record
    prompts = _prompts(TINY.vocab_size)
    uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
    eng.run_to_completion()
    assert all(eng.status(u) == "finished" for u in uids)
    assert sum(attributed.values()) == eng.stats["decode_steps_advanced"]

    snap = eng.tm.metrics_snapshot()
    h = snap["histograms"]
    ttft = h["serve_ttft_ns{terminal=finished}"]
    assert ttft["total"] == eng.stats["finished"] == len(prompts)
    # every finished request decoded at least one block -> one TPOT
    # observation each, and sojourn is keyed by the same terminal
    assert h["serve_tpot_ns"]["total"] == len(prompts)
    assert (h["serve_queue_sojourn_ns{terminal=finished}"]["total"]
            == len(prompts))
    # request tracking map drains with the requests (no leak)
    assert not eng.tm._reqs


def test_terminal_keyed_histograms_split_states(all_params):
    # a cancelled queued request lands in the cancelled sojourn/ttft
    # keys, not the finished ones
    eng = ServeEngine(all_params["tiny"], TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8, telemetry=True)
    u1 = eng.submit(np.arange(5, dtype=np.int32), max_new_tokens=2)
    u2 = eng.submit(np.arange(7, dtype=np.int32), max_new_tokens=2)
    eng.cancel(u2)
    eng.run_to_completion()
    assert eng.status(u1) == "finished" and eng.status(u2) == "cancelled"
    h = eng.tm.metrics_snapshot()["histograms"]
    assert h["serve_ttft_ns{terminal=finished}"]["total"] == 1
    # u2 never reached a slot: no admission -> no sojourn, no TTFT
    assert "serve_ttft_ns{terminal=cancelled}" not in h
    assert "serve_queue_sojourn_ns{terminal=cancelled}" not in h


@pytest.mark.parametrize("every", [1, 3])
def test_a3_probe_dispatch_reconciliation(every, all_params):
    prompts = _prompts(TINY.vocab_size)
    toks, eng = _run(all_params["tiny"], TINY, prompts,
                     a3=A3Config.conservative(), telemetry=True,
                     telemetry_every=every)
    snap = eng.tm.metrics_snapshot()
    nd = eng.stats["decode_dispatches"]
    assert nd > 0
    # the probe rides every telemetry_every-th dispatch, starting with
    # the first (counter % every == 0 pre-increment)
    assert (snap["counters"]["serve_a3_probe_dispatches"]
            == math.ceil(nd / every))
    # samples count (lane, step) pairs: every advanced step of every
    # live lane in a probed dispatch
    samples = snap["counters"]["serve_a3_probe_samples"]
    assert 0 < samples <= len(eng.slots) * eng.stats["decode_steps"]
    if every == 1:              # all dispatches probed: each advanced
        # step contributed at least one live lane
        assert samples >= eng.stats["decode_steps_advanced"]
    mass = snap["histograms"]["serve_a3_captured_mass"]
    cand = snap["histograms"]["serve_a3_candidates"]
    assert mass["total"] == cand["total"] > 0
    # captured-score-mass ratio is a fraction of the full softmax mass
    # measured from the same f32 scores: (0, 1] by construction
    assert 0.0 < mass["sum"] / mass["total"] <= 1.0
    assert cand["sum"] / cand["total"] >= 1.0


def test_probe_absent_without_a3(all_params):
    prompts = _prompts(TINY.vocab_size)
    _, eng = _run(all_params["tiny"], TINY, prompts, telemetry=True,
                  telemetry_every=1)
    assert eng._decode_block_probe is None
    snap = eng.tm.metrics_snapshot()
    assert snap["counters"]["serve_a3_probe_dispatches"] == 0
    assert snap["histograms"]["serve_a3_captured_mass"]["total"] == 0


# ---------------------------------------------------------------------------
# trace export
# ---------------------------------------------------------------------------

def test_trace_export_roundtrip_and_slot_monotonicity(all_params,
                                                      tmp_path):
    prompts = _prompts(TINY.vocab_size)
    _, eng = _run(all_params["tiny"], TINY, prompts,
                  a3=A3Config.conservative(), telemetry=True,
                  telemetry_every=2, page_size=8, cache_pages=16)
    path = tmp_path / "trace.json"
    eng.tm.write_trace(str(path))
    tr = json.loads(path.read_text())
    assert tr["otherData"]["schema"] == "a3-serve-trace/v1"
    evs = tr["traceEvents"]
    assert evs
    names = {e["name"] for e in evs}
    # the request lifecycle appears end to end
    for must in ("submit", "queued", "admit", "prefill", "first_token",
                 "decode_block", "terminal"):
        assert must in names, (must, sorted(names))
    # every span/instant carries a non-negative relative timestamp and
    # per-SLOT timelines are monotone in emission order (the harvest
    # lands tick-synchronously at depth 0, so a slot's spans replay in
    # dispatch order)
    by_slot = {}
    for e in evs:
        assert e["ts"] >= 0.0
        if isinstance(e["tid"], int):
            by_slot.setdefault(e["tid"], []).append(e["ts"])
    assert by_slot
    for tid, ts in by_slot.items():
        assert ts == sorted(ts), f"slot {tid} timeline not monotone"
    # lifecycle events carry their request uid
    assert all("uid" in e["args"] for e in evs
               if e["name"] in ("submit", "terminal"))


def test_trace_ring_bounded_under_pressure(all_params):
    prompts = _prompts(TINY.vocab_size) * 3
    _, eng = _run(all_params["tiny"], TINY, prompts, telemetry=True,
                  trace_events=16)
    assert len(eng.tm.tracer.events) == 16
    snap = eng.tm.metrics_snapshot()
    assert snap["counters"]["serve_trace_events_dropped"] > 0


# ---------------------------------------------------------------------------
# metrics through checkpoint/restore
# ---------------------------------------------------------------------------

def test_telemetry_checkpoint_roundtrip(all_params, tmp_path):
    eng = ServeEngine(all_params["tiny"], TINY, slots=2, max_len=MAX_LEN,
                      prefill_chunk=8, decode_block=2, telemetry=True,
                      a3=A3Config.conservative(), telemetry_every=2)
    prompts = _prompts(TINY.vocab_size)
    uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
    for _ in range(6):          # park mid-flight state in the histograms
        eng.step()
    eng.checkpoint(str(tmp_path))
    before = eng.tm.metrics_snapshot()["histograms"]
    assert any(h["total"] > 0 for h in before.values())

    eng2 = ServeEngine.restore(str(tmp_path), all_params["tiny"], TINY,
                               a3=A3Config.conservative())
    assert eng2.tm is not None
    after = eng2.tm.metrics_snapshot()["histograms"]
    assert after == before      # bucket-exact across the round trip
    # the restored engine keeps observing into the SAME histograms.
    # Requests mid-flight at checkpoint time deliberately get no TTFT
    # (their monotonic-clock tracks died with the old process — the
    # tracer is a flight recorder, the histograms are the durable
    # record), but requests submitted after the restore are tracked
    # end to end on top of the restored counts.
    ttft_key = "serve_ttft_ns{terminal=finished}"
    ttft_before = before.get(ttft_key, {"total": 0})["total"]
    fresh = [eng2.submit(p, max_new_tokens=2)
             for p in _prompts(TINY.vocab_size, seed=11)[:2]]
    eng2.run_to_completion()
    final = eng2.tm.metrics_snapshot()["histograms"]
    assert final[ttft_key]["total"] == ttft_before + len(fresh)
    assert all(eng2.status(u) == "finished" for u in uids + fresh)


def test_old_checkpoint_without_telemetry_restores(all_params, tmp_path):
    # a checkpoint written by an untelemetered engine (or a pre-
    # telemetry version: no "telemetry" key) restores cleanly
    eng = ServeEngine(all_params["tiny"], TINY, slots=1, max_len=MAX_LEN,
                      prefill_chunk=8)
    eng.submit(np.arange(5, dtype=np.int32), max_new_tokens=3)
    eng.step()
    eng.checkpoint(str(tmp_path))
    eng2 = ServeEngine.restore(str(tmp_path), all_params["tiny"], TINY)
    assert eng2.tm is None
    eng2.run_to_completion()
    assert eng2.stats["finished"] == 1
