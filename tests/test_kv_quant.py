"""Int8 KV pages + quantized A^3 candidate scoring.

The quantized-cache contract, layer by layer:

* **Selection** (core): int8-scored ``select_candidates`` (per-column
  fp32 scale folded into the query) picks the same top-M candidates as
  fp scoring up to at most one boundary swap — fixed-seed conformance
  here, the seed-drawn property under hypothesis. With power-of-two
  scales the fold is exact float arithmetic, so the masks are
  bit-identical to selection over dequantized keys.
* **Pool** (decoder + prefix cache): an int8 page pool records
  quantized pages and the warm gather dequantizes in-dispatch — a
  warm-admitted slot's ring equals a cold chunked prefill within the
  per-page quantization bound, for every mixer kind (recurrent carries
  are snapshots, never quantized — those stay exact).
* **Engine**: warm int8 generations match the fp warm path
  token-for-token on the fixed-seed workloads across attention, RG-LRU
  hybrid, xLSTM, and A^3 archs; ``kv_quant="none"`` is bit-identical to
  the default engine by construction (same pool dtype, same gather).
* **Residency**: the int8 pool holds >= 2x the pages of the fp pool at
  equal HBM (int8 payload + tiny scale leaves vs f32 payload).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import given, settings, st

from repro.config import A3Config, AttentionKind, BlockKind, ModelConfig, \
    ServeConfig
from repro.core.candidate_selection import SortedKeys, quantize_sorted_keys, \
    select_candidates, sort_key_columns
from repro.core.quantization import dequantize_int8_block, quantize_int8_block
from repro.models import decoder as dec
from repro.serve.engine import ServeEngine
from repro.serve.prefix_cache import PrefixCache

TINY = ModelConfig("tiny", "dense", num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                   dtype="float32")
TINY_RG = ModelConfig("tiny-rg", "hybrid", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, head_dim=16,
                      attention_kind=AttentionKind.SLIDING, window_size=24,
                      block_pattern=(BlockKind.RGLRU, BlockKind.RGLRU,
                                     BlockKind.ATTENTION),
                      act="gelu", dtype="float32")
TINY_XL = ModelConfig("tiny-xl", "ssm", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=256,
                      head_dim=16,
                      block_pattern=(BlockKind.MLSTM, BlockKind.MLSTM,
                                     BlockKind.SLSTM),
                      dtype="float32")
MAX_LEN = 96
MAX_NEW = 6
PAGE = 8


@pytest.fixture(scope="module")
def all_params():
    return {
        "tiny": dec.init_params(jax.random.PRNGKey(0), TINY),
        "tiny-rg": dec.init_params(jax.random.PRNGKey(1), TINY_RG),
        "tiny-xl": dec.init_params(jax.random.PRNGKey(2), TINY_XL),
    }


def _shared_prefix_prompts(vocab, *, shared_len=24, n=3, seed=7):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, size=shared_len)
    return [np.concatenate([shared,
                            rng.integers(0, vocab, size=4 + 3 * i)])
            for i in range(n)]


# ---------------------------------------------------------------------------
# core: int8-scored candidate selection
# ---------------------------------------------------------------------------

def _overlap_for_seed(seed, s=128, d=16, m=32):
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.normal(size=(s, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    sk = sort_key_columns(k)
    skq, scales = quantize_sorted_keys(sk)
    assert skq.values.dtype == jnp.int8 and scales.shape == (d,)
    fp, _ = select_candidates(sk, q, m)
    qm, _ = select_candidates(skq, q, m, scales=scales)
    n_fp, n_q = int(fp.sum()), int(qm.sum())
    return int(jnp.sum(fp & qm)), min(n_fp, n_q)


def test_int8_selection_overlap_fixed_seeds():
    """Fixed-seed conformance for the serving gate: int8-scored greedy
    selection agrees with fp scoring on >= nsel-1 of the selected
    candidates for every seed in the pinned sweep."""
    for seed in range(24):
        overlap, nsel = _overlap_for_seed(seed)
        assert overlap >= nsel - 1, (seed, overlap, nsel)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=40, deadline=None)
def test_int8_selection_overlap_property(seed):
    """Hypothesis property (seed-drawn so shrinking varies draws rather
    than constructing adversarial near-ties): same >= nsel-1 overlap
    bound over random gaussian keys/queries."""
    overlap, nsel = _overlap_for_seed(seed)
    assert overlap >= nsel - 1, (seed, overlap, nsel)


def test_int8_selection_pow2_scale_exact():
    """With power-of-two column scales, folding the scale into the
    query is EXACT float arithmetic (an exponent shift commutes with the
    product rounding), so int8-scored selection is bit-identical to
    selection over the dequantized columns — the strongest form of the
    quantized-scoring equivalence."""
    rng = np.random.default_rng(5)
    s, d, m = 96, 8, 24
    k = jnp.asarray(rng.normal(size=(s, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    sk = sort_key_columns(k)
    amax = jnp.max(jnp.abs(sk.values), axis=0)
    scales = 2.0 ** jnp.ceil(jnp.log2(jnp.maximum(amax / 127.0, 1e-12)))
    qv = jnp.clip(jnp.round(sk.values / scales), -127, 127) \
        .astype(jnp.int8)
    skq = SortedKeys(values=qv, rows=sk.rows)
    deq = SortedKeys(values=dequantize_int8_block(qv, scales),
                     rows=sk.rows)
    m_q, g_q = select_candidates(skq, q, m, scales=scales)
    m_d, g_d = select_candidates(deq, q, m)
    np.testing.assert_array_equal(np.asarray(m_q), np.asarray(m_d))
    np.testing.assert_array_equal(np.asarray(g_q), np.asarray(g_d))


# ---------------------------------------------------------------------------
# pool: record-quantize / gather-dequantize roundtrip per mixer kind
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [TINY, TINY_RG, TINY_XL],
                         ids=["attention", "rglru", "xlstm"])
def test_int8_pool_gather_within_quant_bound(all_params, cfg):
    """Record a prompt into an int8 pool from lane 0, warm-admit its
    prefix into lane 1 of a fresh cache: every leaf equals a cold
    chunked prefill within the per-page quantization bound (scale/2 per
    element, scale = page amax/127 -> bounded by amax/250); recurrent
    carries are snapshots, not pages, so they stay exact."""
    params = all_params[cfg.name]
    rng = np.random.default_rng(17)
    p = rng.integers(0, cfg.vocab_size, size=26)
    ps, t = PAGE, 16
    pc = PrefixCache(cfg, max_len=MAX_LEN, page_size=ps, cache_pages=8,
                     kv_quant="int8")
    for seg in pc.pool.values():
        assert seg["k"].dtype == jnp.int8 and seg["v"].dtype == jnp.int8
        assert seg["k_scale"].dtype == jnp.float32
    cache = dec.init_cache(cfg, 2, MAX_LEN)
    node = pc.root
    for cur in range(0, len(p), ps):
        take = min(ps, len(p) - cur)
        toks = np.zeros((2, ps), np.int32)
        toks[0, :take] = p[cur:cur + take]
        _, cache = dec.prefill_chunk(params, cfg, cache,
                                     jnp.asarray(toks),
                                     jnp.asarray([cur, 0], jnp.int32),
                                     jnp.asarray([take, 0], jnp.int32))
        if (cur + take) % ps == 0:
            node = pc.record_boundary(cache, 0, p, cur + take, node)
            assert node is not None
    fresh = dec.init_cache(cfg, 2, MAX_LEN)
    fresh2, got_t, _ = pc.admit(fresh, 1, p[:t + 1])
    assert got_t == t
    ref_cache = dec.init_cache(cfg, 2, MAX_LEN)
    for cur in range(0, t, ps):
        toks = np.zeros((2, ps), np.int32)
        toks[1] = p[cur:cur + ps]
        _, ref_cache = dec.prefill_chunk(params, cfg, ref_cache,
                                         jnp.asarray(toks),
                                         jnp.asarray([0, cur], jnp.int32),
                                         jnp.asarray([0, ps], jnp.int32))
    flat_g, _ = jax.tree_util.tree_flatten_with_path(fresh2)
    flat_r, _ = jax.tree_util.tree_flatten_with_path(ref_cache)
    for (ka, a), (kb, b) in zip(flat_g, flat_r):
        assert str(ka) == str(kb)
        an = np.asarray(a, np.float32)[:, 1]
        bn = np.asarray(b, np.float32)[:, 1]
        name = str(ka)
        if "'k'" in name or "'v'" in name:
            # quantized pages: per-element error <= amax/250 of the leaf
            bound = max(np.abs(bn).max() / 250.0, 1e-6)
            assert np.abs(an - bn).max() <= bound, (name,
                                                    np.abs(an - bn).max())
        else:
            # recurrent carries / positions travel as fp snapshots
            np.testing.assert_allclose(an, bn, rtol=1e-6, atol=1e-6,
                                       err_msg=name)


def test_int8_pool_doubles_residency_at_equal_hbm():
    """The serving claim behind the knob: at a fixed HBM budget the int8
    pool holds >= 2x the pages (4-byte payload -> 1 byte + amortized
    fp32 scales)."""
    nbytes = lambda pool: sum(l.nbytes for l in
                              jax.tree_util.tree_leaves(pool))
    fp = dec.init_page_pool(TINY, 32, PAGE)
    q8 = dec.init_page_pool(TINY, 32, PAGE, kv_quant="int8")
    assert nbytes(fp) / nbytes(q8) >= 2.0
    # equal-HBM restatement: the pages an int8 pool fits in the fp
    # pool's footprint
    per_page_fp = nbytes(fp) / 32
    per_page_q8 = nbytes(q8) / 32
    assert int(nbytes(fp) / per_page_q8) >= 2 * int(nbytes(fp)
                                                    / per_page_fp)


# ---------------------------------------------------------------------------
# engine: warm int8 serving conformance across archs (incl. A^3)
# ---------------------------------------------------------------------------

def _run_warm(params, cfg, prompts, *, kv_quant, a3=A3Config()):
    eng = ServeEngine(params, cfg, slots=2, max_len=MAX_LEN, a3=a3,
                      prefill_chunk=PAGE, page_size=PAGE, cache_pages=32,
                      kv_quant=kv_quant)
    u0 = eng.submit(prompts[0], max_new_tokens=MAX_NEW)
    eng.run_to_completion()
    uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts[1:]]
    eng.run_to_completion()
    assert eng.stats["prefix_hits"] == len(prompts) - 1
    return [eng.result(u0)] + [eng.result(u) for u in uids], eng.stats


@pytest.mark.parametrize("arch,a3", [
    ("tiny", A3Config()),
    ("tiny-rg", A3Config()),
    ("tiny-xl", A3Config()),
    ("tiny", A3Config.conservative()),
], ids=["attention", "rglru", "xlstm", "a3"])
def test_int8_warm_matches_fp_warm_fixed_seeds(all_params, arch, a3):
    """Fixed-seed serving conformance: generations off int8 warm
    admissions match the fp warm path token-for-token on this workload
    for every arch kind — the quantization error stays below greedy
    argmax margins here, and the A^3 variant additionally routes the
    restored sorted columns through int8 leaf snapshots."""
    cfg = {"tiny": TINY, "tiny-rg": TINY_RG, "tiny-xl": TINY_XL}[arch]
    params = all_params[arch]
    prompts = _shared_prefix_prompts(cfg.vocab_size)
    fp_out, fp_stats = _run_warm(params, cfg, prompts, kv_quant="none",
                                 a3=a3)
    q_out, q_stats = _run_warm(params, cfg, prompts, kv_quant="int8",
                               a3=a3)
    assert fp_out == q_out
    # both paths reused the same prefix tokens — the int8 pool changes
    # page *bytes*, never trie matching
    assert (fp_stats["prefix_tokens_reused"]
            == q_stats["prefix_tokens_reused"])


def test_kv_quant_none_is_default_engine(all_params):
    """kv_quant="none" must be the identity: same pool dtype tree and
    token-for-token identical generations vs an engine that never heard
    of the knob."""
    prompts = _shared_prefix_prompts(TINY.vocab_size)
    params = all_params["tiny"]
    base = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                       prefill_chunk=PAGE, page_size=PAGE, cache_pages=32)
    none = ServeEngine(params, TINY, slots=2, max_len=MAX_LEN,
                       prefill_chunk=PAGE, page_size=PAGE, cache_pages=32,
                       kv_quant="none")
    assert (jax.tree.map(lambda l: l.dtype, base._pc.pool)
            == jax.tree.map(lambda l: l.dtype, none._pc.pool))
    outs = []
    for eng in (base, none):
        uids = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
        eng.run_to_completion()
        outs.append([eng.result(u) for u in uids])
    assert outs[0] == outs[1]


def test_kv_quant_validation():
    with pytest.raises(ValueError, match="kv_quant"):
        ServeConfig(kv_quant="fp8")
    with pytest.raises(ValueError, match="kv_quant"):
        PrefixCache(TINY, max_len=MAX_LEN, page_size=PAGE, cache_pages=4,
                    kv_quant="int4")
    with pytest.raises(ValueError, match="kv_quant"):
        ServeEngine(None, TINY, kv_quant="bogus")
    assert ServeConfig().kv_quant == "none"


# ---------------------------------------------------------------------------
# kernels: int8 scoring inside the fused decode path
# ---------------------------------------------------------------------------

def test_compact_decode_int8_close_to_fp():
    """a3_decode_attention_compact with int8 sorted keys + int8 K/V
    (scales folded into query / gathered with the winners) stays within
    the quantization error envelope of the fp path on random draws."""
    import dataclasses

    from repro.kernels.decode_attention.ops import \
        a3_decode_attention_compact
    rng = np.random.default_rng(2)
    b, hq, hkv, d, dv, s, ns = 2, 4, 2, 16, 16, 128, 4
    cfg = dataclasses.replace(A3Config.conservative(), select_shards=ns)
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, dv)), jnp.float32)
    valid = jnp.ones((b, s), bool)
    sl = s // ns
    skb = jax.vmap(jax.vmap(jax.vmap(sort_key_columns)))(
        k.reshape(b, hkv, ns, sl, d))
    sk = SortedKeys(skb.values.reshape(b, hkv, s, d),
                    skb.rows.reshape(b, hkv, s, d))
    out_fp = a3_decode_attention_compact(q, k, v, valid, cfg, sk)

    qv, sk_scale = quantize_int8_block(skb.values, axes=(3,))
    kq, ks = quantize_int8_block(k, axes=(3,))
    vq, vs = quantize_int8_block(v, axes=(3,))
    out_q = a3_decode_attention_compact(
        q, kq, vq, valid, cfg,
        SortedKeys(qv.reshape(b, hkv, s, d), sk.rows),
        sk_scale=sk_scale.reshape(b, hkv, ns, d),
        k_scale=ks[..., 0], v_scale=vs[..., 0])
    assert out_q.dtype == jnp.float32
    assert float(jnp.max(jnp.abs(out_fp - out_q))) < 0.1


def test_batch_a3_attention_int8_close_to_fp():
    """a3_attention scores int8 keys directly in the candidate map and
    dequantizes only for the fused softmax."""
    from repro.kernels.a3_attention.ops import a3_attention
    rng = np.random.default_rng(4)
    b, hq, hkv, d, s = 2, 4, 2, 16, 64
    cfg = A3Config.conservative()
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    out_fp = a3_attention(q, k, v, cfg, causal=True)
    kq, ks = quantize_int8_block(k, axes=(2,))
    vq, vs = quantize_int8_block(v, axes=(2,))
    out_q = a3_attention(q, kq, vq, cfg, causal=True,
                         k_scale=ks[:, :, 0], v_scale=vs[:, :, 0])
    assert float(jnp.max(jnp.abs(out_fp - out_q))) < 0.1
