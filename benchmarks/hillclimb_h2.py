"""H2 hillclimb: grok-1-314b x prefill_32k is collective-bound.

Baseline: (data=16, model=16) mesh; grok's 8 experts don't divide the
16-way model axis, so the rules fall back to TP-inside-expert and GSPMD
moves whole expert activation blocks (observed: 3.6 TB/device collective
operand bytes).

Iterations (run in the 512-placeholder-device env):
  v1: same mesh, FSDP off for inference (weights TP-only where they fit)
  v2: alternative factorization of the SAME 256 chips:
      (data=2, ep=8, model=16) — experts get a real EP axis; dispatch
      becomes an all-to-all over ep; dense parts keep 16-way TP.
  v3: v2 + FSDP off.

Usage:  PYTHONPATH=src:. python -m benchmarks.hillclimb_h2
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json
import time

import jax

from repro.config import SHAPE_SUITE, ShardingConfig, get_arch
from repro.launch import roofline
from repro.launch.dryrun import lower_prefill, model_flops_for
from repro.launch.mesh import make_mesh, make_production_mesh


def measure(tag, mesh, mesh_name, scfg, arch="grok-1-314b",
            shape_name="prefill_32k"):
    cfg = get_arch(arch)
    shape = SHAPE_SUITE[shape_name]
    t0 = time.time()
    with mesh:
        compiled = lower_prefill(cfg, shape, mesh, scfg).compile()
    r = roofline.analyze(arch, shape_name, mesh_name, mesh.devices.size,
                         compiled, model_flops_for(cfg, shape))
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 2 ** 30
    print(f"[{tag}] compute={r.compute_s:.2f}s memory={r.memory_s:.2f}s "
          f"collective={r.collective_s:.2f}s peak={peak:.1f}GiB "
          f"ops={r.op_counts} ({time.time()-t0:.0f}s)", flush=True)
    return {**r.to_dict(), "tag": tag, "peak_gib": peak}


def main():
    out = []
    base_mesh = make_production_mesh()
    out.append(measure("baseline 16x16 fsdp", base_mesh, "16x16",
                       ShardingConfig(remat="none")))
    out.append(measure("v1 16x16 no-fsdp", base_mesh, "16x16",
                       ShardingConfig(remat="none", fsdp=False)))
    alt = make_mesh((2, 8, 16), ("data", "ep", "model"))
    alt_cfg = ShardingConfig(remat="none", ep_axis="ep",
                             dp_axes=("data", "ep"))
    out.append(measure("v2 2x8x16 ep-mesh", alt, "2x8x16", alt_cfg))
    out.append(measure("v3 2x8x16 ep no-fsdp", alt, "2x8x16",
                       ShardingConfig(remat="none", ep_axis="ep",
                                      dp_axes=("data", "ep"), fsdp=False)))
    with open("/root/repo/experiments_h2.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
