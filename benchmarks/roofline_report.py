"""Render the EXPERIMENTS.md SSRoofline table from dry-run JSON records.

  PYTHONPATH=src:. python -m benchmarks.roofline_report \
      experiments_dryrun_16x16.json [experiments_dryrun_2x16x16.json ...]
"""
from __future__ import annotations

import json
import sys
from typing import List


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def render(records: List[dict]) -> str:
    rows = []
    hdr = ("| arch | shape | mesh | compute | memory | collective | "
           "bottleneck | peak GiB/dev | useful/HLO | roofline frac |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for r in records:
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR: {r['error'][:40]} |" + " |" * 6)
            continue
        peak = r["memory"]["peak_device_bytes"] / 2 ** 30
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {_fmt_s(r['compute_s'])} | {_fmt_s(r['memory_s'])} "
            f"| {_fmt_s(r['collective_s'])} | **{r['bottleneck']}** "
            f"| {peak:.1f} | {r['useful_flop_ratio']:.3f} "
            f"| {r['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def summarize(records: List[dict]) -> str:
    ok = [r for r in records if "error" not in r]
    bn = {}
    for r in ok:
        bn[r["bottleneck"]] = bn.get(r["bottleneck"], 0) + 1
    lines = [f"cells OK: {len(ok)}/{len(records)}; bottlenecks: {bn}"]
    over = [r for r in ok
            if r["memory"]["peak_device_bytes"] > 16 * 2 ** 30]
    if over:
        lines.append("cells over 16 GiB v5e HBM: " + ", ".join(
            f"{r['arch']}x{r['shape']}({r['mesh']})" for r in over))
    return "\n".join(lines)


def main():
    records = []
    for path in sys.argv[1:]:
        with open(path) as f:
            records.extend(json.load(f))
    print(render(records))
    print()
    print(summarize(records))


if __name__ == "__main__":
    main()
