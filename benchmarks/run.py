"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run              # all (trains the MemN2N once)
  python -m benchmarks.run --only fig11,fig14
Output: ``name,metric,value`` CSV on stdout.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import rows_to_csv

_BENCHES = {
    "fig3": ("benchmarks.bench_attention_fraction", "attention runtime share"),
    "fig11": ("benchmarks.bench_m_sweep", "candidate-selection M sweep"),
    "fig12": ("benchmarks.bench_t_sweep", "post-scoring T sweep"),
    "fig13": ("benchmarks.bench_approx_configs", "conservative/aggressive"),
    "fig14": ("benchmarks.bench_throughput", "throughput/latency + FLOPs"),
    "sec6b": ("benchmarks.bench_quantization", "quantization + LUT bound"),
    "kernels": ("benchmarks.bench_kernels", "kernel block-skip + select"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated keys: " + ",".join(_BENCHES))
    args = ap.parse_args()
    keys = list(_BENCHES) if not args.only else args.only.split(",")

    all_rows = []
    failures = 0
    for k in keys:
        mod_name, desc = _BENCHES[k]
        t0 = time.time()
        print(f"# running {k}: {desc} ...", file=sys.stderr)
        try:
            mod = __import__(mod_name, fromlist=["run"])
            rows = mod.run()
            all_rows.extend(rows)
            print(f"#   {k} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"#   {k} FAILED", file=sys.stderr)
    print(rows_to_csv(all_rows))
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
