"""Recompute roofline terms from saved compiled-HLO artifacts (no
recompilation): keeps the analysis iterable as ``hlo_analysis`` improves.

  PYTHONPATH=src:. python -m benchmarks.reanalyze \
      experiments_dryrun_16x16.json experiments/hlo [out.json]
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from repro.launch.hlo_analysis import HloModule
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def main():
    json_path, hlo_dir = sys.argv[1], sys.argv[2]
    out_path = sys.argv[3] if len(sys.argv) > 3 else json_path
    records = json.load(open(json_path))
    n_updated = 0
    for r in records:
        if "error" in r:
            continue
        fn = f"{r['arch']}_{r['shape']}_{r['mesh']}"
        if r.get("a3_mode", "off") != "off":
            fn += f"_a3-{r['a3_mode']}"
        path = os.path.join(hlo_dir, fn + ".hlo.gz")
        if not os.path.exists(path):
            continue
        with gzip.open(path, "rt") as f:
            mod = HloModule(f.read())
        flops = mod.dot_flops()
        bts = mod.hbm_bytes()
        ob, oc, wire = mod.collectives()
        coll = sum(ob.values())
        r.update(
            flops_per_device=flops, bytes_per_device=bts,
            collective_bytes=coll, wire_bytes=wire,
            compute_s=flops / PEAK_FLOPS_BF16,
            memory_s=bts / HBM_BW,
            collective_s=coll / ICI_BW,
            op_counts=oc,
        )
        terms = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}
        r["bottleneck"] = max(terms, key=terms.get)
        total = flops * r["chips"]
        r["useful_flop_ratio"] = r["model_flops"] / total if total else 0.0
        t = max(terms.values())
        r["roofline_fraction"] = (r["model_flops"] /
                                  (r["chips"] * PEAK_FLOPS_BF16 * t)
                                  if t > 0 else 0.0)
        n_updated += 1
    with open(out_path, "w") as f:
        json.dump(records, f, indent=1)
    print(f"re-analyzed {n_updated}/{len(records)} records -> {out_path}")


if __name__ == "__main__":
    main()
