"""H3 hillclimb: A^3 approximate decode (the paper's technique) vs exact
decode, across batch sizes and context lengths.

Iterations v1-v6 (EXPERIMENTS.md SSPerf) fixed the *implementation*:
  v1 naive compact      -> selection O(M d) per query, 80x regression
  v2 prefix cap ~4M/d   -> O(M) selection work
  v3 heuristic off      -> no M-step sequential scans
  v4 shard-local blocks -> no global top_k across the model axis
  v5 batched (no vmap)  -> gathers keep batch dims; + explicit stage
                           shardings (collective term 2.8s -> 67ms)
  v6 sort-free ranking  -> scatter/sort trade-offs measured

This script measures the *regime*: at B=128 exact attention amortizes
each cache row over B x G queries, while A^3 gathers rows per KV-head
group — so compaction pays off only when the batch is small relative to
the context (the paper's own setting: single-query retrieval). The
beyond-paper demonstration is long_500k on a full-attention arch (B=1),
which the baseline table *skips* as infeasible-by-definition and A^3
makes runnable.

  PYTHONPATH=src:. python -m benchmarks.hillclimb_h3
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import dataclasses
import json
import time

from repro.config import A3Config, ShapeConfig, ShapeKind, ShardingConfig, \
    get_arch
from repro.launch import roofline
from repro.launch.dryrun import lower_decode, model_flops_for
from repro.launch.mesh import make_production_mesh

SHAPES = {
    "decode_32k_b128": ShapeConfig("decode_32k_b128", ShapeKind.DECODE,
                                   32768, 128),
    "decode_32k_b16": ShapeConfig("decode_32k_b16", ShapeKind.DECODE,
                                  32768, 16),
    "long_500k_b1": ShapeConfig("long_500k_b1", ShapeKind.DECODE,
                                524288, 1),
}


def measure(tag, arch, shape, a3):
    cfg = get_arch(arch)
    mesh = make_production_mesh()
    scfg = ShardingConfig(remat="none")
    t0 = time.time()
    with mesh:
        compiled = lower_decode(cfg, shape, mesh, scfg, a3).compile()
    r = roofline.analyze(arch, shape.name, "16x16", 256, compiled,
                         model_flops_for(cfg, shape))
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 2 ** 30
    print(f"[{tag}] mem={r.memory_s*1e3:8.1f}ms coll={r.collective_s*1e3:7.1f}ms "
          f"peak={peak:5.1f}GiB ({time.time()-t0:.0f}s)", flush=True)
    return {**r.to_dict(), "tag": tag, "peak_gib": peak,
            "a3": a3.mode.value}


def main():
    exact = A3Config()
    aggr = dataclasses.replace(A3Config.aggressive(), select_shards=16)
    cons = dataclasses.replace(A3Config.conservative(), select_shards=16)
    out = []
    aggr256 = dataclasses.replace(aggr, select_shards=256)
    for shape_name in ["decode_32k_b128", "decode_32k_b16", "long_500k_b1"]:
        shape = SHAPES[shape_name]
        cells = [("exact", exact), ("a3-aggr", aggr), ("a3-cons", cons)]
        if shape_name == "long_500k_b1":
            # B=1 shards the ring over BOTH axes (256-way): align the
            # selection blocks with the full device grid
            cells = [("exact", exact), ("a3-aggr-ns16", aggr),
                     ("a3-aggr-ns256", aggr256)]
        for label, a3 in cells:
            out.append(measure(f"phi4 {shape_name} {label}",
                               "phi4-mini-3.8b", shape, a3))
    with open("/root/repo/experiments_h3.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
