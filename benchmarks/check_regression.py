"""Warn-only bench-trajectory guard: diff a fresh ``BENCH_serve.json``
against the committed baseline's scenario headline numbers.

  python benchmarks/check_regression.py --fresh /tmp/BENCH_serve.json \
      [--baseline benchmarks/BENCH_serve.json] [--tolerance 0.30]

Intended as a CI step AFTER regenerating the bench on the runner: it
prints one line per headline (value, baseline, delta) and a ``WARN``
marker when a headline moved past the tolerance in the bad direction.
It ALWAYS exits 0 — CI bench hardware is noisy shared capacity, so
the trajectory is surfaced, not enforced; a committed-baseline bump
belongs in the PR that deliberately moves a headline.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# (dotted path into BENCH_serve.json, direction) — the per-scenario
# headline numbers worth watching. "higher" = bigger is better.
HEADLINES = [
    ("result.tok_per_s", "higher"),
    ("result.tick_ms_p50", "lower"),
    ("result.dispatches_per_tick", "lower"),
    ("dispatch_compare.speedup", "higher"),
    ("tail_latency.chunked.worst_over_decode_median", "lower"),
    ("tail_latency_hybrid.chunked_ratio_growth", "lower"),
    ("dispatch_pipeline.1.speedup_vs_sync", "higher"),
    ("prefix_reuse.warm_admission_speedup", "higher"),
    ("kv_quant.residency_ratio_at_equal_hbm", "higher"),
    ("overload_shed.p99_improvement", "higher"),
    ("l2_eviction_pressure.l2_hit_speedup_vs_cold", "higher"),
]


def _get(tree, dotted):
    cur = tree
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur if isinstance(cur, (int, float)) else None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="freshly generated BENCH_serve.json")
    ap.add_argument("--baseline", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "BENCH_serve.json"))
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="relative regression past this fraction WARNs")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    warns = 0
    for path, direction in HEADLINES:
        fv, bv = _get(fresh, path), _get(base, path)
        if fv is None or bv is None:
            print(f"skip  {path}: missing "
                  f"({'fresh' if fv is None else 'baseline'})")
            continue
        if bv == 0:
            print(f"skip  {path}: zero baseline")
            continue
        rel = (fv - bv) / abs(bv)
        regressed = rel < -args.tolerance if direction == "higher" \
            else rel > args.tolerance
        tag = "WARN " if regressed else "ok   "
        warns += regressed
        print(f"{tag}{path}: {fv:.4g} vs baseline {bv:.4g} "
              f"({rel:+.1%}, {direction} is better)")
    if warns:
        print(f"{warns} headline(s) regressed past "
              f"{args.tolerance:.0%} — warn-only, not failing the build")
    else:
        print("bench trajectory within tolerance")
    return 0    # ALWAYS: this is a tripwire, not a gate


if __name__ == "__main__":
    sys.exit(main())
