"""Kernel-path benchmarks: block-skip ratio of the block-sparse A^3
kernel under candidate masks, on both unstructured (random) and
clustered (realistic) key distributions.

The ASIC skips *rows*; the TPU kernel skips *tiles*, so the realized
saving depends on whether the selected candidates cluster. Real
attention is heavily clustered (a few keys dominate many queries — the
paper's own near-zero-softmax observation), which we model by drawing
keys around a small number of centroids and queries near the same
centroids. Random (isotropic) data is the adversarial case and shows
tile-skipping degrading toward dense — reported honestly side by side.

Also: candidate-selection cost (vectorized greedy vs the full dot
product it replaces) and per-query candidate statistics.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.config import A3Config
from repro.core.a3_attention import candidate_block_map
from repro.core.candidate_selection import select_candidates_batch, \
    sort_key_columns


def _clustered(key, n, d, n_clusters=8, spread=0.15):
    kc, kk, kq = jax.random.split(key, 3)
    cents = jax.random.normal(kc, (n_clusters, d))
    assign = jax.random.randint(kk, (n,), 0, n_clusters)
    k = cents[assign] + spread * jax.random.normal(kq, (n, d))
    return k, cents, assign


def run(seq: int = 1024, d: int = 64, block: int = 128) -> List[dict]:
    rows: List[dict] = []
    key = jax.random.PRNGKey(0)

    datasets = {}
    k1, k2, k3 = jax.random.split(key, 3)
    datasets["random"] = (jax.random.normal(k1, (seq, d)) * 0.5,
                          jax.random.normal(k2, (seq, d)) * 0.5)
    kk, cents, assign = _clustered(k3, seq, d)
    kq = cents[assign] + 0.3 * jax.random.normal(k1, (seq, d))
    datasets["clustered"] = (kk * 0.5, kq * 0.5)

    for dname, (k, q) in datasets.items():
        sk = sort_key_columns(k)
        for label, a3 in [("conservative", A3Config.conservative()),
                          ("aggressive", A3Config.aggressive())]:
            m = a3.m_for(seq)
            mask, _ = select_candidates_batch(sk, q / jnp.sqrt(d * 1.0), m)
            cand_per_q = float(jnp.mean(jnp.sum(mask, -1)))
            for bs in (block, 32):
                bm = candidate_block_map(mask, bs, bs)
                nq, nk = bm.shape
                tri = jnp.tril(jnp.ones((nq, nk), bool))
                live = float(jnp.sum(bm & tri)) / float(jnp.sum(tri))
                rows.append({"name": "kernel_block_skip",
                             "metric":
                             f"live_frac_{dname}_{label}_b{bs}",
                             "value": f"{live:.3f}"})
            rows.append({"name": "kernel_block_skip",
                         "metric": f"cand_per_query_{dname}_{label}",
                         "value": f"{cand_per_q:.1f}"})

    # candidate-selection cost vs the dot product it replaces (one batch
    # of `seq` queries; CPU wall time, TPU cost is the block-map itself)
    k, q = datasets["clustered"]
    sk = sort_key_columns(k)
    sel = jax.jit(lambda q: select_candidates_batch(sk, q, seq // 8)[0])
    t_sel = time_fn(sel, q, iters=5)
    dot = jax.jit(lambda q: q @ k.T)
    t_dot = time_fn(dot, q, iters=5)
    rows.append({"name": "kernel_candidate_select",
                 "metric": f"select_batch{seq}_us",
                 "value": f"{t_sel*1e6:.1f}"})
    rows.append({"name": "kernel_candidate_select",
                 "metric": f"full_dot_batch{seq}_us",
                 "value": f"{t_dot*1e6:.1f}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
