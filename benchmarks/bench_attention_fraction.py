"""Paper Fig. 3: fraction of query-response time spent in the attention
mechanism.

Measured the way the paper frames it: MemN2N query response = embedding
of the question + attention hops + final projection; the attention
mechanism (score, softmax, weighted sum over n memories) is timed
against the total. The paper reports >70% for MemN2N query response at
n<=320 on CPU; we sweep n.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.models import memn2n


def run() -> List[dict]:
    rows: List[dict] = []
    for n in [64, 320, 1024]:
        cfg = memn2n.MemN2NConfig(vocab_size=512, d_embed=64, num_hops=3,
                                  max_sentences=n, max_words=8)
        params = memn2n.init_params(jax.random.PRNGKey(0), cfg)
        key = jax.random.PRNGKey(1)
        sents = jax.random.randint(key, (16, n, 8), 1, 512)
        quest = jax.random.randint(key, (16, 8), 1, 512)

        full = jax.jit(lambda s, q: jax.vmap(
            lambda ss, qq: memn2n.answer(params, ss, qq, cfg))(s, q))
        t_full = time_fn(full, sents, quest, iters=10)

        # attention-free variant: embedding + final projection only
        def no_attn(s, q):
            u = jnp.sum(params["query_embed"][q]
                        * (q > 0)[:, None].astype(jnp.float32), axis=0)
            return u @ params["w_final"]

        nofn = jax.jit(lambda s, q: jax.vmap(
            lambda ss, qq: no_attn(ss, qq))(s, q))
        t_no = time_fn(nofn, sents, quest, iters=10)
        frac = max(0.0, (t_full - t_no) / t_full)
        rows.append({"name": "fig3_attention_fraction",
                     "metric": f"memn2n_attn_share_n{n}",
                     "value": f"{frac:.3f}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
