"""Paper SSVI-B quantization study + SSIII-A footnote-1 LUT error bound.

(a) f-bit sweep: quantize MemN2N attention inputs to i=4, f in
    {2,3,4,6} and measure accuracy delta (paper: f=4 costs <0.1%).
(b) 2-LUT exponent decomposition: max |e^x - lut(x)| over the valid
    input range, checked against the analytic epsilon bound.
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_memn2n
from repro.config import A3Config, A3Mode
from repro.models import memn2n
from repro.core.quantization import make_lut_exp, quantize_fixed_point


def run(num_statements: int = 48) -> List[dict]:
    params, cfg, task, test = trained_memn2n(num_statements)
    rows: List[dict] = []
    base = float(memn2n.accuracy(params, test, cfg))

    for f in [2, 3, 4, 6]:
        a3 = A3Config(mode=A3Mode.CUSTOM, m_fraction=1.0,
                      threshold_pct=1e-6, int_bits=4, frac_bits=f)
        acc = float(memn2n.accuracy(params, test, cfg, a3))
        rows.append({"name": "sec6b_quantization",
                     "metric": f"acc_delta_pct_f={f}",
                     "value": f"{100*(acc-base):.2f}"})

    # LUT exponent error (fn.1: |e^{x+eps} - e^x| < |eps| for x <= 0):
    # the two-LUT path quantizes x to 2f fraction bits (eps = 2^-2f / 2)
    # and the error after exp must stay below eps.
    for f in [4, 8]:
        # index width must cover the [-8, 0] input range: 2f fraction
        # bits + 3 integer bits
        lut = make_lut_exp(frac_bits=2 * f, total_bits=2 * f + 3,
                           out_frac_bits=24)
        xs = jnp.linspace(-8.0, 0.0, 20001)
        err = float(jnp.max(jnp.abs(lut(xs) - jnp.exp(xs))))
        eps = 2.0 ** (-2 * f) / 2
        rows.append({"name": "fn1_lut_exponent",
                     "metric": f"max_abs_err_2f={2*f}",
                     "value": f"{err:.2e}"})
        rows.append({"name": "fn1_lut_exponent",
                     "metric": f"bound_eps_2f={2*f}",
                     "value": f"{eps:.2e}"})
        rows.append({"name": "fn1_lut_exponent",
                     "metric": f"bound_ok_2f={2*f}",
                     "value": str(err <= eps + 1e-9)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
