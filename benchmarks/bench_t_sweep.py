"""Paper Fig. 12: post-scoring threshold T (%) vs (a) accuracy and (b)
normalized number of selected entries. Candidate selection is disabled
(M = n) to isolate post-scoring, mirroring the paper's ablation.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import trained_memn2n
from repro.config import A3Config, A3Mode
from repro.models import memn2n


def run(num_statements: int = 48) -> List[dict]:
    params, cfg, task, test = trained_memn2n(num_statements)
    rows: List[dict] = []
    base_acc = float(memn2n.accuracy(params, test, cfg))
    rows.append({"name": "fig12_t_sweep", "metric": "acc_exact",
                 "value": f"{base_acc:.4f}"})

    for t_pct in [1.0, 5.0, 10.0, 20.0]:
        a3 = A3Config(mode=A3Mode.CUSTOM, m_fraction=1.0,
                      threshold_pct=t_pct)
        acc = float(memn2n.accuracy(params, test, cfg, a3))

        def kept_frac(s, q):
            _, aux = memn2n.answer_with_a3(params, s, q, cfg, a3)
            k = jnp.sum(aux["hop0"]["kept"])
            c = jnp.sum(aux["hop0"]["candidates"])
            return k / jnp.maximum(c, 1)

        fr = jax.vmap(kept_frac)(test["sentences"][:64],
                                 test["question"][:64])
        rows.append({"name": "fig12_t_sweep",
                     "metric": f"acc_delta_pct_T={t_pct:g}",
                     "value": f"{100*(acc-base_acc):.2f}"})
        rows.append({"name": "fig12_t_sweep",
                     "metric": f"kept_fraction_T={t_pct:g}",
                     "value": f"{float(jnp.mean(fr)):.3f}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
