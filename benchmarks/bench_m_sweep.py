"""Paper Fig. 11: candidate-selection iteration count M vs (a) model
accuracy and (b) number of selected candidates, on the MemN2N/bAbI
workload (synthetic task; same model for every point).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import trained_memn2n
from repro.config import A3Config, A3Mode
from repro.models import memn2n


def run(num_statements: int = 48) -> List[dict]:
    params, cfg, task, test = trained_memn2n(num_statements)
    n = num_statements
    rows: List[dict] = []

    base_acc = float(memn2n.accuracy(params, test, cfg))
    rows.append({"name": "fig11_m_sweep", "metric": "acc_exact",
                 "value": f"{base_acc:.4f}"})

    for frac, label in [(1.0, "n"), (0.5, "n/2"), (0.25, "n/4"),
                        (0.125, "n/8")]:
        a3 = A3Config(mode=A3Mode.CUSTOM, m_fraction=frac,
                      threshold_pct=0.0001)   # isolate candidate selection
        acc = float(memn2n.accuracy(params, test, cfg, a3))
        # candidate count on the first hop
        def cand_count(s, q):
            _, aux = memn2n.answer_with_a3(params, s, q, cfg, a3)
            return jnp.sum(aux["hop0"]["candidates"])
        counts = jax.vmap(cand_count)(test["sentences"][:64],
                                      test["question"][:64])
        rows.append({"name": "fig11_m_sweep",
                     "metric": f"acc_delta_pct_M={label}",
                     "value": f"{100*(acc-base_acc):.2f}"})
        rows.append({"name": "fig11_m_sweep",
                     "metric": f"mean_candidates_M={label}",
                     "value": f"{float(jnp.mean(counts)):.1f}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
