"""Serving-engine tick latency under staggered request arrivals.

Staggered arrivals are the adversarial case for the old per-position-
group decode loop: every active slot sat at a different position, so a
tick cost one jitted dispatch (plus a full-cache merge copy) *per slot*.
The ragged single-dispatch engine pays one dispatch and zero merge
copies regardless of skew — this benchmark measures per-tick latency and
tokens/sec on exactly that workload and writes machine-readable
``BENCH_serve.json`` to seed the perf trajectory across PRs.

The *tail-latency* scenario measures the chunked-admission claim: a
2k-token prompt admitted against 3 decoding slots stalls every slot for
one whole-prompt forward under per-admit prefill, but only one
``prefill_chunk`` dispatch per tick under chunked admission. Recorded
both ways: ``worst_over_median`` (vs the median measured tick,
admission window included — stays ~<=2x chunked) and
``worst_over_decode_median`` (vs the decode-only baseline — chunked is
a constant multiple set by the chunk size, independent of prompt
length, where whole-prompt scales with the prompt).

The *hybrid tail-latency* scenario runs the same bounded-tail claim
through a recurrent/hybrid arch (RG-LRU pattern): chunked admission
goes through the identical mixer-state dispatch, so the chunked
worst-tick ratio must be set by the chunk size and independent of
prompt length (measured at two prompt lengths;
``chunked_ratio_growth`` ~ 1), while whole-prompt admission scales.

The *decode-block sweep* measures the multi-step scanned decode claim:
at ``decode_block`` in {1, 8, 32}, T decode steps run device-resident
per dispatch (in-graph sampling + in-graph A^3 re-sort) and the host
syncs once per block, so ``syncs_per_token`` falls as ~1/T and
``per_token_ms`` improves monotonically from T=1 to T=8 as dispatch +
sync overhead amortizes.

The *prefix-reuse* scenario measures the paged prefix-cache claim: 16
requests sharing a 1k-token system prompt admit cold (every request
re-prefills the prefix) vs warm (the trie matches the prefix, one
gather dispatch restores it, only the suffix prefills) — warm
admission's ``prefill_tokens`` collapses to the suffix, with the exact
accounting identity ``prefill_tokens_cold == prefill_tokens_warm +
prefix_tokens_reused`` asserted in the payload.

The *l2-eviction-pressure* scenario measures the host-RAM L2 tier
claim: Zipf-popular shared prefixes whose working set is ~4x the device
page budget thrash the L1 trie; with the L2 tier, evictions demote to
checksummed host blobs and later lookups promote them back, recovering
the reuse L1-only loses — with the exact token-accounting identity
asserted for both tiers, identical generations everywhere, and
``l2_integrity_drops == 0`` on the fault-free run.

The *overload-shed* scenario measures the bounded-admission claim:
requests arriving at ~2x service capacity run against an unbounded
queue vs ``max_queue=8`` + reject-new shedding. Unbounded, late
arrivals inherit the whole backlog (p99 sojourn scales with run
length); bounded, overflow terminates REJECTED at submit and admitted
requests' p99 stays set by the config, not the overload duration.

  PYTHONPATH=src python benchmarks/bench_serve_latency.py \
      [--slots 4] [--requests 8] [--stagger 2] [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import time

import jax
import numpy as np

from repro.config import A3Config, AttentionKind, BlockKind, ModelConfig
from repro.models import decoder
from repro.serve.engine import ServeEngine

TINY = ModelConfig("bench-tiny", "dense", num_layers=4, d_model=128,
                   num_heads=8, num_kv_heads=4, d_ff=256, vocab_size=512,
                   head_dim=32, dtype="float32")
# hybrid recurrent arch (recurrentgemma-like RG-LRU pattern): chunked
# admission must bound tail ticks here too — the mixer-state interface
# carries the conv tail + LRU hidden state across chunk boundaries
TINY_HYBRID = ModelConfig("bench-tiny-hybrid", "hybrid", num_layers=3,
                          d_model=128, num_heads=8, num_kv_heads=4,
                          d_ff=256, vocab_size=512, head_dim=32,
                          attention_kind=AttentionKind.SLIDING,
                          window_size=64,
                          block_pattern=(BlockKind.RGLRU, BlockKind.RGLRU,
                                         BlockKind.ATTENTION),
                          act="gelu", dtype="float32")
# dispatch-pipeline scenario arch: small enough that host
# orchestration per tick (the thing the pipeline optimizes) is
# comparable to the model math instead of drowned by it
NANO = ModelConfig("bench-nano", "dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                   head_dim=16, dtype="float32")


def run_staggered(params, *, slots: int, requests: int, stagger: int,
                  prompt_len: int, max_new: int, max_len: int,
                  a3: A3Config) -> dict:
    """Submit ``requests`` prompts of varying length, one every
    ``stagger`` ticks, and time each engine tick."""
    eng = ServeEngine(params, TINY, slots=slots, max_len=max_len, a3=a3)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, TINY.vocab_size,
                            size=prompt_len + 3 * (i % 5))
               for i in range(requests)]
    # warm the decode jit (first tick compiles) before timing
    w = eng.submit(prompts[0][:prompt_len], max_new_tokens=2)
    eng.run_to_completion()
    assert eng.result(w) is not None
    warm_dispatches = eng.stats["decode_dispatches"]
    warm_steps = eng.stats["decode_steps"]

    pending = list(enumerate(prompts))
    tick_times = []
    uids, tick = [], 0
    t_start = time.perf_counter()
    while pending or eng._queue or any(s.active for s in eng.slots):
        if pending and tick % stagger == 0:
            i, p = pending.pop(0)
            uids.append(eng.submit(p, max_new_tokens=max_new))
        t0 = time.perf_counter()
        eng.step()
        jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
        tick_times.append(time.perf_counter() - t0)
        tick += 1
        if tick > 10_000:
            raise RuntimeError("benchmark did not converge")
    wall = time.perf_counter() - t_start

    new_tokens = sum(len(eng.result(u) or []) for u in uids)
    ts = np.asarray(tick_times)
    dispatches = eng.stats["decode_dispatches"] - warm_dispatches
    ticks_advanced = max(eng.stats["decode_steps"] - warm_steps, 1)
    return {
        "ticks": len(tick_times),
        "wall_s": wall,
        "new_tokens": new_tokens,
        "tok_per_s": new_tokens / wall,
        "tick_ms_p50": float(np.percentile(ts, 50) * 1e3),
        "tick_ms_p90": float(np.percentile(ts, 90) * 1e3),
        "tick_ms_mean": float(ts.mean() * 1e3),
        "decode_dispatches": dispatches,
        "decode_ticks": ticks_advanced,
        "dispatches_per_tick": dispatches / ticks_advanced,
    }


def compare_dispatch_schemes(params, *, slots: int, max_len: int) -> dict:
    """Micro-compare the decode hot path: ONE ragged dispatch for skewed
    slots vs the pre-ragged scheme (one scalar-pos dispatch per position
    group, each followed by the full-cache ``jnp.where`` merge)."""
    import jax.numpy as jnp
    from repro.serve.engine import make_serve_step

    rng = np.random.default_rng(1)
    pos_np = np.asarray([8 + 7 * i for i in range(slots)], np.int32)
    toks = jnp.asarray(rng.integers(0, TINY.vocab_size, slots), jnp.int32)
    cache = decoder.init_cache(TINY, slots, max_len)

    ragged = jax.jit(make_serve_step(TINY))
    scalar = jax.jit(make_serve_step(TINY))

    def ragged_tick(cache):
        logits, cache = ragged(params, cache, toks, jnp.asarray(pos_np))
        return logits, cache

    def grouped_tick(cache):
        logits = None
        for si in range(slots):          # worst case: every slot skewed
            lg, new_cache = scalar(params, cache, toks,
                                   jnp.int32(int(pos_np[si])))
            sel = jnp.arange(slots) == si
            cache = jax.tree.map(
                lambda new, old: jnp.where(
                    sel.reshape((1, slots) + (1,) * (new.ndim - 2)),
                    new, old), new_cache, cache)
            logits = lg
        return logits, cache

    def time_tick(fn, cache, iters=20, warmup=3):
        for _ in range(warmup):
            out, cache = fn(cache)
        jax.block_until_ready(out)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            out, cache = fn(cache)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts) * 1e3)

    ragged_ms = time_tick(ragged_tick, cache)
    grouped_ms = time_tick(grouped_tick, decoder.init_cache(TINY, slots,
                                                            max_len))
    return {
        "ragged_tick_ms": ragged_ms,
        "grouped_tick_ms": grouped_ms,
        "speedup": grouped_ms / ragged_ms,
    }


def run_tail_latency(params, *, slots: int = 4, prompt_len: int = 2048,
                     chunk: int = 64, a3: A3Config = A3Config(),
                     model: ModelConfig = TINY) -> dict:
    """Tail-tick latency: one ``prompt_len``-token prompt admitted
    mid-stream against ``slots - 1`` actively decoding slots.

    Whole-prompt admission (an explicit max_len-sized chunk, so the
    prompt admits in one dispatch; ``prefill_chunk=None`` defaults to a
    capped chunk and would not reproduce the stall) blocks every
    decoding slot for the entire prompt forward on the admission tick;
    chunked admission bounds the stall to one ``chunk``-token dispatch
    per tick. Reports worst-tick / median-tick for both modes — the
    chunked ratio is the bounded-tail claim (no tick should exceed ~2x
    the median). ``model`` selects the arch — the hybrid recurrent
    scenario runs the same workload through RG-LRU + attention
    segments."""
    vocab = model.vocab_size
    max_len = prompt_len + 64
    results = {}
    for label, ch in (("whole_prompt", max_len), ("chunked", chunk)):
        eng = ServeEngine(params, model, slots=slots, max_len=max_len,
                          a3=a3, prefill_chunk=ch)
        rng = np.random.default_rng(1)
        # warm both jitted dispatches (first prefill/decode tick
        # compiles; dispatch shapes are prompt-length-independent in
        # both modes — whole-prompt admission is a max_len-sized chunk)
        w = eng.submit(rng.integers(0, vocab, size=12), max_new_tokens=3)
        eng.run_to_completion()
        assert eng.result(w) is not None

        # slots-1 short requests decode steadily with plenty of budget
        for _ in range(slots - 1):
            eng.submit(rng.integers(0, vocab, size=12),
                       max_new_tokens=max_len)
        long_prompt = rng.integers(0, vocab, size=prompt_len)

        def tick():
            t0 = time.perf_counter()
            eng.step()
            jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
            return time.perf_counter() - t0

        for _ in range(3):
            tick()                    # untimed settle: admission + warmup
        gc.disable()                  # GC pauses are not engine latency
        try:
            baseline = [tick() for _ in range(10)]   # steady decode-only
            uid = eng.submit(long_prompt, max_new_tokens=4)
            overlap = []
            while eng.result(uid) is None:
                overlap.append(tick())
                if len(overlap) > 10_000:
                    raise RuntimeError("tail benchmark did not converge")
        finally:
            gc.enable()
            gc.collect()
        ts = np.asarray(baseline + overlap)
        med = float(np.percentile(ts, 50))
        base_med = float(np.percentile(baseline, 50))
        worst = float(ts.max())
        results[label] = {
            "ticks_measured": len(ts),
            "admission_ticks": len(overlap),
            "decode_tick_ms_p50": base_med * 1e3,
            "tick_ms_p50": med * 1e3,
            "tick_ms_worst": worst * 1e3,
            # comparable across modes: worst tick vs the decode-only
            # baseline (chunked: a constant ~chunk-sized multiple,
            # independent of prompt length; whole-prompt: scales with
            # the prompt)
            "worst_over_decode_median": worst / base_med,
            # steady-state-under-admission-load view (median includes
            # the admission-window ticks)
            "worst_over_median": worst / med,
            "prefill_dispatches": eng.stats["prefill_dispatches"],
            "ticks": eng.stats["ticks"],
        }
    results["config"] = {"slots": slots, "prompt_len": prompt_len,
                         "chunk": chunk, "arch": model.name}
    return results


def run_tail_latency_hybrid(*, slots: int = 4, chunk: int = 64,
                            prompt_lens=(256, 1024)) -> dict:
    """The recurrent-arch bounded-tail claim: the hybrid RG-LRU arch
    admits through the same chunked path (mixer-state interface), so
    its worst-tick / decode-median ratio is set by the chunk size and
    INDEPENDENT of prompt length, while whole-prompt admission scales
    with the prompt. Runs the tail scenario at two prompt lengths and
    reports the chunked ratio's growth between them (~1.0 = bounded)."""
    params = decoder.init_params(jax.random.PRNGKey(1), TINY_HYBRID)
    out = {}
    for plen in prompt_lens:
        out[str(plen)] = run_tail_latency(params, slots=slots,
                                          prompt_len=plen, chunk=chunk,
                                          model=TINY_HYBRID)
    lo, hi = str(prompt_lens[0]), str(prompt_lens[-1])
    out["chunked_ratio_growth"] = (
        out[hi]["chunked"]["worst_over_decode_median"]
        / out[lo]["chunked"]["worst_over_decode_median"])
    out["whole_prompt_ratio_growth"] = (
        out[hi]["whole_prompt"]["worst_over_decode_median"]
        / out[lo]["whole_prompt"]["worst_over_decode_median"])
    out["config"] = {"slots": slots, "chunk": chunk,
                     "prompt_lens": list(prompt_lens),
                     "arch": TINY_HYBRID.name}
    return out


def run_decode_block_sweep(params, *, slots: int = 4, requests: int = 4,
                           prompt_len: int = 16, max_new: int = 65,
                           max_len: int = 128,
                           blocks=(1, 8, 32)) -> dict:
    """Multi-step scanned decode: per-token tick latency and host syncs
    per token at ``decode_block`` in {1, 8, 32} on decode-heavy traffic.

    decode_block=1 is the old engine's cadence: every generated token
    pays a full dispatch + blocking host read round-trip. Larger blocks
    run T steps device-resident per dispatch (in-graph sampling +
    re-sort) and sync once per block, so ``syncs_per_token`` falls as
    ~1/T and the decode-phase ``per_token_ms`` drops as the per-dispatch
    overhead amortizes. ``max_new`` is chosen so every block size
    divides the decode-step count evenly (65 -> 64 steps after the
    prefill token): partial blocks still execute their masked tail
    steps, which would charge T=32 for work it throws away and muddy
    the overhead-amortization comparison this scenario isolates.
    Requests admit upfront via one chunked-prefill dispatch and the
    measured window starts after the admission tick, so the per-token
    figure is pure decode."""
    results = {}
    for t in blocks:
        eng = ServeEngine(params, TINY, slots=slots, max_len=max_len,
                          decode_block=t, prefill_chunk=prompt_len)
        rng = np.random.default_rng(0)
        # warm every dispatch shape (prefill + blocked decode compile)
        w = eng.submit(rng.integers(0, TINY.vocab_size, size=prompt_len),
                       max_new_tokens=2 * t)
        eng.run_to_completion()
        assert eng.result(w) is not None
        eng.stats = {k: 0 for k in eng.stats}

        uids = [eng.submit(rng.integers(0, TINY.vocab_size,
                                        size=prompt_len),
                           max_new_tokens=max_new)
                for _ in range(requests)]
        eng.step()                 # admission tick: prefill + first block
        jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
        admitted = sum(len(s.generated) for s in eng.slots if s.active)
        t0 = time.perf_counter()
        eng.run_to_completion()
        jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
        wall = time.perf_counter() - t0
        new_tokens = sum(len(eng.result(u) or []) for u in uids)
        decode_tokens = new_tokens - admitted
        results[str(t)] = {
            "decode_block": t,
            "decode_wall_s": wall,
            "new_tokens": new_tokens,
            "decode_tokens": decode_tokens,
            "per_token_ms": wall / decode_tokens * 1e3,
            "tok_per_s": decode_tokens / wall,
            "host_syncs": eng.stats["host_syncs"],
            "syncs_per_token": eng.stats["host_syncs"] / new_tokens,
            "decode_dispatches": eng.stats["decode_dispatches"],
            "decode_blocks": eng.stats["decode_blocks"],
            "ticks": eng.stats["ticks"],
        }
    ks = [str(t) for t in blocks]
    results["speedup_1_to_8"] = (results[ks[0]]["per_token_ms"]
                                 / results["8"]["per_token_ms"]
                                 if "8" in results else None)
    results["config"] = {"slots": slots, "requests": requests,
                         "prompt_len": prompt_len, "max_new": max_new,
                         "max_len": max_len, "blocks": list(blocks)}
    return results


def run_dispatch_pipeline(*, slots: int = 4, requests: int = 4,
                          prompt_len: int = 16, max_new: int = 65,
                          max_len: int = 128, blocks=(1, 8),
                          depths=(0, 1, 2, 3), reps: int = 3,
                          device_latency_s: float = 0.0015) -> dict:
    """The pipelined tick loop: deferred async ring harvest vs the
    synchronous engine, at ``decode_block`` in {1, 8}.

    ``pipeline_depth=0`` harvests every block's ring with a blocking
    host read before the next tick plans — the host sits in the
    device's shadow once per block. ``depth=d`` keeps up to ``d``
    harvests in flight behind the dispatch stream (the next block's
    input tokens chain through the device-resident carry) and only
    force-lands the over-depth oldest ring before each dispatch, so
    the host plans/dispatches ahead of the device instead of waiting
    out every block.

    **Measurement.** Host/device overlap needs the device to make
    progress while the host runs — on this repo's CPU-only CI hosts,
    XLA "device" compute timeshares the very cores the tick loop runs
    on (often a single core), so the overlap the pipeline creates is
    physically invisible in raw wall clock there: total work is
    conserved and tok/s lands ~1.0x regardless of depth. The scenario
    therefore measures steady-state decode throughput under the
    engine's ``virtual_device_latency_s`` accelerator emulation — each
    decode block's ring becomes readable ``device_latency_s`` after
    dispatch, via a GIL-releasing readiness floor that models an
    accelerator completing asynchronously off-host (the regime A^3 /
    NOVA target, where orchestration — not FLOPs — is the ceiling).
    The synchronous engine serializes on that latency once per block;
    the pipelined loop hides it behind tick work. Raw un-emulated wall
    tok/s is reported alongside (``raw_wall_block1``) for honesty, not
    asserted. The scenario asserts the acceptance criteria in-line:

    * ``tokens_match`` — every depth generates token-for-token the
      synchronous engine's streams (deferral and the emulated latency
      are scheduling only),
    * ``syncs_per_token`` strictly lower than synchronous at EVERY
      pipelined depth, for both block sizes,
    * steady-state decode throughput at ``decode_block=1`` reaches
      >= 1.2x synchronous at the best depth (block=1 is where the
      per-token round-trip dominates; at block=8 the sync is already
      1/8th as frequent, so deferral mostly trims stall count).

    Runs use the NANO arch so host orchestration (the thing the
    pipeline optimizes) is not drowned by model math; wall times are
    best-of-``reps``."""
    rng_seed = 0
    params = decoder.init_params(jax.random.PRNGKey(0), NANO)

    def once(block, depth, latency):
        eng = ServeEngine(params, NANO, slots=slots, max_len=max_len,
                          decode_block=block, prefill_chunk=prompt_len,
                          pipeline_depth=depth,
                          virtual_device_latency_s=latency)
        rng = np.random.default_rng(rng_seed)
        w = eng.submit(rng.integers(0, NANO.vocab_size, size=prompt_len),
                       max_new_tokens=2 * block)
        eng.run_to_completion()
        assert eng.result(w) is not None
        eng.stats = {k: 0 for k in eng.stats}
        uids = [eng.submit(rng.integers(0, NANO.vocab_size,
                                        size=prompt_len),
                           max_new_tokens=max_new)
                for _ in range(requests)]
        eng.step()                 # admission tick: prefill + first block
        jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
        t0 = time.perf_counter()
        eng.run_to_completion()
        jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
        wall = time.perf_counter() - t0
        toks = [eng.result(u) for u in uids]
        return wall, toks, dict(eng.stats)

    def best_of(block, depth, latency):
        wall = toks = stats = None
        for _ in range(reps):
            w, t, s = once(block, depth, latency)
            if wall is None or w < wall:
                wall, toks, stats = w, t, s
        return wall, toks, stats

    results = {}
    for block in blocks:
        per_depth, ref_toks = {}, None
        for depth in depths:
            wall, toks, stats = best_of(block, depth, device_latency_s)
            if depth == 0:
                ref_toks = toks
            new_tokens = sum(len(t or []) for t in toks)
            match = toks == ref_toks
            assert match, (block, depth)         # deferral never changes tokens
            per_depth[str(depth)] = {
                "pipeline_depth": depth,
                "decode_wall_s": wall,
                "new_tokens": new_tokens,
                "tok_per_s": new_tokens / wall,
                "host_syncs": stats["host_syncs"],
                "host_sync_stalls": stats["host_sync_stalls"],
                "syncs_per_token": stats["host_syncs"] / new_tokens,
                "decode_dispatches": stats["decode_dispatches"],
                "tokens_match": match,
            }
        sync0 = per_depth["0"]["syncs_per_token"]
        for depth in depths[1:]:
            assert per_depth[str(depth)]["syncs_per_token"] < sync0, (
                block, depth)                    # strictly fewer blocking syncs
        best = max((per_depth[str(d)] for d in depths[1:]),
                   key=lambda r: r["tok_per_s"])
        entry = {"depths": per_depth,
                 "best_depth": best["pipeline_depth"],
                 "speedup_vs_sync": (best["tok_per_s"]
                                     / per_depth["0"]["tok_per_s"]),
                 "stall_reduction_at_best": (
                     per_depth["0"]["host_sync_stalls"]
                     / max(1, best["host_sync_stalls"]))}
        results[str(block)] = entry
    # the headline acceptance number: decode_block=1 is the
    # per-token-round-trip regime the pipeline targets
    assert results["1"]["speedup_vs_sync"] >= 1.2, results["1"]
    # honesty row: the same workload with no emulated device latency.
    # On a host with cores to spare this tracks the emulated speedup;
    # on single-core CI it sits near 1.0x because XLA compute and the
    # tick loop timeshare one core and total work is conserved.
    raw = {}
    for depth in (0, results["1"]["best_depth"]):
        wall, toks, _ = best_of(1, depth, 0.0)
        raw[str(depth)] = sum(len(t or []) for t in toks) / wall
    results["raw_wall_block1"] = {
        "tok_per_s": raw,
        "speedup_vs_sync": raw[str(results["1"]["best_depth"])]
                           / raw["0"],
        "note": "no emulated latency; overlap needs a real async "
                "device or a spare host core to show in wall clock"}
    results["config"] = {"slots": slots, "requests": requests,
                         "prompt_len": prompt_len, "max_new": max_new,
                         "max_len": max_len, "blocks": list(blocks),
                         "depths": list(depths), "reps": reps,
                         "device_latency_s": device_latency_s,
                         "arch": NANO.name}
    return results


def run_prefix_reuse(params, *, shared_len: int = 1024, requests: int = 16,
                     suffix_len: int = 16, page_size: int = 64,
                     cache_pages: int = 64, chunk: int = 64,
                     max_new: int = 4) -> dict:
    """The paged prefix-cache claim: ``requests`` prompts sharing a
    ``shared_len``-token system prompt (distinct short suffixes) admit
    against a cold engine vs a prefix-cache-enabled one.

    Cold admission re-prefills the shared prefix for every request;
    warm admission walks the trie, gathers the matched pages in ONE
    jitted copy dispatch, and prefills only the suffix — so warm
    ``prefill_tokens`` collapses from ~requests x shared_len to
    ~shared_len + requests x suffix, ``prefix_tokens_reused`` accounts
    for the difference exactly
    (``prefill_tokens_cold == prefill_tokens_warm + prefix_tokens_reused``),
    and per-request admission wall time drops accordingly. Requests are
    submitted one at a time (each runs to completion before the next
    arrives) so every warm request sees a fully recorded prefix — the
    adversarial-for-cold, friendly-for-warm serving shape of a shared
    system prompt."""
    rng = np.random.default_rng(2)
    shared = rng.integers(0, TINY.vocab_size, size=shared_len)
    prompts = [np.concatenate([shared,
                               rng.integers(0, TINY.vocab_size,
                                            size=suffix_len)])
               for _ in range(requests)]
    max_len = shared_len + suffix_len + max_new + 8
    results = {}
    for label, pages in (("cold", 0), ("warm", cache_pages)):
        eng = ServeEngine(params, TINY, slots=2, max_len=max_len,
                          prefill_chunk=chunk, page_size=page_size,
                          cache_pages=pages)
        # warm the jits (both prefill variants + decode) off the clock
        w = eng.submit(rng.integers(0, TINY.vocab_size, size=24),
                       max_new_tokens=2)
        eng.run_to_completion()
        assert eng.result(w) is not None
        base = dict(eng.stats)
        admit_s = []
        gc.disable()
        try:
            for p in prompts:
                t0 = time.perf_counter()
                u = eng.submit(p, max_new_tokens=max_new)
                eng.run_to_completion()
                jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
                admit_s.append(time.perf_counter() - t0)
                assert len(eng.result(u)) == max_new
        finally:
            gc.enable()
            gc.collect()
        ts = np.asarray(admit_s)
        results[label] = {
            "requests": requests,
            "prefill_tokens": eng.stats["prefill_tokens"]
            - base["prefill_tokens"],
            "prefill_dispatches": eng.stats["prefill_dispatches"]
            - base["prefill_dispatches"],
            "prefix_hits": eng.stats["prefix_hits"],
            "prefix_tokens_reused": eng.stats["prefix_tokens_reused"],
            "gather_dispatches": eng.stats["gather_dispatches"],
            "pages_recorded": eng.stats["pages_recorded"],
            "pages_evicted": eng.stats["pages_evicted"],
            "request_ms_p50": float(np.percentile(ts, 50) * 1e3),
            "request_ms_mean": float(ts.mean() * 1e3),
            # first request is always cold (it records the pages); the
            # steady-state figure excludes it
            "warm_request_ms_mean": float(ts[1:].mean() * 1e3),
            "first_request_ms": float(ts[0] * 1e3),
        }
    c, w = results["cold"], results["warm"]
    results["tokens_invariant_holds"] = (
        c["prefill_tokens"] == w["prefill_tokens"]
        + w["prefix_tokens_reused"])
    # the identity is load-bearing, not informational: fail the run
    # rather than publish a payload that records its own violation
    assert results["tokens_invariant_holds"], (c, w)
    results["reused_per_hit"] = (w["prefix_tokens_reused"]
                                 / max(w["prefix_hits"], 1))
    results["reuse_fraction_of_shared"] = (
        results["reused_per_hit"] / shared_len)
    results["warm_admission_speedup"] = (c["warm_request_ms_mean"]
                                         / w["warm_request_ms_mean"])
    results["config"] = {"shared_len": shared_len, "requests": requests,
                         "suffix_len": suffix_len, "page_size": page_size,
                         "cache_pages": cache_pages, "chunk": chunk,
                         "max_new": max_new, "arch": TINY.name}
    return results


def run_kv_quant(params, *, shared_len: int = 512, requests: int = 8,
                 suffix_len: int = 16, page_size: int = 64,
                 cache_pages: int = 64, chunk: int = 64,
                 max_new: int = 4) -> dict:
    """The quantized-cache claim: the same shared-prefix workload as
    ``run_prefix_reuse`` under ``kv_quant`` in {none, int8}.

    The int8 pool stores KV pages (and A^3 sorted-key snapshots) as
    int8 with per-page fp32 scales, so at a FIXED ``cache_pages`` budget
    its HBM footprint shrinks ~4x — equivalently, the pages held at
    equal HBM (cache residency) grow by the recorded
    ``residency_ratio_at_equal_hbm`` (>= 2 is load-bearing, asserted).
    The warm gather reads 1 byte/element instead of 4
    (``gather_bytes_per_reused_token``), dequantizing inside the same
    one-dispatch copy. Generations are recorded for both variants and
    compared (``tokens_match`` — expected True on this workload: the
    quantization error sits far below greedy argmax margins)."""
    rng = np.random.default_rng(3)
    shared = rng.integers(0, TINY.vocab_size, size=shared_len)
    prompts = [np.concatenate([shared,
                               rng.integers(0, TINY.vocab_size,
                                            size=suffix_len)])
               for _ in range(requests)]
    max_len = shared_len + suffix_len + max_new + 8
    results = {}
    outs = {}
    for label in ("none", "int8"):
        eng = ServeEngine(params, TINY, slots=2, max_len=max_len,
                          prefill_chunk=chunk, page_size=page_size,
                          cache_pages=cache_pages, kv_quant=label)
        w = eng.submit(rng.integers(0, TINY.vocab_size, size=24),
                       max_new_tokens=2)
        eng.run_to_completion()
        assert eng.result(w) is not None
        base = dict(eng.stats)
        admit_s = []
        outs[label] = []
        gc.disable()
        try:
            for p in prompts:
                t0 = time.perf_counter()
                u = eng.submit(p, max_new_tokens=max_new)
                eng.run_to_completion()
                jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
                admit_s.append(time.perf_counter() - t0)
                outs[label].append(eng.result(u))
        finally:
            gc.enable()
            gc.collect()
        ts = np.asarray(admit_s)
        pool_bytes = sum(l.nbytes
                         for l in jax.tree.leaves(eng._pc.pool))
        # pool-side bytes a warm gather reads per reused token: the
        # per-token share of every page leaf (K/V payload + scales)
        per_token = pool_bytes / (cache_pages * page_size)
        results[label] = {
            "prefix_hits": eng.stats["prefix_hits"],
            "prefix_tokens_reused": eng.stats["prefix_tokens_reused"],
            "pages_recorded": eng.stats["pages_recorded"],
            "pool_bytes": pool_bytes,
            "pool_bytes_per_page": pool_bytes / cache_pages,
            "hbm_bytes_per_cached_token": per_token,
            "gather_bytes_per_reused_token": per_token,
            "warm_request_ms_mean": float(ts[1:].mean() * 1e3),
            "warm_tok_s": float((len(ts) - 1) * max_new
                                / max(ts[1:].sum(), 1e-9)),
            "first_request_ms": float(ts[0] * 1e3),
        }
    n, q = results["none"], results["int8"]
    # equal-HBM residency: pages the int8 pool fits in the fp pool's
    # footprint, relative to the fp pool's own page count
    results["residency_ratio_at_equal_hbm"] = (n["pool_bytes_per_page"]
                                               / q["pool_bytes_per_page"])
    results["gather_bytes_ratio"] = (n["gather_bytes_per_reused_token"]
                                     / q["gather_bytes_per_reused_token"])
    # >= 2x residency at equal HBM is the acceptance gate for the knob —
    # fail the bench rather than publish a payload violating it
    assert results["residency_ratio_at_equal_hbm"] >= 2.0, results
    assert results["gather_bytes_ratio"] >= 2.0, results
    results["tokens_match"] = outs["none"] == outs["int8"]
    results["config"] = {"shared_len": shared_len, "requests": requests,
                         "suffix_len": suffix_len, "page_size": page_size,
                         "cache_pages": cache_pages, "chunk": chunk,
                         "max_new": max_new, "arch": TINY.name}
    return results


def run_l2_eviction_pressure(params, *, n_prefixes: int = 8,
                             shared_len: int = 256, requests: int = 24,
                             suffix_len: int = 16, page_size: int = 64,
                             cache_pages: int = 8, chunk: int = 64,
                             max_new: int = 4,
                             l2_bytes: int = 1 << 28) -> dict:
    """The host-RAM L2 tier claim: Zipf-popular shared prefixes whose
    working set is ~4x the device page budget (``cache_pages`` holds
    1/4 of it), so the L1 trie thrashes — pages recorded for one prefix
    evict another's before it returns.

    Three engines on the SAME Zipf-sampled request stream: ``cold``
    (no cache), ``l1_only`` (device pages only — evictions free the
    page), and ``l2`` (evictions demote to the checksummed host store;
    later lookups promote verified blobs back). L1-only under thrash
    loses most reuse; the L2 tier recovers it at the cost of a
    host->device copy instead of a full prefix re-prefill — recorded as
    ``l2_hit_speedup_vs_cold`` (mean admission latency, cold /
    L2-enabled). Deterministic claims asserted in the payload: the
    accounting identity ``prefill_tokens_cold == prefill_tokens_warm +
    prefix_tokens_reused`` holds exactly for BOTH cached variants, the
    L2 engine reuses strictly more tokens than L1-only, every variant
    emits identical generations, and a fault-free run counts
    ``l2_integrity_drops == 0``."""
    rng = np.random.default_rng(11)
    prefixes = [rng.integers(0, TINY.vocab_size, size=shared_len)
                for _ in range(n_prefixes)]
    # Zipf popularity over the prefix set (s ~ 1.1)
    w = 1.0 / np.arange(1, n_prefixes + 1) ** 1.1
    w /= w.sum()
    picks = rng.choice(n_prefixes, size=requests, p=w)
    prompts = [np.concatenate([prefixes[k],
                               rng.integers(0, TINY.vocab_size,
                                            size=suffix_len)])
               for k in picks]
    max_len = shared_len + suffix_len + max_new + 8
    working_set_pages = n_prefixes * (shared_len // page_size)
    results = {}
    outs = {}
    for label, pages, l2 in (("cold", 0, 0),
                             ("l1_only", cache_pages, 0),
                             ("l2", cache_pages, l2_bytes)):
        eng = ServeEngine(params, TINY, slots=2, max_len=max_len,
                          prefill_chunk=chunk, page_size=page_size,
                          cache_pages=pages, l2_bytes=l2)
        wu = eng.submit(rng.integers(0, TINY.vocab_size, size=24),
                        max_new_tokens=2)
        eng.run_to_completion()
        assert eng.result(wu) is not None
        base = dict(eng.stats)
        admit_s = []
        outs[label] = []
        gc.disable()
        try:
            for p in prompts:
                t0 = time.perf_counter()
                u = eng.submit(p, max_new_tokens=max_new)
                eng.run_to_completion()
                jax.block_until_ready(jax.tree.leaves(eng.cache)[0])
                admit_s.append(time.perf_counter() - t0)
                outs[label].append(eng.result(u))
        finally:
            gc.enable()
            gc.collect()
        ts = np.asarray(admit_s)
        results[label] = {
            "prefill_tokens": eng.stats["prefill_tokens"]
            - base["prefill_tokens"],
            "prefix_hits": eng.stats["prefix_hits"],
            "prefix_tokens_reused": eng.stats["prefix_tokens_reused"],
            "pages_recorded": eng.stats["pages_recorded"],
            "pages_evicted": eng.stats["pages_evicted"],
            "l2_spills": eng.stats.get("l2_spills", 0),
            "l2_hits": eng.stats.get("l2_hits", 0),
            "l2_evictions": eng.stats.get("l2_evictions", 0),
            "l2_integrity_drops": eng.stats.get("l2_integrity_drops", 0),
            "l2_bytes_used": (eng._pc.l2.bytes_used
                              if pages and eng._pc.l2 is not None else 0),
            "request_ms_p50": float(np.percentile(ts, 50) * 1e3),
            "request_ms_mean": float(ts.mean() * 1e3),
        }
    c, l1, l2r = results["cold"], results["l1_only"], results["l2"]
    # reuse removes work, never changes it — exact, for both tiers
    for r in (l1, l2r):
        assert c["prefill_tokens"] == (r["prefill_tokens"]
                                       + r["prefix_tokens_reused"]), \
            (c, r)
    results["tokens_invariant_holds"] = True
    # same generations everywhere: a promoted page is a copy
    assert outs["cold"] == outs["l1_only"] == outs["l2"]
    results["generations_match"] = True
    # fault-free run: every promotion verified clean
    assert l2r["l2_integrity_drops"] == 0, l2r
    # the tier must actually engage and recover thrashed reuse
    assert l2r["l2_hits"] > 0, l2r
    assert l2r["prefix_tokens_reused"] > l1["prefix_tokens_reused"], \
        (l1, l2r)
    results["l2_hit_speedup_vs_cold"] = (c["request_ms_mean"]
                                         / l2r["request_ms_mean"])
    results["l2_speedup_vs_l1_only"] = (l1["request_ms_mean"]
                                        / l2r["request_ms_mean"])
    results["reuse_recovered_tokens"] = (l2r["prefix_tokens_reused"]
                                         - l1["prefix_tokens_reused"])
    results["config"] = {"n_prefixes": n_prefixes,
                         "shared_len": shared_len, "requests": requests,
                         "suffix_len": suffix_len, "page_size": page_size,
                         "cache_pages": cache_pages,
                         "working_set_pages": working_set_pages,
                         "chunk": chunk, "max_new": max_new,
                         "l2_bytes": l2_bytes, "zipf_s": 1.1,
                         "arch": TINY.name}
    return results


def run_overload_shed(params, *, slots: int = 4, requests: int = 64,
                      prompt_len: int = 24, max_new: int = 16,
                      max_len: int = 128, max_queue: int = 8) -> dict:
    """The bounded-admission claim: arrivals at ~2x service capacity,
    unbounded queue vs ``max_queue`` + reject-new shedding.

    One request arrives every ``max_new // (2 * slots)`` ticks while a
    request occupies a slot for ~``1 + max_new`` ticks, so offered load
    is ~2x what the ``slots`` lanes can drain. Unbounded, the queue
    grows linearly for the whole run and late arrivals inherit the
    entire backlog in their latency — p99 sojourn time scales with the
    run length, not the service time. Bounded, overflow terminates
    REJECTED at submit (zero cost, zero queue time) and every ADMITTED
    request's sojourn stays within ``max_queue`` services of a lone
    request — the p99 the shedding engine reports is a property of the
    config, not of how long the overload lasted. Recorded per policy:
    finished/rejected counts, p50/p99 sojourn ms (admitted requests
    only), and the max queue depth observed."""
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, TINY.vocab_size, size=prompt_len)
               for _ in range(requests)]
    arrival_every = max(1, max_new // (2 * slots))
    results = {}
    for label, mq in (("unbounded", 0), ("shed", max_queue)):
        eng = ServeEngine(params, TINY, slots=slots, max_len=max_len,
                          max_queue=mq, shed_policy="reject-new")
        # warm the jits off the clock
        w = eng.submit(prompts[0][:8], max_new_tokens=2)
        eng.run_to_completion()
        assert eng.result(w) is not None
        base = dict(eng.stats)
        submit_s, finish_s = {}, {}
        uids = []
        tick = 0
        next_idx = 0
        max_depth = 0
        gc.disable()
        t0 = time.perf_counter()
        try:
            while next_idx < requests or eng.in_flight:
                if next_idx < requests and tick % arrival_every == 0:
                    u = eng.submit(prompts[next_idx],
                                   max_new_tokens=max_new)
                    submit_s[u] = time.perf_counter()
                    uids.append(u)
                    next_idx += 1
                eng.step()
                max_depth = max(max_depth, len(eng._queue))
                now = time.perf_counter()
                for u in uids:
                    if u not in finish_s and eng.status(u) == "finished":
                        finish_s[u] = now
                tick += 1
        finally:
            gc.enable()
            gc.collect()
        wall_s = time.perf_counter() - t0
        statuses = [eng.status(u) for u in uids]
        sojourn = np.asarray([finish_s[u] - submit_s[u]
                              for u in uids if u in finish_s])
        results[label] = {
            "max_queue": mq,
            "requests": requests,
            "finished": statuses.count("finished"),
            "rejected": statuses.count("rejected"),
            "ticks": tick,
            "wall_s": wall_s,
            "max_queue_depth": max_depth,
            "sojourn_ms_p50": float(np.percentile(sojourn, 50) * 1e3),
            "sojourn_ms_p99": float(np.percentile(sojourn, 99) * 1e3),
            "sojourn_ms_mean": float(sojourn.mean() * 1e3),
            "new_tokens": sum(len(eng.result(u) or []) for u in uids),
        }
        # nothing left behind: every submitted request reached a
        # terminal state and the conservation identity closed
        assert eng.in_flight == 0
        assert all(s in ("finished", "rejected") for s in statuses)
    u, s = results["unbounded"], results["shed"]
    results["p99_improvement"] = (u["sojourn_ms_p99"]
                                  / s["sojourn_ms_p99"])
    results["p50_improvement"] = (u["sojourn_ms_p50"]
                                  / s["sojourn_ms_p50"])
    results["config"] = {"slots": slots, "requests": requests,
                         "prompt_len": prompt_len, "max_new": max_new,
                         "max_len": max_len, "max_queue": max_queue,
                         "arrival_every_ticks": arrival_every,
                         "shed_policy": "reject-new", "arch": TINY.name}
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--stagger", type=int, default=2,
                    help="ticks between request arrivals (position skew)")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--tail-prompt-len", type=int, default=2048,
                    help="long-prompt length for the tail-latency scenario")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="admission-prefill chunk for the tail scenario")
    ap.add_argument("--a3", default="off",
                    choices=["off", "conservative", "aggressive"])
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "BENCH_serve.json"))
    args = ap.parse_args()

    a3 = {"off": A3Config(), "conservative": A3Config.conservative(),
          "aggressive": A3Config.aggressive()}[args.a3]
    params = decoder.init_params(jax.random.PRNGKey(0), TINY)
    res = run_staggered(params, slots=args.slots, requests=args.requests,
                        stagger=args.stagger, prompt_len=args.prompt_len,
                        max_new=args.max_new, max_len=args.max_len, a3=a3)
    cmp = compare_dispatch_schemes(params, slots=args.slots,
                                   max_len=args.max_len)
    tail = run_tail_latency(params, slots=args.slots,
                            prompt_len=args.tail_prompt_len,
                            chunk=args.prefill_chunk, a3=a3)
    tail_hybrid = run_tail_latency_hybrid(slots=args.slots,
                                          chunk=args.prefill_chunk)
    blocks = run_decode_block_sweep(params, slots=args.slots)
    pipeline = run_dispatch_pipeline(slots=args.slots)
    prefix = run_prefix_reuse(params)
    kv_quant = run_kv_quant(params)
    l2_pressure = run_l2_eviction_pressure(params)
    overload = run_overload_shed(params, slots=args.slots)
    payload = {
        "bench": "serve_latency_staggered",
        "arch": TINY.name,
        "config": {k: getattr(args, k) for k in
                   ("slots", "requests", "stagger", "prompt_len",
                    "max_new", "max_len", "a3")},
        "result": res,
        "dispatch_compare": cmp,
        "tail_latency": tail,
        "tail_latency_hybrid": tail_hybrid,
        "decode_block_sweep": blocks,
        "dispatch_pipeline": pipeline,
        "prefix_reuse": prefix,
        "kv_quant": kv_quant,
        "l2_eviction_pressure": l2_pressure,
        "overload_shed": overload,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main()
