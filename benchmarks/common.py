"""Shared benchmark utilities: a trained MemN2N on synthetic bAbI (the
paper's primary workload) + timing helpers.

Training is cached in-process and on disk (/tmp) so the figure
benchmarks (M sweep, T sweep, config comparison, quantization) all
evaluate the same model, as the paper does.
"""
from __future__ import annotations

import functools
import os
import pickle
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import A3Config
from repro.data.babi import generate_babi, make_task
from repro.models import memn2n
from repro.optim.adamw import adamw_init, adamw_update
from repro.config import OptimizerConfig

_CACHE = "/tmp/repro_bench_memn2n.pkl"


@functools.lru_cache(maxsize=None)
def trained_memn2n(num_statements: int = 48, steps: int = 800,
                   batch: int = 64, seed: int = 0):
    """Returns (params, cfg, task, test_batch). Cached on disk.

    Recipe (validated to 100% exact-attention accuracy): 3 hops, d=64,
    AdamW lr 1e-2 cosine to 0.3, 800 steps of fresh synthetic stories.
    """
    task = make_task(num_actors=64, num_places=16, max_sentences=64,
                     max_words=8)
    cfg = memn2n.MemN2NConfig(vocab_size=task.vocab_size, d_embed=64,
                              num_hops=3, max_sentences=task.max_sentences,
                              max_words=task.max_words)
    key_cache = (num_statements, steps, batch, seed)
    if os.path.exists(_CACHE):
        try:
            with open(_CACHE, "rb") as f:
                saved = pickle.load(f)
            if saved["key"] == key_cache:
                params = jax.tree.map(jnp.asarray, saved["params"])
                test = saved["test"]
                return params, cfg, task, test
        except Exception:
            pass

    params = memn2n.init_params(jax.random.PRNGKey(seed), cfg)
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=10, total_steps=steps,
                           weight_decay=0.0, grad_clip_norm=1.0,
                           min_lr_ratio=0.3)
    opt = adamw_init(params, ocfg)

    @jax.jit
    def step(params, opt, batch_):
        loss, grads = jax.value_and_grad(memn2n.loss_fn)(params, batch_, cfg)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    for i in range(steps):
        b = generate_babi(task, batch, num_statements, seed=1000 + i)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step(params, opt, b)

    test = generate_babi(task, 512, num_statements, seed=9)
    test = {k: np.asarray(v) for k, v in test.items()}
    with open(_CACHE, "wb") as f:
        pickle.dump({"key": key_cache,
                     "params": jax.tree.map(np.asarray, params),
                     "test": test}, f)
    return params, cfg, task, {k: jnp.asarray(v) for k, v in test.items()}


def time_fn(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall time (s) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def rows_to_csv(rows) -> str:
    lines = ["name,metric,value"]
    for r in rows:
        lines.append(f"{r['name']},{r['metric']},{r['value']}")
    return "\n".join(lines)
