"""Paper Fig. 13: the two named approximation configs —
conservative (M=n/2, T=5%) and aggressive (M=n/8, T=10%) — accuracy
change and true top-2 recall after approximation.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import trained_memn2n
from repro.config import A3Config
from repro.models import memn2n


def _top2_recall(params, cfg, test, a3) -> float:
    """Fraction of true top-2 score entries that survive approximation
    (paper Fig. 13b, top-2 for bAbI)."""
    def one(s, q):
        _, aux = memn2n.answer_with_a3(params, s, q, cfg, a3)
        scores = aux["hop0"]["scores"]
        kept = aux["hop0"]["kept"]
        _, top2 = jax.lax.top_k(scores, 2)
        return jnp.mean(kept[top2].astype(jnp.float32))
    r = jax.vmap(one)(test["sentences"][:128], test["question"][:128])
    return float(jnp.mean(r))


def run(num_statements: int = 48) -> List[dict]:
    params, cfg, task, test = trained_memn2n(num_statements)
    rows: List[dict] = []
    base = float(memn2n.accuracy(params, test, cfg))
    rows.append({"name": "fig13_configs", "metric": "acc_exact",
                 "value": f"{base:.4f}"})
    for label, a3 in [("conservative", A3Config.conservative()),
                      ("aggressive", A3Config.aggressive())]:
        acc = float(memn2n.accuracy(params, test, cfg, a3))
        rec = _top2_recall(params, cfg, test, a3)
        rows.append({"name": "fig13_configs",
                     "metric": f"acc_delta_pct_{label}",
                     "value": f"{100*(acc-base):.2f}"})
        rows.append({"name": "fig13_configs",
                     "metric": f"top2_recall_{label}",
                     "value": f"{rec:.3f}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
