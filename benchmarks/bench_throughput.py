"""Paper Fig. 14: throughput/latency of the attention operation —
exact vs approximate (conservative / aggressive).

On real TPU hardware the win comes from the block-sparse kernel skipping
candidate-free tiles. This container is CPU-only, so we report BOTH:
  * measured wall time of the jitted reference paths (CPU; indicative),
  * the FLOP-reduction accounting (`flop_savings`) that determines the
    TPU-side speedup of the score/output stages (paper's operation-count
    argument, SSVI-C).
Shapes follow the paper: n=320, d=64 (BERT/SQuAD-like self-attention),
and a batched single-query (MemN2N-like) case.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.config import A3Config
from repro.core.a3_attention import a3_self_attention, flop_savings


def run(n: int = 320, d: int = 64) -> List[dict]:
    rows: List[dict] = []
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (n, d)) * 0.5
    k = jax.random.normal(kk, (n, d)) * 0.5
    v = jax.random.normal(kv, (n, d)) * 0.5

    configs = [("exact", A3Config()),
               ("conservative", A3Config.conservative()),
               ("aggressive", A3Config.aggressive())]
    base_t = None
    for label, a3 in configs:
        fn = jax.jit(lambda q, k, v, a3=a3: a3_self_attention(q, k, v, a3)[0])
        t = time_fn(fn, q, k, v, iters=10)
        rows.append({"name": "fig14_throughput",
                     "metric": f"self_attn_us_{label}",
                     "value": f"{t*1e6:.1f}"})
        if base_t is None:
            base_t = t
        _, aux = a3_self_attention(q, k, v, a3)
        sav = flop_savings(aux, n, d)
        rows.append({"name": "fig14_throughput",
                     "metric": f"score_flop_fraction_{label}",
                     "value": f"{float(sav['score_flop_fraction']):.3f}"})
        rows.append({"name": "fig14_throughput",
                     "metric": f"output_flop_fraction_{label}",
                     "value": f"{float(sav['output_flop_fraction']):.3f}"})
        rows.append({"name": "fig14_throughput",
                     "metric": f"mean_candidates_{label}",
                     "value": f"{float(sav['mean_candidates']):.1f}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
