"""End-to-End Memory Network on synthetic bAbI (the paper's SSVI
evaluation, self-contained): train with exact attention, then evaluate
with the A^3 approximation at several (M, T) settings — reproducing the
shape of Figures 11-13.

    PYTHONPATH=src python examples/babi_memn2n.py [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.config import A3Config, A3Mode, OptimizerConfig
from repro.data.babi import generate_babi, make_task
from repro.models import memn2n
from repro.optim.adamw import adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--statements", type=int, default=48)
    args = ap.parse_args()

    task = make_task(num_actors=64, num_places=16, max_sentences=64)
    cfg = memn2n.MemN2NConfig(vocab_size=task.vocab_size, d_embed=64,
                              num_hops=3, max_sentences=task.max_sentences,
                              max_words=task.max_words)
    params = memn2n.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=10, min_lr_ratio=0.3,
                           total_steps=args.steps, weight_decay=0.0)
    opt = adamw_init(params, ocfg)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(memn2n.loss_fn)(params, batch, cfg)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    for i in range(args.steps):
        b = generate_babi(task, 64, args.statements, seed=1000 + i)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step(params, opt, b)
        if i % 100 == 0:
            print(f"step {i:4d} loss {float(loss):.4f}")

    test = generate_babi(task, 512, args.statements, seed=7)
    test = {k: jnp.asarray(v) for k, v in test.items()}
    base = float(memn2n.accuracy(params, test, cfg))
    print(f"\nexact attention accuracy: {base:.3f}")
    for label, a3 in [
            ("conservative M=n/2 T=5%", A3Config.conservative()),
            ("aggressive  M=n/8 T=10%", A3Config.aggressive()),
            ("custom      M=n/4 T=8%", A3Config(mode=A3Mode.CUSTOM,
                                                m_fraction=0.25,
                                                threshold_pct=8.0))]:
        acc = float(memn2n.accuracy(params, test, cfg, a3))
        print(f"A3 {label}: accuracy {acc:.3f} (delta "
              f"{100 * (acc - base):+.1f}%)")


if __name__ == "__main__":
    main()
