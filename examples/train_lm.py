"""End-to-end training driver: ~100M-param LM for a few hundred steps
through the full production stack — config registry, synthetic data
pipeline with host prefetch, AdamW + cosine schedule, per-layer remat,
async checkpointing, watchdog, and crash recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch internlm2-1.8b]

The default model is a ~100M-param variant of the assigned internlm2
family (16 layers, d_model 512); on a real cluster the same entrypoint
runs the full config on the production mesh (see launch/dryrun.py for
the compiled proof).
"""
import argparse
import dataclasses
import tempfile
import time

from repro.config import (
    CheckpointConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    ShapeKind,
    ShardingConfig,
    get_arch,
)
from repro.train.loop import train_with_recovery


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    base = get_arch(args.arch)
    # ~100M-param variant of the assigned family (CPU-trainable)
    cfg = dataclasses.replace(
        base, num_layers=16, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=1536, vocab_size=8192, dtype="float32",
        moe=None, block_pattern=base.block_pattern)
    print(f"model: {cfg.name} variant, {cfg.param_count()/1e6:.1f}M params")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_lm_")
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("train", ShapeKind.TRAIN, args.seq, args.batch),
        optimizer=OptimizerConfig(lr=3e-4, total_steps=args.steps,
                                  warmup_steps=args.steps // 10),
        sharding=ShardingConfig(remat="none"),
        checkpoint=CheckpointConfig(directory=ckpt_dir, save_every=50),
    )

    t0 = time.time()
    out = train_with_recovery(run, num_steps=args.steps)
    dt = time.time() - t0
    losses = out["losses"]
    toks = args.seq * args.batch * len(losses)
    print(f"steps={len(losses)} loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"| {toks/dt:,.0f} tok/s | checkpoints in {ckpt_dir}")
    assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
