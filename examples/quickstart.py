"""Quickstart: the paper's attention pipeline in 60 lines.

Builds a (key, value) memory, preprocesses it at "comprehension time"
(column sort, paper SSIV-C), then answers queries with exact attention,
conservative A^3, and aggressive A^3, printing the candidate / kept
counts and the output error — the accuracy/efficiency trade-off knob the
paper exposes via (M, T).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.config import A3Config
from repro.core import a3_attention_batch, preprocess

N, D, Q = 320, 64, 8                       # paper's BERT-scale memory

key = jax.random.PRNGKey(0)
kk, kv, kq = jax.random.split(key, 3)
keys = jax.random.normal(kk, (N, D)) * 0.5
values = jax.random.normal(kv, (N, D)) * 0.5
queries = jax.random.normal(kq, (Q, D)) * 0.5

# --- comprehension time (off the critical path) --------------------------
state = preprocess(keys, values)

# --- query time ------------------------------------------------------------
exact, _ = a3_attention_batch(state, queries, A3Config())

for name, cfg in [("conservative (M=n/2, T=5%)", A3Config.conservative()),
                  ("aggressive  (M=n/8, T=10%)", A3Config.aggressive())]:
    out, aux = a3_attention_batch(state, queries, cfg)
    cand = float(jnp.mean(jnp.sum(aux["candidates"], -1)))
    kept = float(jnp.mean(jnp.sum(aux["kept"], -1)))
    err = float(jnp.max(jnp.abs(out - exact)))
    cos = float(jnp.mean(jnp.sum(out * exact, -1) /
                         (jnp.linalg.norm(out, axis=-1) *
                          jnp.linalg.norm(exact, axis=-1) + 1e-9)))
    print(f"{name}")
    print(f"  candidates {cand:6.1f}/{N}   kept {kept:6.1f}/{N}   "
          f"max|err| {err:.4f}   cos(exact) {cos:.4f}")

print("\nexact output row 0, first 6 dims:", exact[0, :6])
