"""Serving example: batched requests through the slot engine with the
A^3 approximate decode path, comparing exact vs approximate outputs and
reporting agreement + engine stats.

    PYTHONPATH=src python examples/serve_lm.py [--arch phi4-mini-3.8b]
"""
import argparse

import jax
import numpy as np

from repro.config import A3Config, get_arch, smoke_variant
from repro.models import decoder
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_variant(get_arch(args.arch))
    params = decoder.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
               for _ in range(args.requests)]

    results = {}
    for label, a3 in [("exact", A3Config()),
                      ("a3-conservative", A3Config.conservative())]:
        eng = ServeEngine(params, cfg, slots=4, max_len=256, a3=a3)
        uids = [eng.submit(p, max_new_tokens=args.max_new) for p in prompts]
        eng.run_to_completion()
        results[label] = [eng.result(u) for u in uids]
        total = sum(len(r) for r in results[label])
        print(f"{label:16s}: {total} tokens generated, stats={eng.stats}")

    agree = np.mean([
        np.mean(np.asarray(a) == np.asarray(b))
        for a, b in zip(results["exact"], results["a3-conservative"])])
    print(f"\nexact vs A3-conservative token agreement: {agree:.2%}")
    print("sample exact      :", results["exact"][0])
    print("sample a3-conserv :", results["a3-conservative"][0])


if __name__ == "__main__":
    main()
