"""Serving example: batched requests through the slot engine with the
A^3 approximate decode path, comparing exact vs approximate outputs and
reporting agreement + engine stats.

Every ``engine.step()`` is one *tick* of the admission state machine::

    admit -> chunked prefill -> blocked decode
                                 (T x [in-graph resort -> step -> sample])

* **admit**: queued requests claim free slots and enter the PREFILLING
  phase (no forward pass; the first chunk dispatch resets the slot's
  reused mixer state — KV ring rows AND recurrent carries — in-graph).
* **chunked prefill**: all PREFILLING slots advance by up to
  ``prefill_chunk`` prompt tokens in ONE padded ragged dispatch (per-
  slot cursors), so a long prompt never stalls decoding slots for more
  than one chunk. Every arch admits this way — recurrent/hybrid stacks
  carry mid-prompt state across chunks through the per-segment
  mixer-state interface (``repro.models.mixer``). A slot whose cursor
  reaches the end of its prompt samples its first token in-graph and
  flips to DECODING; the same tick's decode block consumes that token
  on device (the prefill tick itself never blocks).
* **blocked decode**: every DECODING slot advances up to
  ``decode_block`` = T tokens in ONE jitted ``lax.scan`` dispatch
  (per-slot positions, donated in-place KV cache). Sampling runs
  in-graph (greedy argmax; temperature hook in ``ServeConfig``), each
  step feeding the next, and the A^3 ``sorted_upto`` watermark check +
  fresh-tail re-sort also run in-graph — the host never reads a
  watermark, and syncs only once per block to harvest the [slots, T]
  token ring (``stats["host_syncs"]``). Lanes that exhaust their
  budget mid-block ride along masked at pos=-1.

Chunking and decode blocking are scheduling decisions, not model
changes — the example runs the same prompts with whole-prompt,
chunked, and blocked-decode engines, reports that the generations are
identical (up to fp-tie flips; ``tests/test_serve_conformance.py``
asserts it), then compares exact vs A^3.

    PYTHONPATH=src python examples/serve_lm.py [--arch phi4-mini-3.8b]
"""
import argparse

import jax
import numpy as np

from repro.config import A3Config, get_arch, smoke_variant
from repro.models import decoder
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--decode-block", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_variant(get_arch(args.arch))
    params = decoder.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
               for _ in range(args.requests)]

    results = {}
    runs = [("exact", A3Config(), None, 1),
            ("exact-chunked", A3Config(), args.prefill_chunk, 1),
            ("exact-blocked", A3Config(), args.prefill_chunk,
             args.decode_block),
            ("a3-conservative", A3Config.conservative(), None, 1)]
    syncs = {}
    for label, a3, chunk, block in runs:
        eng = ServeEngine(params, cfg, slots=4, max_len=256, a3=a3,
                          prefill_chunk=chunk, decode_block=block)
        uids = [eng.submit(p, max_new_tokens=args.max_new) for p in prompts]
        eng.run_to_completion()
        results[label] = [eng.result(u) for u in uids]
        total = sum(len(r) for r in results[label])
        syncs[label] = eng.stats["host_syncs"] / max(total, 1)
        print(f"{label:16s}: {total} tokens generated, stats={eng.stats}")

    if results["exact"] == results["exact-chunked"]:
        print("\nchunked admission == whole-prompt admission "
              "(scheduling changed, outputs did not)")
    else:
        print("\nWARNING: chunked admission changed outputs "
              "(fp-tie flip or recurrent-arch fallback)")
    if results["exact"] == results["exact-blocked"]:
        print(f"blocked decode (T={args.decode_block}) == per-step decode "
              f"at {syncs['exact-blocked']:.2f} host syncs/token "
              f"(vs {syncs['exact']:.2f} per-step)")
    else:
        print("\nWARNING: blocked decode changed outputs (fp-tie flip)")

    agree = np.mean([
        np.mean(np.asarray(a) == np.asarray(b))
        for a, b in zip(results["exact"], results["a3-conservative"])])
    print(f"\nexact vs A3-conservative token agreement: {agree:.2%}")
    print("sample exact      :", results["exact"][0])
    print("sample a3-conserv :", results["a3-conservative"][0])


if __name__ == "__main__":
    main()
