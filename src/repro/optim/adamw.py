"""AdamW + cosine schedule + global-norm clipping, pure JAX.

Optimizer state dtypes are configurable (``OptimizerConfig.m_dtype`` /
``v_dtype``): storing the first moment in bf16 drops optimizer state from
8 to 6 bytes/param — the difference between grok-314B fitting a 256-chip
pod with activations or not (DESIGN.md SS4).

The update is fully pytree-structural so it shards exactly like the
params under FSDP (each leaf's opt state inherits the param's sharding).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig


class OptState(NamedTuple):
    step: jax.Array            # int32 scalar
    m: Any                     # pytree like params
    v: Any


def cosine_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    step_f = step.astype(jnp.float32)
    warm = cfg.lr * step_f / max(cfg.warmup_steps, 1)
    progress = (step_f - cfg.warmup_steps) / max(
        cfg.total_steps - cfg.warmup_steps, 1)
    progress = jnp.clip(progress, 0.0, 1.0)
    floor = cfg.lr * cfg.min_lr_ratio
    cos = floor + 0.5 * (cfg.lr - floor) * (1 + jnp.cos(jnp.pi * progress))
    return jnp.where(step_f < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    return jnp.sqrt(sq)


def adamw_init(params: Any, cfg: OptimizerConfig) -> OptState:
    m_dt = jnp.dtype(cfg.m_dtype)
    v_dt = jnp.dtype(cfg.v_dtype)
    m = jax.tree.map(lambda p: jnp.zeros(p.shape, m_dt), params)
    v = jax.tree.map(lambda p: jnp.zeros(p.shape, v_dt), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def adamw_update(
    grads: Any, state: OptState, params: Any, cfg: OptimizerConfig
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = cosine_schedule(cfg, step)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return (pf.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_m, new_v), metrics
