"""Int8 gradient compression with error feedback for the cross-pod (DCI)
all-reduce.

On a multi-pod mesh the ``pod`` axis crosses the data-center interconnect
— the scarcest bandwidth in the system (DESIGN.md SS4). The standard trick
is to compress the gradient before the cross-pod reduction and keep the
quantization residual locally ("error feedback"), adding it back into the
next step's gradient so the bias does not accumulate (Seide et al.,
1-bit SGD lineage).

Scheme per leaf:
  scale  = psum_max(|g|) / 127          (one scalar collective, tiny)
  q      = round(g / scale)  in int8
  g_hat  = psum(q) * scale / n_pods     (int8 payload on the wire)
  err    = g - dequant(q)               (kept local, fed back next step)

Wire bytes: 1 byte/param instead of 4 (f32) or 2 (bf16) -> 2-4x DCI
bandwidth saving; the collective term of the roofline drops accordingly.

Used inside ``shard_map`` over the ``pod`` axis (see train.step).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(g: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.round(g.astype(jnp.float32) / scale)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def psum_compressed(grads: Any, axis_name: str,
                    err: Any = None) -> Tuple[Any, Any]:
    """All-reduce ``grads`` over ``axis_name`` with int8 compression and
    error feedback. Returns (mean gradient, new error state)."""
    n = jax.lax.psum(1, axis_name)

    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        # error feedback folds into the gradient BEFORE the amax: the
        # scale must cover g + e, otherwise feedback can exceed the int8
        # grid, clip, and re-enter the residual every step instead of
        # draining (non-accumulation is pinned by the drain property in
        # test_sharding_multidev.py)
        gf = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.maximum(amax / 127.0, 1e-12)
        q = compress_int8(gf, scale)
        new_err = gf - decompress_int8(q, scale)
        # int8 payload; accumulate in int32 to avoid overflow across pods
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = total.astype(jnp.float32) * scale / n
        return mean.astype(g.dtype), new_err

    out = jax.tree.map(one, grads, err)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return mean, new_err
