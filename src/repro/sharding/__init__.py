from repro.sharding.rules import (
    batch_spec,
    cache_specs,
    logical_to_mesh_axes,
    param_specs,
    shardings_for,
)
from repro.sharding.compression import (
    compress_int8,
    decompress_int8,
    psum_compressed,
)

__all__ = [
    "batch_spec", "cache_specs", "logical_to_mesh_axes", "param_specs",
    "shardings_for", "compress_int8", "decompress_int8", "psum_compressed",
]
