"""GPipe-style pipeline-parallel microbatch schedule over a ``pipe`` mesh
axis, expressed with ``shard_map`` + ``ppermute``.

Not used by the default production mesh (the assigned meshes are
(data, model) and (pod, data, model); attention-approximation work gains
little from PP), but provided as a first-class substrate feature: stages
hold disjoint layer slices, microbatches stream through with
``collective_permute`` between neighbours, and the bubble fraction is
(P-1)/(M+P-1) as usual.

The stage function must be shape-preserving ([mb, S, D] -> [mb, S, D]).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,          # pytree with leading [P_stages] axis
    x: jax.Array,               # [M_microbatches, mb, S, D]
    mesh: Mesh,
    axis: str = "pipe",
) -> jax.Array:
    """Runs M microbatches through P stages; returns final outputs in
    microbatch order [M, mb, S, D]."""
    n_stages = mesh.shape[axis]

    def stage_local(params, xs):            # runs per-device
        params = jax.tree.map(lambda t: t[0], params)   # drop stage axis
        m = xs.shape[0]
        stage_id = jax.lax.axis_index(axis)
        n_ticks = m + n_stages - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if t < m); others use buf
            mb_idx = jnp.clip(t, 0, m - 1)
            inp = jnp.where(stage_id == 0, xs[mb_idx], buf)
            active = (t - stage_id >= 0) & (t - stage_id < m)
            y = stage_fn(params, inp)
            y = jnp.where(active, y, buf)
            # pass to next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt = jax.lax.ppermute(y, axis, perm)
            # last stage writes its finished microbatch
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            write = (stage_id == n_stages - 1) & active
            outs = jnp.where(
                write,
                outs.at[out_idx].set(y),
                outs)
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs),
                                    jnp.arange(n_ticks))
        # only the last stage's outs are real; broadcast via masked psum
        outs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outs, 0.0), axis)
        return outs

    fn = shard_map(stage_local, mesh=mesh,
                   in_specs=(P(axis), P()),
                   out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x)
