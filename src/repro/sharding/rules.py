"""Logical-axis sharding rules: param pytree -> PartitionSpecs.

Parallelism mapping (DESIGN.md SS4):
  * ``data`` mesh axis  — DP over the batch + FSDP (ZeRO-3) over one
    weight dim; SP (sequence sharding) when the batch is too small.
  * ``model`` mesh axis — TP over heads / FFN width; EP over MoE experts
    when the expert count divides the axis.
  * ``pod`` mesh axis   — outer pure-DP axis (gradients cross the DCI
    once per step; optionally int8-compressed).

Rules are *intent-based*: each weight leaf gets logical axes
("fsdp" | "tp" | "ep" | None) per dimension from a name table, the
intents are lowered to mesh axes, and any assignment whose mesh-axis size
does not divide the dim is dropped (e.g. 8 grok experts on a 16-way model
axis fall back to TP-within-expert). This keeps every (arch x mesh) cell
well-defined without per-arch special cases.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig, ShapeKind, ShardingConfig

# leaf name -> logical intent for the trailing (non-layer-stack) dims
_MATRIX_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # attention / generic projections [D_in, D_out]
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    # FFN
    "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    # recurrent blocks
    "w_in_gate": ("fsdp", "tp"), "w_in_rnn": ("fsdp", "tp"),
    "w_a": ("fsdp", "tp"), "w_x": ("fsdp", "tp"),
    "w_out": ("tp", "fsdp"), "wx": ("fsdp", "tp"),
    "w_o": ("fsdp", "tp"),
    # small per-channel tensors
    "conv_w": (None, "tp"), "conv_b": ("tp",), "lam": ("tp",),
    "w_i": ("fsdp", None), "w_f": ("fsdp", None),
    "b_i": (None,), "b_f": (None,), "b": ("tp",),
    "router": ("fsdp", None),
    "scale": (None,), "ln_scale": None,   # None -> replicate all dims
}

# MoE expert stacks [E, D_in, D_out]: EP on the expert dim when it
# divides the model axis, otherwise TP inside each expert.
_MOE_RULES: Dict[str, Tuple[Tuple[Optional[str], ...],
                            Tuple[Optional[str], ...]]] = {
    "w_gate": (("ep", "fsdp", None), (None, "fsdp", "tp")),
    "w_up": (("ep", "fsdp", None), (None, "fsdp", "tp")),
    "w_down": (("ep", None, "fsdp"), (None, "tp", "fsdp")),
}


def logical_to_mesh_axes(cfg: ShardingConfig) -> Dict[str, Optional[str]]:
    return {
        "fsdp": cfg.fsdp_axis if cfg.fsdp else None,
        "tp": cfg.tp_axis if cfg.tensor_parallel else None,
        "ep": cfg.ep_axis if cfg.expert_parallel else None,
    }


def _sanitize(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
              mesh_shape: Dict[str, int]) -> P:
    """Drop assignments whose mesh-axis size doesn't divide the dim."""
    out = []
    for dim, ax in zip(shape, axes):
        if ax is None or ax not in mesh_shape or dim % mesh_shape[ax] != 0:
            out.append(None)
        else:
            out.append(ax)
    return P(*out)


def _leaf_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               logical: Dict[str, Optional[str]],
               mesh_shape: Dict[str, int]) -> P:
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    stacked = path[0].startswith("seg")          # leading layers axis

    if name == "embed":       # [Vp, D]: vocab TP, width FSDP
        return _sanitize(tuple(logical.get(a) for a in ("tp", "fsdp")),
                         shape, mesh_shape)
    if name == "lm_head":
        return _sanitize(tuple(logical.get(a) for a in ("fsdp", "tp")),
                         shape, mesh_shape)

    if parent == "moe" and name in _MOE_RULES and len(shape) - stacked == 3:
        primary, fallback = _MOE_RULES[name]
        e_dim = shape[1] if stacked else shape[0]
        ep_ax = logical.get("ep")
        use = primary if (ep_ax and e_dim % mesh_shape.get(ep_ax, 1) == 0) \
            else fallback
        axes = tuple(logical.get(a) if a else None for a in use)
        if stacked:
            axes = (None,) + axes
        return _sanitize(axes, shape, mesh_shape)

    intent = _MATRIX_RULES.get(name)
    if intent is None:
        return P()                                # replicate unknown leaves
    axes = tuple(logical.get(a) if a else None for a in intent)
    if stacked:
        axes = (None,) + axes
    if len(axes) != len(shape):                   # rank mismatch -> replicate
        if len(axes) < len(shape):
            axes = axes + (None,) * (len(shape) - len(axes))
        else:
            axes = axes[: len(shape)]
    return _sanitize(axes, shape, mesh_shape)


def param_specs(params_shape: Any, sharding_cfg: ShardingConfig,
                mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``params_shape`` (shapes or arrays)."""
    logical = logical_to_mesh_axes(sharding_cfg)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path)
        return _leaf_spec(names, tuple(leaf.shape), logical, mesh_shape)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def shardings_for(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation / input shardings
# ---------------------------------------------------------------------------

def _dp_axes(mesh_shape: Dict[str, int],
             cfg: Optional[ShardingConfig] = None) -> Tuple[str, ...]:
    names = cfg.dp_axes if cfg is not None else ("pod", "data", "ep")
    return tuple(a for a in names if a in mesh_shape)


def batch_spec(shape: ShapeConfig, mesh: Mesh,
               sharding_cfg: Optional[ShardingConfig] = None) -> P:
    """Sharding for [B, S] token inputs.

    Batch shards over (pod, data) when divisible; a batch too small for
    the data axis (long-context decode, B=1) switches to sequence
    parallelism: S shards over (data, model).
    """
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = _dp_axes(mesh_shape, sharding_cfg)
    dp = int(np.prod([mesh_shape[a] for a in dp_axes])) if dp_axes else 1
    if shape.global_batch % dp == 0 and shape.global_batch >= dp:
        return P(dp_axes if len(dp_axes) > 1 else dp_axes[0], None)
    # SP fallback: sequence over (data, model)
    sp_axes = tuple(a for a in ("data", "model") if a in mesh_shape)
    sp = int(np.prod([mesh_shape[a] for a in sp_axes]))
    if shape.seq_len % sp == 0:
        return P(None, sp_axes)
    return P(None, None)


def act_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              sharding_cfg: Optional[ShardingConfig] = None) -> Dict[str, Any]:
    """NamedShardings for the model's activation constraint points.

    hidden [B, S, D]: batch over (pod, data); if the batch is too small
      (long-context decode) the sequence shards over (data, model).
    q/k/v [B, H, S, Dh]: heads over model when divisible, otherwise the
      q sequence shards over model (attention sequence parallelism) and
      k/v stay head-replicated — each device computes its seq slice
      against the full (windowed) KV.
    """
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = _dp_axes(mesh_shape, sharding_cfg)
    dp = int(np.prod([mesh_shape[a] for a in dp_axes])) if dp_axes else 1
    tp_name = sharding_cfg.tp_axis if sharding_cfg else "model"
    model = mesh_shape.get(tp_name, 1)
    dp_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes
                                                else None)
    b, s = shape.global_batch, shape.seq_len
    batch_ok = b % dp == 0 and b >= dp
    is_decode = shape.kind == ShapeKind.DECODE

    def ns(*axes):
        return NamedSharding(mesh, P(*axes))

    specs: Dict[str, Any] = {}
    seq_len_here = 1 if is_decode else s
    if batch_ok:
        specs["hidden"] = ns(dp_spec, None, None)
        hq, hkv = cfg.num_heads, cfg.num_kv_heads
        q_heads = tp_name if hq % model == 0 else None
        kv_heads = tp_name if hkv % model == 0 else None
        q_seq = None
        if q_heads is None and not is_decode and s % model == 0:
            q_seq = tp_name
        specs["q"] = ns(dp_spec, q_heads, q_seq, None)
        specs["kv"] = ns(dp_spec, kv_heads, None, None)
    elif not is_decode:
        fsdp_name = sharding_cfg.fsdp_axis if sharding_cfg else "data"
        sp_axes = tuple(a for a in (fsdp_name, tp_name) if a in mesh_shape)
        sp = int(np.prod([mesh_shape[a] for a in sp_axes]))
        if s % sp == 0:
            specs["hidden"] = ns(None, sp_axes, None)
            specs["q"] = ns(None, None, sp_axes, None)
            specs["kv"] = ns(None, None, sp_axes, None)
    else:
        # decode with tiny batch: replicate hidden; shard cache scan via
        # cache_specs (ring over (data, model)).
        specs["hidden"] = ns(None, None, None)
    # decode-path constraints: per-layer cache slice [B, Hkv, W, Dh] and
    # q [B, Hq, 1, Dh] — keeps the A^3 selection batch-sharded (GSPMD
    # replicated it otherwise) and the ring on the model axis.
    if is_decode and batch_ok:
        ring = tp_name if s % model == 0 else None
        specs["kv_cache"] = ns(dp_spec, None, ring, None)
        specs["q"] = ns(dp_spec,
                        tp_name if cfg.num_heads % model == 0 else None,
                        None, None)
        # A^3 sharded-selection stages: batch over dp, block axis (NS)
        # over the model axis; everything inside a block is chip-local.
        specs["a3_blocks"] = ns(dp_spec, None, ring, None, None)
        specs["a3_prefix"] = ns(dp_spec, None, ring, None, None, None)
        specs["a3_greedy"] = ns(dp_spec, None, ring, None, None)
        specs["a3_scores"] = ns(dp_spec, None, ring, None)
    return specs


def cache_specs(cache_shape: Any, shape: ShapeConfig, mesh: Mesh,
                sharding_cfg: Optional[ShardingConfig] = None) -> Any:
    """Sharding for decode caches.

    Attention K/V rings [L, B, Hkv, W, Dh]: batch over (pod, data) when
    divisible, ring length over model (TP of the KV search — each chip
    scans its slice of the cache, the flash-style combine is a psum).
    For B=1 long-context, ring shards over (data, model).
    Recurrent states [L, B, ...]: batch over (pod, data) when divisible.
    """
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = _dp_axes(mesh_shape, sharding_cfg)
    dp = int(np.prod([mesh_shape[a] for a in dp_axes])) if dp_axes else 1
    tp_name = sharding_cfg.tp_axis if sharding_cfg else "model"
    model = mesh_shape.get(tp_name, 1)
    dp_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes
                                                else None)

    def spec(leaf):
        shp = tuple(leaf.shape)
        batch_ok = len(shp) >= 2 and shp[1] % dp == 0 and shp[1] >= dp
        if len(shp) == 5:                       # attention K/V ring
            w = shp[3]
            if batch_ok:
                ring = tp_name if w % model == 0 else None
                return P(None, dp_spec, None, ring, None)
            fsdp_name = sharding_cfg.fsdp_axis if sharding_cfg else "data"
            axes = tuple(a for a in (fsdp_name, tp_name)
                         if a in mesh_shape)
            sp = int(np.prod([mesh_shape[a] for a in axes]))
            if w % sp == 0:
                return P(None, None, None, axes, None)
            return P(None, None, None, None, None)
        # recurrent state [L, B, ...]
        axes = [None] * len(shp)
        if batch_ok:
            axes[1] = dp_spec
        return P(*axes)

    return jax.tree.map(spec, cache_shape)
