from repro.kernels.mlstm_chunk.ops import mlstm_chunk

__all__ = ["mlstm_chunk"]
