"""Chunkwise-parallel mLSTM Pallas TPU kernel (SSPerf H1).

The XLA-compiled chunkwise mLSTM streams every intermediate
([B,H,L,L] decay/score tiles, full-width gate products) through HBM —
the dominant memory-roofline term of xlstm-350m x train_4k. This kernel
keeps the whole per-chunk working set in VMEM:

  grid = (B, H, n_chunks); the chunk axis is the innermost (sequential
  on TPU) dimension, and the recurrent state (C [Dk,Dv], n [Dk], m [1])
  lives in VMEM scratch across chunk iterations — HBM traffic collapses
  to the q/k/v streams read once and h written once.

VMEM working set at L=256, Dh=256 (v5e budget 16 MB):
  q/k/v/h tiles 4 x L x Dh f32      = 1.0 MB
  decay/score tiles 2 x L x L f32   = 0.5 MB
  state C + n + gates               = 0.3 MB        => ~2 MB, MXU-aligned.

Forward only (deployment path: serving prefill + the train forward
under remat); the backward stays in XLA. Validated against ``ref.py``
with ``interpret=True`` on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mlstm_kernel(
    q_ref, k_ref, v_ref, li_ref, lf_ref,   # inputs
    h_ref,                                  # output
    c_scr, n_scr, m_scr,                    # VMEM carry across chunks
    *,
    scale: float,
    block: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    q = q_ref[0, 0].astype(jnp.float32)                  # [L, Dk]
    k = k_ref[0, 0].astype(jnp.float32) * scale          # [L, Dk]
    v = v_ref[0, 0].astype(jnp.float32)                  # [L, Dv]
    li = li_ref[0, 0].astype(jnp.float32)                # [L, 1]
    lf = lf_ref[0, 0].astype(jnp.float32)                # [L, 1]

    f_cum = jnp.cumsum(lf, axis=0)                       # [L, 1]
    f_tot = f_cum[block - 1, 0]                          # scalar
    m_prev = m_scr[0, 0]                                 # scalar

    # intra-chunk decay D[t, u] = F[t] - F[u] + li[u], causal
    dmat = f_cum - f_cum.T + li.T                        # [L, L]
    rows = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    dmat = jnp.where(cols <= rows, dmat, NEG_INF)

    inter_log = f_cum + m_prev                           # [L, 1]
    m_row = jnp.maximum(jnp.max(dmat, axis=-1, keepdims=True), inter_log)
    w = jnp.exp(dmat - m_row)                            # [L, L]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * w
    inter_w = jnp.exp(inter_log - m_row)                 # [L, 1]
    qc = jax.lax.dot_general(q, c_scr[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    num = jax.lax.dot_general(s, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32) \
        + inter_w * qc                                   # [L, Dv]
    qn = jax.lax.dot_general(q, n_scr[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, 1]
    den = jnp.sum(s, axis=-1, keepdims=True) + inter_w * qn
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_row))
    h_ref[0, 0] = (num / den).astype(h_ref.dtype)

    # ---- state update to end of chunk ----
    wr_log = f_tot - f_cum + li                          # [L, 1]
    m_new = jnp.maximum(f_tot + m_prev, jnp.max(wr_log))  # scalar
    f_eff = jnp.exp(f_tot + m_prev - m_new)
    wr = jnp.exp(wr_log - m_new)                         # [L, 1]
    kw = k * wr                                          # [L, Dk]
    c_scr[...] = f_eff * c_scr[...] + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # [Dk, Dv]
    n_scr[...] = f_eff * n_scr[...] + jnp.sum(kw, axis=0)[:, None]
    m_scr[...] = jnp.full((1, 1), m_new, jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("chunk", "scale", "interpret"))
def mlstm_chunk_kernel(
    q: jax.Array,                    # [B, H, S, Dk]
    k: jax.Array,                    # [B, H, S, Dk]
    v: jax.Array,                    # [B, H, S, Dv]
    log_i: jax.Array,                # [B, H, S]
    log_f: jax.Array,                # [B, H, S]  (log-sigmoid, <= 0)
    *,
    chunk: int = 256,
    scale: float = 1.0,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, s)
    assert s % L == 0, (s, L)
    grid = (b, h, s // L)

    li = log_i[..., None]
    lf = log_f[..., None]

    kernel = functools.partial(_mlstm_kernel, scale=scale, block=L)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, L, dk), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, L, dk), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, L, dv), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b_, h_, c: (b_, h_, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L, dv),
                               lambda b_, h_, c: (b_, h_, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((dk, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, li, lf)
