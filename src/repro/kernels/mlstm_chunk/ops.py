"""Public entry: chunkwise mLSTM recurrent core (kernel or oracle)."""
from __future__ import annotations

import jax

from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_kernel
from repro.kernels.mlstm_chunk.ref import mlstm_chunk_ref


def mlstm_chunk(q, k, v, log_i, log_f, *, chunk: int = 256,
                scale: float = 1.0, use_kernel: bool = False,
                interpret: bool = False) -> jax.Array:
    if use_kernel:
        return mlstm_chunk_kernel(q, k, v, log_i, log_f, chunk=chunk,
                                  scale=scale, interpret=interpret)
    return mlstm_chunk_ref(q, k, v, log_i, log_f, scale=scale)
