"""Pure-jnp oracle for the chunkwise mLSTM kernel: sequential per-token
recurrence (exact), f64-free but f32 throughout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_chunk_ref(q, k, v, log_i, log_f, *, scale: float = 1.0):
    """q/k/v [B,H,S,D*], gates [B,H,S]. Returns h [B,H,S,Dv] (f32 math,
    cast back to q.dtype). Sequential scan over S — the exact oracle."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32) * scale
    vf = v.astype(jnp.float32)
    li = log_i.astype(jnp.float32)
    lf = log_f.astype(jnp.float32)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, lit, lft = xs                       # [B,H,D*], [B,H]
        m_new = jnp.maximum(lft + m, lit)
        f_eff = jnp.exp(lft + m - m_new)
        i_eff = jnp.exp(lit - m_new)
        C_new = f_eff[..., None, None] * C + i_eff[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n_new = f_eff[..., None] * n + i_eff[..., None] * kt
        num = jnp.einsum("bhkv,bhk->bhv", C_new, qt)
        qn = jnp.einsum("bhk,bhk->bh", n_new, qt)
        den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
        return (C_new, n_new, m_new), num / den[..., None]

    C0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    n0 = jnp.zeros((b, h, dk), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (qf, kf, vf, li, lf))
    _, hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 2).astype(q.dtype)
