"""Fused attention Pallas TPU kernel (baseline for A³ comparisons).

Online-softmax (flash) attention with GQA, causal and sliding-window
masking. Written for TPU v5e: 128-aligned q/k tiles so the QKᵀ and PV
matmuls land on the MXU; the running (m, l, acc) state lives in VMEM
scratch across the innermost kv-block grid dimension.

Validated on CPU with ``interpret=True`` against ``ref.py``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,           # inputs
    o_ref,                          # output
    m_scr, l_scr, acc_scr,          # VMEM scratch
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)                 # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)                 # [bk, dv]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # [bq, bk]

    rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    # decode/prefill offset: query i sits at absolute position i + (seq_k - seq_q)
    abs_rows = rows + (seq_k - seq_q)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= cols <= abs_rows
    if window is not None:
        mask &= cols > abs_rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # [bq, 1]
    row_max = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, row_max)
    alpha = jnp.exp(m_prev - m_new)                      # [bq, 1]
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)

    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ik == nk - 1)
    def _emit():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = jnp.where(
            l == 0.0, 0.0, acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "scale",
                     "interpret"),
)
def flash_attention(
    q: jax.Array,                   # [B, Hq, Sq, D]
    k: jax.Array,                   # [B, Hkv, Sk, D]
    v: jax.Array,                   # [B, Hkv, Sk, Dv]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    assert k.shape == (b, hkv, sk, d), (q.shape, k.shape)
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5

    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)

    grid = (b, hq, sq // bq, sk // bk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, seq_q=sq, seq_k=sk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, dv),
                         lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv),
                               lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
