"""Public jit'd entry point for fused attention.

Dispatches between the Pallas TPU kernel and the pure-jnp reference
(`use_kernel=False` is the analyzable-HLO path used by the dry-run; the
kernel path is the deployment path on real TPUs and is validated in
interpret mode on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def fused_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    use_kernel: bool = False,
    interpret: bool = False,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    if use_kernel:
        return flash_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            block_q=block_q, block_k=block_k, interpret=interpret)
    return attention_ref(q, k, v, causal=causal, window=window, scale=scale)
