"""Pure-jnp oracle for the fused attention kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,                 # [B, Hq, Sq, D]
    k: jnp.ndarray,                 # [B, Hkv, Sk, D]
    v: jnp.ndarray,                 # [B, Hkv, Sk, Dv]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5

    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * scale

    rows = jnp.arange(sq)[:, None] + (sk - sq)
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None], s, -jnp.inf)

    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m)
    p = jnp.where(mask[None, None], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    w = p / jnp.maximum(l, 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vq.astype(jnp.float32)).astype(q.dtype)
