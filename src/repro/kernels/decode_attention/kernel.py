"""Single-token decode attention Pallas TPU kernel with A³ masking.

Decode is the accelerator's home turf: one query vector against an n-row
KV memory — exactly the paper's Figure 1 unit op. On TPU the op is
HBM-bandwidth-bound (the KV cache streams through VMEM once), so the
MXU-friendly layout puts the GQA *query-head group* in the sublane
dimension: each grid step computes a [G, bk] score tile with one
[G, D]·[D, bk] matmul.

A³ enters as a per-position candidate mask (row-granular — decode is
bandwidth- not MXU-bound, so row granularity costs nothing here) plus the
post-scoring threshold of §IV-D.

The default path is a **fused single-pass** kernel: a flash-style online
softmax streams K/V through VMEM exactly once, carrying running
max/sum/accumulator scratch with rescaling. Because decode is
bandwidth-bound, halving the K reads (the old two-pass structure read K
once for the row max and again for the weighted sum) directly cuts
per-token latency.

Post-scoring in the fused pass tests scores against the *running* max —
a documented superset relaxation of the paper's exact two-pass rule: the
running max only grows, so ``s >= running_max - t`` is implied by
``s >= final_max - t``; no entry the exact pass keeps is ever dropped.
Entries admitted early that the exact rule would drop each carry softmax
weight < exp(-t) relative to the max, so the output delta is bounded (and
tested) by ~n·exp(-t) in total variation of the attention weights.
``exact_two_pass=True`` keeps the literal ASIC pipeline (pass 1 =
dot-product + max modules, pass 2 = exponent + output modules) for
bit-faithful §IV-D semantics.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _rowmax_kernel(q_ref, k_ref, mask_ref, m_out, m_scr, *, scale):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    q = q_ref[0].astype(jnp.float32)                     # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, D]
    mask = mask_ref[0]                                   # [G, bk]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    m_scr[...] = jnp.maximum(m_scr[...], jnp.max(s, -1, keepdims=True))

    @pl.when(ik == nk - 1)
    def _emit():
        m_out[0] = m_scr[...][:, 0]


def _attend_kernel(q_ref, k_ref, v_ref, mask_ref, rm_ref, o_ref,
                   l_scr, acc_scr, *, scale, threshold):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, Dv]
    mask = mask_ref[0]                                   # [G, bk]
    rm = rm_ref[0][:, None]                              # [G, 1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if threshold is not None:
        mask &= s >= rm - threshold
    p = jnp.where(mask, jnp.exp(s - rm), 0.0)
    l_scr[...] += jnp.sum(p, -1, keepdims=True)
    acc_scr[...] += jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _emit():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = jnp.where(l == 0.0, 0.0, acc_scr[...] / safe
                             ).astype(o_ref.dtype)


def _fused_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale, threshold):
    """Single-pass online-softmax decode: one K/V stream, running
    max/sum/acc scratch with rescaling. Threshold (if any) is applied
    against the running max — see the module docstring for the bound."""
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, Dv]
    mask = mask_ref[0]                                   # [G, bk]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[...]                                  # [G, 1]
    m_cur = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    keep = mask
    if threshold is not None:
        keep &= s >= m_cur - threshold
    p = jnp.where(keep, jnp.exp(s - m_cur), 0.0)
    alpha = jnp.exp(m_prev - m_cur)                      # rescale factor
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(ik == nk - 1)
    def _emit():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = jnp.where(l == 0.0, 0.0, acc_scr[...] / safe
                             ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("threshold", "scale", "block_k", "interpret",
                     "exact_two_pass"))
def decode_attention(
    q: jax.Array,                   # [B, Hq, D] one new token per sequence
    k: jax.Array,                   # [B, Hkv, S, D]
    v: jax.Array,                   # [B, Hkv, S, Dv]
    mask: jax.Array,                # [B, Hq, S] candidates & cache validity
    *,
    threshold: Optional[float] = None,
    scale: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = False,
    exact_two_pass: bool = False,
) -> jax.Array:
    b, hq, d = q.shape
    _, hkv, s, dv = v.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    bk = min(block_k, s)
    assert s % bk == 0

    grid = (b, hkv, s // bk)

    q_spec = pl.BlockSpec((1, group, d), lambda b_, h, ik: (b_, h, 0))
    kv_spec = pl.BlockSpec((1, 1, bk, d), lambda b_, h, ik: (b_, h, ik, 0))
    vv_spec = pl.BlockSpec((1, 1, bk, dv), lambda b_, h, ik: (b_, h, ik, 0))
    mask_spec = pl.BlockSpec((1, group, bk), lambda b_, h, ik: (b_, h, ik))
    o_spec = pl.BlockSpec((1, group, dv), lambda b_, h, ik: (b_, h, 0))

    if not exact_two_pass:
        return pl.pallas_call(
            functools.partial(_fused_kernel, scale=scale,
                              threshold=threshold),
            grid=grid,
            in_specs=[q_spec, kv_spec, vv_spec, mask_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((b, hq, dv), q.dtype),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, dv), jnp.float32),
            ],
            interpret=interpret,
        )(q, k, v, mask)

    rm_spec = pl.BlockSpec((1, group), lambda b_, h, ik: (b_, h))

    rowmax = pl.pallas_call(
        functools.partial(_rowmax_kernel, scale=scale),
        grid=grid,
        in_specs=[q_spec, kv_spec, mask_spec],
        out_specs=rm_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq), jnp.float32),
        scratch_shapes=[pltpu.VMEM((group, 1), jnp.float32)],
        interpret=interpret,
    )(q, k, mask)

    return pl.pallas_call(
        functools.partial(_attend_kernel, scale=scale, threshold=threshold),
        grid=grid,
        in_specs=[q_spec, kv_spec, vv_spec, mask_spec, rm_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask, rowmax)
