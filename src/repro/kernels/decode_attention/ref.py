"""Pure-jnp oracle for the decode attention kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def decode_attention_ref(
    q: jnp.ndarray,                 # [B, Hq, D]
    k: jnp.ndarray,                 # [B, Hkv, S, D]
    v: jnp.ndarray,                 # [B, Hkv, S, Dv]
    mask: jnp.ndarray,              # [B, Hq, S]
    *,
    threshold: Optional[float] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, hq, d = q.shape
    _, hkv, s_len, dv = v.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    kq = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vq = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kq) * scale
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    keep = mask
    if threshold is not None:
        keep = keep & (s >= m - threshold)
        s = jnp.where(keep, s, -jnp.inf)
    p = jnp.where(keep, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    w = p / jnp.maximum(l, 1e-30)
    return jnp.einsum("bhk,bhkd->bhd", w, vq).astype(q.dtype)
