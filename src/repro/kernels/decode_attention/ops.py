"""Public entry: A³ decode attention over a KV cache.

Composes greedy candidate selection (core) with the decode kernel / ref.
The cache-validity mask and the A³ candidate mask are merged; positions
written after the last column sort ("fresh tail") are always candidates —
the exact-tail policy for autoregressive decode described in DESIGN.md.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import A3Config, A3Mode
from repro.core.candidate_selection import SortedKeys, select_candidates
from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def a3_decode_attention(
    q: jax.Array,                   # [B, Hq, D]
    k: jax.Array,                   # [B, Hkv, S, D]
    v: jax.Array,                   # [B, Hkv, S, Dv]
    valid_mask: jax.Array,          # [B, S] cache validity
    cfg: A3Config,
    sorted_keys: Optional[SortedKeys] = None,   # batched tree if provided
    fresh_from: Optional[jax.Array] = None,     # [B] first unsorted position
    *,
    use_kernel: bool = False,
    interpret: bool = False,
    exact_two_pass: bool = False,
) -> jax.Array:
    b, hq, d = q.shape
    _, hkv, s_len, _ = k.shape
    group = hq // hkv
    scale = d ** -0.5

    if cfg.mode == A3Mode.OFF or sorted_keys is None:
        mask = jnp.broadcast_to(valid_mask[:, None, :], (b, hq, s_len))
        thr = None if cfg.mode == A3Mode.OFF else cfg.threshold_nats
    else:
        m = cfg.m_for(s_len)

        def per_bh(sk_vals, sk_rows, qh):
            sk = SortedKeys(values=sk_vals, rows=sk_rows)
            cand, _ = select_candidates(sk, qh * scale, m)
            return cand

        # vmap over batch then heads; sorted_keys are per (batch, kv-head)
        def per_b(sk_vals, sk_rows, qb):        # qb [Hq, D]
            qg = qb.reshape(hkv, group, d)
            f = jax.vmap(lambda skv, skr, qs: jax.vmap(
                lambda one_q: per_bh(skv, skr, one_q))(qs))
            return f(sk_vals, sk_rows, qg).reshape(hq, s_len)

        cand = jax.vmap(per_b)(sorted_keys.values, sorted_keys.rows, q)
        if fresh_from is not None:
            pos = jnp.arange(s_len)[None, None, :]
            cand = cand | (pos >= fresh_from[:, None, None])
        mask = cand & valid_mask[:, None, :]
        thr = cfg.threshold_nats

    if use_kernel:
        return decode_attention(q, k, v, mask, threshold=thr,
                                interpret=interpret,
                                exact_two_pass=exact_two_pass)
    return decode_attention_ref(q, k, v, mask, threshold=thr)


def a3_decode_attention_compact(
    q: jax.Array,                   # [B, Hq, D]
    k: jax.Array,                   # [B, Hkv, S, D]
    v: jax.Array,                   # [B, Hkv, S, Dv]
    valid_mask: jax.Array,          # [B, S]
    cfg: A3Config,
    sorted_keys: SortedKeys,        # batched per (B, Hkv): [B, Hkv, S, D]
    fresh_mask: Optional[jax.Array] = None,   # [B, S] always-include rows
    budget: Optional[int] = None,
    sk_scale: Optional[jax.Array] = None,     # [B, Hkv, NS, D] fp32
    k_scale: Optional[jax.Array] = None,      # [B, Hkv, S] fp32 per row
    v_scale: Optional[jax.Array] = None,      # [B, Hkv, S] fp32 per row
    return_probe: bool = False,
) -> jax.Array:
    """A^3 decode with **sharded compaction** (SSPerf H3.v4).

    The KV ring is treated as ``cfg.select_shards`` contiguous blocks
    (aligned with the model mesh axis so each block is chip-local;
    ``sorted_keys`` are column-sorted *per block* with block-local row
    ids). Each block runs the greedy walk (prefix-capped, heuristic-free
    — see v2/v3 notes in EXPERIMENTS.md) and gathers its own top-(C/NS)
    candidates; the concatenated [C] candidate set is small, so the
    final post-scoring + softmax is exact over it. The HLO never does a
    global top_k across shards (v3's collective-permute storm) and only
    moves C x D gathered bytes across chips.

    Candidate sets are unioned across the GQA group; ``fresh_mask`` rows
    (written after the last re-sort) are force-included per block.

    **Int8 scoring** (the quantized-cache path): with ``sk_scale``,
    ``sorted_keys.values`` may be int8 columns — the per-(block, column)
    fp32 scale is folded into the query instead of dequantizing the
    ring, so the greedy walk scores S x D int8 bytes and only the
    selected C candidates are ever widened. Positive scales preserve
    both the per-column sort order and the q-sign split, so the walk
    itself is unchanged. With ``k_scale``/``v_scale`` (per ring row) the
    K/V blocks may be int8 too; scales are gathered along with the
    ``idx`` winners and applied to just the [C] compacted candidates —
    the exact softmax then runs in f32 over dequantized values.

    **Quality probe** (``return_probe=True``): additionally returns a
    ``[B, 2]`` float32 leaf of per-lane telemetry — mean candidate
    count per kv head, and the *captured score mass* ratio (softmax
    mass over the kept candidate set / full softmax mass over every
    valid ring row, both measured against the full-score max in f32,
    so the ratio is in [0, 1] by construction and 1.0 means the
    approximation lost nothing this step). The probe scores the full
    ring once (an exact-attention-score-sized einsum), so callers
    sample it rather than running it every step. The attention output
    itself is computed by the identical ops either way.
    """
    b, hq, d = q.shape
    _, hkv, s_len, dv = v.shape
    group = hq // hkv
    scale = d ** -0.5
    ns = cfg.select_shards if s_len % max(cfg.select_shards, 1) == 0 else 1
    sl = s_len // ns                               # block length
    m = cfg.m_for(s_len)
    c_total = int(min(s_len, budget if budget is not None
                      else max(64, m // 2)))
    c_loc = min(sl, max(16, c_total // ns))
    m_loc = min(sl * d, max(c_loc, m // ns))
    thr = cfg.threshold_nats
    # v2: bound the per-column prefix to ~4M/d — the walk pops M elements
    # across d columns, so O(M) selection work instead of O(M d).
    cap = min(sl, max(16, (4 * m_loc + d - 1) // d))

    # ---- fully batched (no vmap): gathers keep explicit batch dims so
    # GSPMD partitions them instead of replicating (v4's jnp.take under
    # triple-vmap was compiled with a replicated batch axis) -------------
    from repro.models.common import shard_act
    blk5 = lambda t: shard_act(t.reshape(b, hkv, ns, sl, t.shape[-1]),
                               "a3_blocks")
    kb, vb = blk5(k), blk5(v)
    skv, skr = blk5(sorted_keys.values), blk5(sorted_keys.rows)
    qg = (q.reshape(b, hkv, group, d).astype(jnp.float32)) * scale
    valid_b = valid_mask.reshape(b, 1, ns, sl)
    fresh_b = (fresh_mask.reshape(b, 1, ns, sl)
               if fresh_mask is not None else jnp.zeros_like(valid_b))

    # prefix slices per block (static; ascending sort -> bottom=min side)
    top_v = skv[..., sl - cap:, :][..., ::-1, :]     # [B,Hkv,NS,cap,D]
    bot_v = skv[..., :cap, :]
    top_r = skr[..., sl - cap:, :][..., ::-1, :]
    bot_r = skr[..., :cap, :]

    qpos = (qg > 0)[:, :, None, :, None, :]          # [B,Hkv,1,G,1,D]
    qexp = qg[:, :, None, :, None, :]
    if sk_scale is not None:
        # int8 sorted columns: fold the per-(block, column) scale into
        # the query (scale > 0 keeps the sign split and walk order)
        qexp = qexp * sk_scale.reshape(b, hkv, ns, 1, 1, d)
    tv = top_v[:, :, :, None].astype(jnp.float32)    # [B,Hkv,NS,1,cap,D]
    bv = bot_v[:, :, :, None].astype(jnp.float32)
    prod_max = shard_act(jnp.where(qpos, tv, bv) * qexp,
                         "a3_prefix")                # [B,Hkv,NS,G,cap,D]
    prod_min = shard_act(jnp.where(qpos, bv, tv) * qexp, "a3_prefix")
    rows_max = jnp.where(qpos, top_r[:, :, :, None], bot_r[:, :, :, None])
    rows_min = jnp.where(qpos, bot_r[:, :, :, None], top_r[:, :, :, None])

    # top-(m_loc) products per block via batched top_k, then a batched
    # scatter-add into per-row greedy scores. (A sort-free variant that
    # scatter-adds ALL cap*d prefix products was measured — v6 — and
    # regressed the collective term 15x: GSPMD replicates the larger
    # scatter; see EXPERIMENTS.md H3.)
    # (v3 note: the cumulative-sum minQ heuristic — an M-step sequential
    # scan, 4096-deep while loops per layer — is dropped here; top-C
    # budgeting makes it second order.)
    flat = lambda t: t.reshape(*t.shape[:4], cap * d)
    a_vals, a_idx = jax.lax.top_k(flat(prod_max), m_loc)
    b_nvals, b_idx = jax.lax.top_k(-flat(prod_min), m_loc)
    b_vals = -b_nvals
    a_rows = jnp.take_along_axis(
        flat(jnp.broadcast_to(rows_max, prod_max.shape)), a_idx, axis=-1)
    b_rows = jnp.take_along_axis(
        flat(jnp.broadcast_to(rows_min, prod_min.shape)), b_idx, axis=-1)

    base = jnp.zeros((b, hkv, ns, group, sl), jnp.float32)
    bi, hi, si, gi, _ = jnp.meshgrid(
        jnp.arange(b), jnp.arange(hkv), jnp.arange(ns),
        jnp.arange(group), jnp.arange(m_loc), indexing="ij")
    greedy = base.at[bi, hi, si, gi, a_rows].add(
        jnp.where(a_vals > 0, a_vals, 0.0))
    greedy = greedy.at[bi, hi, si, gi, b_rows].add(
        jnp.where(b_vals < 0, b_vals, 0.0))
    greedy = shard_act(greedy, "a3_greedy")

    score_u = jnp.max(greedy, axis=3)                # union over G
    score_u = jnp.where(valid_b, score_u, -jnp.inf)
    score_u = jnp.where(fresh_b & valid_b, jnp.inf, score_u)
    _, idx = jax.lax.top_k(score_u, c_loc)           # [B,Hkv,NS,Cl]
    idx = shard_act(idx, "a3_scores")
    live = jnp.take_along_axis(score_u, idx, axis=-1) > 0
    kc = shard_act(jnp.take_along_axis(kb, idx[..., None], axis=3),
                   "a3_blocks")                      # [B,Hkv,NS,Cl,D]
    vc = shard_act(jnp.take_along_axis(vb, idx[..., None], axis=3),
                   "a3_blocks")
    # int8 K/V: dequantize ONLY the compacted candidates — the per-row
    # scales ride the same idx gather, so S x D stays 1 byte/element
    # and just C x D elements widen to f32
    if k_scale is not None:
        ksc = jnp.take_along_axis(k_scale.reshape(b, hkv, ns, sl),
                                  idx, axis=3)[..., None]
        kc = kc.astype(jnp.float32) * ksc
    if v_scale is not None:
        vsc = jnp.take_along_axis(v_scale.reshape(b, hkv, ns, sl),
                                  idx, axis=3)[..., None]
        vc = vc.astype(jnp.float32) * vsc

    # v7: score/output matmuls take bf16 inputs with f32 accumulation
    # (MXU-native); keeps the gathered K/V in their cache dtype instead
    # of converting to f32 (halves the gather-side bytes).
    kdt = (kc.dtype if jnp.issubdtype(kc.dtype, jnp.floating)
           else jnp.float32)
    vdt = (vc.dtype if jnp.issubdtype(vc.dtype, jnp.floating)
           else jnp.float32)
    scores = jnp.einsum("bhgd,bhncd->bhgnc", qg.astype(kdt),
                        kc.astype(kdt),
                        preferred_element_type=jnp.float32)
    scores = jnp.where(live[:, :, None], scores, -jnp.inf)
    scores = scores.reshape(b, hkv, group, ns * c_loc)
    mx = jnp.max(scores, axis=-1, keepdims=True)
    keep = scores >= mx - thr                        # post-scoring SSIV-D
    w = jnp.where(keep, jnp.exp(scores - mx), 0.0)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-20)
    vcat = vc.astype(vdt).reshape(b, hkv, ns * c_loc, dv)
    out = jnp.einsum("bhgc,bhcd->bhgd", w.astype(vdt), vcat,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, hq, dv).astype(vdt)
    if not return_probe:
        return out

    # ---- captured-score-mass probe: score the FULL ring once in f32,
    # then compare the softmax mass at the kept candidate positions
    # against the total. Both masses use the full-score values and the
    # full-score max, so sel <= full holds exactly (candidate blocks
    # are disjoint and top_k indices are distinct — no double counting).
    kbf = kb.astype(jnp.float32)
    if k_scale is not None:
        kbf = kbf * k_scale.reshape(b, hkv, ns, sl)[..., None]
    fs = jnp.einsum("bhgd,bhnld->bhgnl", qg, kbf,
                    preferred_element_type=jnp.float32)
    fs = jnp.where(valid_b[:, :, None], fs, -jnp.inf)  # [B,Hkv,G,NS,SL]
    mxf = jnp.max(fs, axis=(3, 4), keepdims=True)      # [B,Hkv,G,1,1]
    finite = jnp.isfinite(mxf)
    full_mass = jnp.sum(
        jnp.where(finite & (fs > -jnp.inf), jnp.exp(fs - mxf), 0.0),
        axis=(3, 4))                                   # [B,Hkv,G]
    idx_g = jnp.broadcast_to(idx[:, :, None],
                             (b, hkv, group, ns, c_loc))
    fsel = jnp.take_along_axis(fs, idx_g, axis=4)      # [B,Hkv,G,NS,Cl]
    kept = (keep.reshape(b, hkv, group, ns, c_loc)
            & live[:, :, None])
    sel_mass = jnp.sum(
        jnp.where(kept & finite & (fsel > -jnp.inf),
                  jnp.exp(fsel - mxf), 0.0), axis=(3, 4))
    ratio = jnp.where(full_mass > 0.0, sel_mass
                      / jnp.maximum(full_mass, 1e-20), 0.0)
    cand = jnp.sum(live, axis=(2, 3)).astype(jnp.float32)   # [B,Hkv]
    probe = jnp.stack([jnp.mean(cand, axis=1),
                       jnp.mean(ratio, axis=(1, 2))], axis=1)
    return out, probe
