"""A³ block-sparse attention Pallas TPU kernel.

The TPU realization of the paper's compute-skipping (DESIGN.md §2): the
candidate-selection mask is reduced to kv-block granularity, and the
kernel's grid — built with ``PrefetchScalarGridSpec`` — visits only the
live kv blocks of each query block (``kv_indices``/``kv_counts``). The
QKᵀ and PV MACs for dead blocks are never issued, which is the MXU-aligned
analogue of the ASIC skipping non-candidate rows.

Post-scoring selection (§IV-D) is exact: a first (half-cost: no PV matmul)
pass computes the true masked row max over live blocks, and the second pass
drops every entry whose score trails it by more than ``threshold`` nats
before the weighted sum — precisely the accelerator's subtract-and-compare
module, fused into the softmax.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _block_mask(iq, jk_abs, *, block_q, block_k, seq_q, seq_k, causal,
                window):
    rows = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (seq_k - seq_q)
    cols = jk_abs * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    return mask


def _sparse_rowmax_kernel(
    idx_ref, cnt_ref,               # scalar prefetch
    q_ref, k_ref,                   # inputs
    m_out,                          # output [1, 1, bq]
    m_scr,                          # scratch [bq, 1]
    *, scale, causal, window, block_q, block_k, seq_q, seq_k,
):
    b, h, iq, j = (pl.program_id(i) for i in range(4))
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    live = j < cnt_ref[b, h, iq]
    jk_abs = idx_ref[b, h, iq, j]

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _block_mask(iq, jk_abs, block_q=block_q, block_k=block_k,
                           seq_q=seq_q, seq_k=seq_k, causal=causal,
                           window=window)
        s = jnp.where(mask, s, NEG_INF)
        m_scr[...] = jnp.maximum(m_scr[...], jnp.max(s, -1, keepdims=True))

    @pl.when(j == nj - 1)
    def _emit():
        m_out[0, 0] = m_scr[...][:, 0]


def _sparse_attend_kernel(
    idx_ref, cnt_ref,               # scalar prefetch
    q_ref, k_ref, v_ref, rowmax_ref,
    o_ref,
    l_scr, acc_scr,
    *, scale, causal, window, threshold, block_q, block_k, seq_q, seq_k,
):
    b, h, iq, j = (pl.program_id(i) for i in range(4))
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    live = j < cnt_ref[b, h, iq]
    jk_abs = idx_ref[b, h, iq, j]

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        rm = rowmax_ref[0, 0][:, None]                   # [bq, 1]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _block_mask(iq, jk_abs, block_q=block_q, block_k=block_k,
                           seq_q=seq_q, seq_k=seq_k, causal=causal,
                           window=window)
        if threshold is not None:
            # post-scoring selection: drop entries > threshold nats below max
            mask &= s >= rm - threshold
        p = jnp.where(mask, jnp.exp(s - rm), 0.0)
        l_scr[...] += jnp.sum(p, -1, keepdims=True)
        acc_scr[...] += jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _emit():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = jnp.where(l == 0.0, 0.0,
                                acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "threshold", "scale",
                     "block_q", "block_k", "interpret"),
)
def a3_sparse_attention(
    q: jax.Array,                   # [B, Hq, Sq, D]
    k: jax.Array,                   # [B, Hkv, Sk, D]
    v: jax.Array,                   # [B, Hkv, Sk, Dv]
    kv_indices: jax.Array,          # [B, Hq, nq_blocks, max_blocks] int32
    kv_counts: jax.Array,           # [B, Hq, nq_blocks] int32
    *,
    threshold: Optional[float] = None,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    bq, bk = min(block_q, sq), min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0
    nq = sq // bq
    maxb = kv_indices.shape[-1]
    assert kv_indices.shape == (b, hq, nq, maxb)
    assert kv_counts.shape == (b, hq, nq)

    grid = (b, hq, nq, maxb)

    def q_map(b_, h, iq, j, idx, cnt):
        return (b_, h, iq, 0)

    def kv_map(b_, h, iq, j, idx, cnt):
        return (b_, h // group, idx[b_, h, iq, j], 0)

    def rm_map(b_, h, iq, j, idx, cnt):
        return (b_, h, iq)

    # ---- pass 1: true row max over live candidate blocks ----
    rowmax = pl.pallas_call(
        functools.partial(
            _sparse_rowmax_kernel, scale=scale, causal=causal, window=window,
            block_q=bq, block_k=bk, seq_q=sq, seq_k=sk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), q_map),
                pl.BlockSpec((1, 1, bk, d), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, bq), rm_map),
            scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
        interpret=interpret,
    )(kv_indices, kv_counts, q, k)

    # ---- pass 2: post-scoring mask + weighted sum ----
    out = pl.pallas_call(
        functools.partial(
            _sparse_attend_kernel, scale=scale, causal=causal, window=window,
            threshold=threshold, block_q=bq, block_k=bk, seq_q=sq, seq_k=sk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), q_map),
                pl.BlockSpec((1, 1, bk, d), kv_map),
                pl.BlockSpec((1, 1, bk, dv), kv_map),
                pl.BlockSpec((1, 1, bq), rm_map),
            ],
            out_specs=pl.BlockSpec((1, 1, bq, dv), q_map),
            scratch_shapes=[
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, dv), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dv), q.dtype),
        interpret=interpret,
    )(kv_indices, kv_counts, q, k, v, rowmax)
    return out


def build_block_map(
    block_mask: jax.Array,          # [B, Hq, nq, nk] bool
    max_blocks: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Pack a boolean block mask into (kv_indices, kv_counts) for the kernel.

    Live block ids are compacted to the front (stable order); padding points
    at block 0 and is masked by kv_counts inside the kernel.
    """
    b, h, nq, nk = block_mask.shape
    if max_blocks is None:
        max_blocks = nk
    order = jnp.argsort(~block_mask, axis=-1, stable=True)     # live first
    counts = jnp.sum(block_mask, axis=-1).astype(jnp.int32)
    idx = order[..., :max_blocks].astype(jnp.int32)
    idx = jnp.where(
        jnp.arange(max_blocks)[None, None, None, :] < counts[..., None],
        idx, 0)
    counts = jnp.minimum(counts, max_blocks)
    return idx, counts
