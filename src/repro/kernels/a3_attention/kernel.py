"""A³ block-sparse attention Pallas TPU kernel.

The TPU realization of the paper's compute-skipping (DESIGN.md §2): the
candidate-selection mask is reduced to kv-block granularity, and the
kernel's grid — built with ``PrefetchScalarGridSpec`` — visits only the
live kv blocks of each query block (``kv_indices``/``kv_counts``). The
QKᵀ and PV MACs for dead blocks are never issued, which is the MXU-aligned
analogue of the ASIC skipping non-candidate rows.

GQA-shared KV fetch: the grid iterates over *kv* heads and the whole
query-head group rides in the q block (``[G, bq, D]`` folded to a
``[G·bq, D]`` MXU tile), so each live K/V block streams from HBM exactly
once per group instead of ``group`` times — K/V traffic drops by the GQA
factor. Candidate maps are correspondingly per kv head: the group's
per-query-head candidate sets are **unioned** (``union_block_map_gqa``),
which only ever adds candidates, never removes any.

Post-scoring selection (§IV-D) is exact: a first (half-cost: no PV matmul)
pass computes the true masked row max over live blocks, and the second pass
drops every entry whose score trails it by more than ``threshold`` nats
before the weighted sum — precisely the accelerator's subtract-and-compare
module, fused into the softmax.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _block_mask(iq, jk_abs, *, block_q, block_k, seq_q, seq_k, causal,
                window):
    rows = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (seq_k - seq_q)
    cols = jk_abs * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    return mask


def _group_mask(iq, jk_abs, *, group, block_q, block_k, seq_q, seq_k,
                causal, window):
    """Position mask replicated across the folded GQA group: [G·bq, bk]."""
    m = _block_mask(iq, jk_abs, block_q=block_q, block_k=block_k,
                    seq_q=seq_q, seq_k=seq_k, causal=causal, window=window)
    return jnp.broadcast_to(m[None], (group, block_q, block_k)
                            ).reshape(group * block_q, block_k)


def _sparse_rowmax_kernel(
    idx_ref, cnt_ref,               # scalar prefetch
    q_ref, k_ref,                   # inputs
    m_out,                          # output [1, 1, G, bq]
    m_scr,                          # scratch [G*bq, 1]
    *, group, scale, causal, window, block_q, block_k, seq_q, seq_k,
):
    b, h, iq, j = (pl.program_id(i) for i in range(4))
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    live = j < cnt_ref[b, h, iq]
    jk_abs = idx_ref[b, h, iq, j]

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32).reshape(group * block_q, -1)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _group_mask(iq, jk_abs, group=group, block_q=block_q,
                           block_k=block_k, seq_q=seq_q, seq_k=seq_k,
                           causal=causal, window=window)
        s = jnp.where(mask, s, NEG_INF)
        m_scr[...] = jnp.maximum(m_scr[...], jnp.max(s, -1, keepdims=True))

    @pl.when(j == nj - 1)
    def _emit():
        m_out[0, 0] = m_scr[...][:, 0].reshape(group, block_q)


def _sparse_attend_kernel(
    idx_ref, cnt_ref,               # scalar prefetch
    q_ref, k_ref, v_ref, rowmax_ref,
    o_ref,
    l_scr, acc_scr,
    *, group, scale, causal, window, threshold, block_q, block_k,
    seq_q, seq_k,
):
    b, h, iq, j = (pl.program_id(i) for i in range(4))
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    live = j < cnt_ref[b, h, iq]
    jk_abs = idx_ref[b, h, iq, j]

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32).reshape(group * block_q, -1)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        rm = rowmax_ref[0, 0].reshape(group * block_q)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _group_mask(iq, jk_abs, group=group, block_q=block_q,
                           block_k=block_k, seq_q=seq_q, seq_k=seq_k,
                           causal=causal, window=window)
        if threshold is not None:
            # post-scoring selection: drop entries > threshold nats below max
            mask &= s >= rm - threshold
        p = jnp.where(mask, jnp.exp(s - rm), 0.0)
        l_scr[...] += jnp.sum(p, -1, keepdims=True)
        acc_scr[...] += jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _emit():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        out = jnp.where(l == 0.0, 0.0, acc_scr[...] / safe)
        o_ref[0, 0] = out.reshape(group, block_q, -1).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "threshold", "scale",
                     "block_q", "block_k", "interpret"),
)
def a3_sparse_attention(
    q: jax.Array,                   # [B, Hq, Sq, D]
    k: jax.Array,                   # [B, Hkv, Sk, D]
    v: jax.Array,                   # [B, Hkv, Sk, Dv]
    kv_indices: jax.Array,          # [B, Hkv|Hq, nq_blocks, max_blocks] int32
    kv_counts: jax.Array,           # [B, Hkv|Hq, nq_blocks] int32
    *,
    threshold: Optional[float] = None,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Block-sparse A³ attention with GQA-folded KV streaming.

    ``kv_indices``/``kv_counts`` are per *kv* head. Per-query-head maps
    (head dim ``Hq``) are accepted for convenience and are unioned across
    each GQA group first (a superset: candidates are only ever added).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    bq, bk = min(block_q, sq), min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0
    nq, nk = sq // bq, sk // bk
    assert kv_counts.shape[:2] in ((b, hkv), (b, hq))
    if kv_indices.shape[1] == hq and group > 1:
        kv_indices, kv_counts = union_block_map_gqa(kv_indices, kv_counts,
                                                    group, nk)
    maxb = kv_indices.shape[-1]
    assert kv_indices.shape == (b, hkv, nq, maxb)
    assert kv_counts.shape == (b, hkv, nq)

    # grid over kv heads: each live K/V block is fetched once per GQA
    # group (the query-head group is folded into the q block).
    grid = (b, hkv, nq, maxb)
    qg = q.reshape(b, hkv, group, sq, d)

    def q_map(b_, h, iq, j, idx, cnt):
        return (b_, h, 0, iq, 0)

    def kv_map(b_, h, iq, j, idx, cnt):
        return (b_, h, idx[b_, h, iq, j], 0)

    def rm_map(b_, h, iq, j, idx, cnt):
        return (b_, h, 0, iq)

    kw = dict(group=group, scale=scale, causal=causal, window=window,
              block_q=bq, block_k=bk, seq_q=sq, seq_k=sk)

    # ---- pass 1: true row max over live candidate blocks ----
    rowmax = pl.pallas_call(
        functools.partial(_sparse_rowmax_kernel, **kw),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, group, bq, d), q_map),
                pl.BlockSpec((1, 1, bk, d), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, group, bq), rm_map),
            scratch_shapes=[pltpu.VMEM((group * bq, 1), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, sq), jnp.float32),
        interpret=interpret,
    )(kv_indices, kv_counts, qg, k)

    # ---- pass 2: post-scoring mask + weighted sum ----
    out = pl.pallas_call(
        functools.partial(_sparse_attend_kernel, threshold=threshold, **kw),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, group, bq, d), q_map),
                pl.BlockSpec((1, 1, bk, d), kv_map),
                pl.BlockSpec((1, 1, bk, dv), kv_map),
                pl.BlockSpec((1, 1, group, bq), rm_map),
            ],
            out_specs=pl.BlockSpec((1, 1, group, bq, dv), q_map),
            scratch_shapes=[
                pltpu.VMEM((group * bq, 1), jnp.float32),
                pltpu.VMEM((group * bq, dv), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, sq, dv), q.dtype),
        interpret=interpret,
    )(kv_indices, kv_counts, qg, k, v, rowmax)
    return out.reshape(b, hq, sq, dv)


def build_block_map(
    block_mask: jax.Array,          # [B, H, nq, nk] bool
    max_blocks: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Pack a boolean block mask into (kv_indices, kv_counts) for the kernel.

    Live block ids are compacted to the front (stable order); padding points
    at block 0 and is masked by kv_counts inside the kernel.
    """
    b, h, nq, nk = block_mask.shape
    if max_blocks is None:
        max_blocks = nk
    order = jnp.argsort(~block_mask, axis=-1, stable=True)     # live first
    counts = jnp.sum(block_mask, axis=-1).astype(jnp.int32)
    idx = order[..., :max_blocks].astype(jnp.int32)
    idx = jnp.where(
        jnp.arange(max_blocks)[None, None, None, :] < counts[..., None],
        idx, 0)
    counts = jnp.minimum(counts, max_blocks)
    return idx, counts


def block_map_to_mask(kv_indices: jax.Array, kv_counts: jax.Array,
                      nk: int) -> jax.Array:
    """Inverse of :func:`build_block_map`: expand (indices, counts) back
    to a dense [B, H, nq, nk] boolean block mask."""
    b, h, nq, maxb = kv_indices.shape
    live = jnp.arange(maxb)[None, None, None, :] < kv_counts[..., None]
    bm = jnp.zeros((b, h, nq, nk), dtype=bool)
    bi, hi, qi = jnp.meshgrid(jnp.arange(b), jnp.arange(h), jnp.arange(nq),
                              indexing="ij")
    bi = jnp.broadcast_to(bi[..., None], kv_indices.shape)
    hi = jnp.broadcast_to(hi[..., None], kv_indices.shape)
    qi = jnp.broadcast_to(qi[..., None], kv_indices.shape)
    return bm.at[bi, hi, qi, kv_indices].max(live)


def union_block_map_gqa(
    kv_indices: jax.Array,          # [B, Hq, nq, maxb]
    kv_counts: jax.Array,           # [B, Hq, nq]
    group: int,
    nk: int,
) -> Tuple[jax.Array, jax.Array]:
    """Union per-query-head candidate block maps across each GQA group.

    The folded kernel streams each kv block once per *group*, so the map
    must be per kv head; the union is the superset that preserves every
    head's candidates (never drops attention an individual head wanted).
    """
    b, hq_, nq, _ = kv_indices.shape
    hkv = hq_ // group
    bm = block_map_to_mask(kv_indices, kv_counts, nk)
    bm = bm.reshape(b, hkv, group, nq, nk).any(axis=2)
    return build_block_map(bm)
