"""Pure-jnp oracle for the A³ block-sparse attention kernel.

Implements the *block-dilated* candidate semantics the kernel computes: a
key position participates iff its kv block is live for the query's block,
the causal/window mask admits it, and (optionally) its score is within
``threshold`` nats of the row max over participating positions.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def a3_sparse_attention_ref(
    q: jnp.ndarray,                 # [B, Hq, Sq, D]
    k: jnp.ndarray,                 # [B, Hkv, Sk, D]
    v: jnp.ndarray,                 # [B, Hkv, Sk, Dv]
    kv_indices: jnp.ndarray,        # [B, Hq, nq, maxb] int32
    kv_counts: jnp.ndarray,         # [B, Hq, nq] int32
    *,
    threshold: Optional[float] = None,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    group = hq // hkv
    bq, bk = min(block_q, sq), min(block_k, sk)
    nq, nk = sq // bq, sk // bk
    maxb = kv_indices.shape[-1]
    if scale is None:
        scale = d ** -0.5

    # expand (indices, counts) back to a dense [B, Hq, nq, nk] block mask
    live = jnp.arange(maxb)[None, None, None, :] < kv_counts[..., None]
    bm = jnp.zeros((b, hq, nq, nk), dtype=bool)
    bi, hi, qi = jnp.meshgrid(jnp.arange(b), jnp.arange(hq), jnp.arange(nq),
                              indexing="ij")
    bi = jnp.broadcast_to(bi[..., None], kv_indices.shape)
    hi = jnp.broadcast_to(hi[..., None], kv_indices.shape)
    qi = jnp.broadcast_to(qi[..., None], kv_indices.shape)
    bm = bm.at[bi, hi, qi, kv_indices].max(live)

    # element-level mask
    elem = jnp.repeat(jnp.repeat(bm, bq, axis=2), bk, axis=3)  # [B,Hq,Sq,Sk]
    rows = jnp.arange(sq)[:, None] + (sk - sq)
    cols = jnp.arange(sk)[None, :]
    if causal:
        elem &= (cols <= rows)[None, None]
    if window is not None:
        elem &= (cols > rows - window)[None, None]

    kq = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vq = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kq) * scale
    s = jnp.where(elem, s, -jnp.inf)

    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    if threshold is not None:
        elem &= s >= m - threshold
        s = jnp.where(elem, s, -jnp.inf)
    p = jnp.where(elem, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    w = p / jnp.maximum(l, 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vq).astype(q.dtype)
