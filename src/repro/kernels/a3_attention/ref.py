"""Pure-jnp oracle for the A³ block-sparse attention kernel.

Implements the *block-dilated* candidate semantics the kernel computes: a
key position participates iff its kv block is live for the query's block,
the causal/window mask admits it, and (optionally) its score is within
``threshold`` nats of the row max over participating positions.

Matches the kernel's GQA contract: candidate maps are per kv head (every
query head in a group shares its kv head's — unioned — map). Per-query-
head maps are accepted and unioned across the group first, exactly as the
kernel does.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def a3_sparse_attention_ref(
    q: jnp.ndarray,                 # [B, Hq, Sq, D]
    k: jnp.ndarray,                 # [B, Hkv, Sk, D]
    v: jnp.ndarray,                 # [B, Hkv, Sk, Dv]
    kv_indices: jnp.ndarray,        # [B, Hkv|Hq, nq, maxb] int32
    kv_counts: jnp.ndarray,         # [B, Hkv|Hq, nq] int32
    *,
    threshold: Optional[float] = None,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    from repro.kernels.a3_attention.kernel import (
        block_map_to_mask,
        union_block_map_gqa,
    )

    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    group = hq // hkv
    bq, bk = min(block_q, sq), min(block_k, sk)
    nq, nk = sq // bq, sk // bk
    if scale is None:
        scale = d ** -0.5

    if kv_indices.shape[1] == hq and group > 1:
        kv_indices, kv_counts = union_block_map_gqa(kv_indices, kv_counts,
                                                    group, nk)
    bm = block_map_to_mask(kv_indices, kv_counts, nk)   # [B, Hkv, nq, nk]
    bm = jnp.repeat(bm, group, axis=1)                  # [B, Hq, nq, nk]

    # element-level mask
    elem = jnp.repeat(jnp.repeat(bm, bq, axis=2), bk, axis=3)  # [B,Hq,Sq,Sk]
    rows = jnp.arange(sq)[:, None] + (sk - sq)
    cols = jnp.arange(sk)[None, :]
    if causal:
        elem &= (cols <= rows)[None, None]
    if window is not None:
        elem &= (cols > rows - window)[None, None]

    kq = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vq = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kq) * scale
    s = jnp.where(elem, s, -jnp.inf)

    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    if threshold is not None:
        elem &= s >= m - threshold
        s = jnp.where(elem, s, -jnp.inf)
    p = jnp.where(elem, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    w = p / jnp.maximum(l, 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vq).astype(q.dtype)
