"""Public entry point: A³-approximate attention with block skipping.

Builds the candidate block map from the core greedy selection and invokes
either the Pallas kernel (deployment) or the jnp reference (analyzable
HLO / CPU validation).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import A3Config, A3Mode
from repro.core.candidate_selection import select_candidates_batch, sort_key_columns
from repro.kernels.a3_attention.kernel import a3_sparse_attention, build_block_map
from repro.kernels.a3_attention.ref import a3_sparse_attention_ref


def candidate_block_map_for_heads(
    q: jax.Array,                   # [B, Hq, Sq, D]
    k: jax.Array,                   # [B, Hkv, Sk, D]
    cfg: A3Config,
    k_scale: Optional[jax.Array] = None,   # [B, Hkv, D] fp32 (int8 k)
) -> Tuple[jax.Array, jax.Array]:
    """Run greedy candidate selection per (batch, head, query), reduce to
    kv-block granularity, and union across each GQA group — the kernel
    streams K/V per kv head, so the map is per kv head too. Returns
    (kv_indices [B, Hkv, nq, maxb], kv_counts [B, Hkv, nq]).

    With ``k_scale`` the keys may be int8 (per-(batch, kv-head, column)
    symmetric quantization): the positive scale is folded into the query
    instead of dequantizing S x D keys — column sort order and the
    greedy walk's sign split are both scale-invariant."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = d ** -0.5
    m = cfg.m_for(sk)

    qs = q * scale
    if k_scale is not None:
        qs = (qs.astype(jnp.float32)
              * jnp.repeat(k_scale, group, axis=1)[:, :, None, :])

    def per_bh(qh, kh):             # qh [Sq, d] (pre-scaled), kh [Sk, d]
        sk_sorted = sort_key_columns(kh)
        mask, _ = select_candidates_batch(sk_sorted, qh, m)
        return mask                  # [Sq, Sk]

    kq = jnp.repeat(k, group, axis=1)
    masks = jax.vmap(jax.vmap(per_bh))(qs, kq)           # [B, Hq, Sq, Sk]
    bq, bk = min(cfg.block_q, sq), min(cfg.block_k, sk)
    nq, nk = sq // bq, sk // bk
    bm = masks.reshape(b, hq, nq, bq, nk, bk).any(axis=(3, 5))
    bm = bm.reshape(b, hkv, group, nq, nk).any(axis=2)   # GQA union
    return build_block_map(bm)


def a3_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: A3Config,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    k_scale: Optional[jax.Array] = None,   # [B, Hkv, D] fp32 (int8 k)
    v_scale: Optional[jax.Array] = None,   # [B, Hkv, D] fp32 (int8 v)
    use_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """A³-approximate (or exact when cfg.mode == OFF) fused attention.

    ``k_scale``/``v_scale`` enable int8 K/V: candidate selection scores
    the int8 keys directly (scale folded into the query inside
    :func:`candidate_block_map_for_heads`); only the fused softmax
    kernel sees dequantized values."""

    def _dequant(x, s):
        return (x.astype(jnp.float32) * s[:, :, None, :]).astype(q.dtype)

    if cfg.mode == A3Mode.OFF:
        from repro.kernels.flash_attention.ops import fused_attention
        if k_scale is not None:
            k = _dequant(k, k_scale)
        if v_scale is not None:
            v = _dequant(v, v_scale)
        return fused_attention(q, k, v, causal=causal, window=window,
                               use_kernel=use_kernel, interpret=interpret)

    kv_indices, kv_counts = candidate_block_map_for_heads(
        q, k, cfg, k_scale=k_scale)
    if k_scale is not None:
        k = _dequant(k, k_scale)
    if v_scale is not None:
        v = _dequant(v, v_scale)
    threshold = cfg.threshold_nats
    fn = a3_sparse_attention if use_kernel else a3_sparse_attention_ref
    kw = dict(threshold=threshold, causal=causal, window=window,
              block_q=cfg.block_q, block_k=cfg.block_k)
    if use_kernel:
        kw["interpret"] = interpret
    return fn(q, k, v, kv_indices, kv_counts, **kw)
