"""Assigned architecture configs. Importing this package populates the
registry used by ``repro.config.get_arch`` / ``--arch`` flags."""
from repro.configs import (  # noqa: F401
    deepseek_moe_16b,
    grok_1_314b,
    gemma3_4b,
    phi4_mini_3_8b,
    h2o_danube_1_8b,
    internlm2_1_8b,
    recurrentgemma_2b,
    xlstm_350m,
    musicgen_medium,
    internvl2_2b,
)
