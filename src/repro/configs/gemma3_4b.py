"""gemma3-4b [hf:google/gemma-3-*-pt; unverified].

5:1 local:global attention pattern (window 1024 local layers, full
global layers), 128k context, GQA kv=4, head_dim 256, 262k vocab, tied
embeddings. A^3 applies most usefully to the *global* layers — the local
layers already bound the search window (DESIGN.md SS5).
"""
from repro.config import AttentionKind, ModelConfig, register_arch


@register_arch("gemma3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        num_layers=34,
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        d_ff=10240,
        vocab_size=262144,
        head_dim=256,
        max_seq_len=131072,
        rope_theta=1_000_000.0,
        attention_kind=AttentionKind.LOCAL_GLOBAL,
        local_global_pattern=5,
        window_size=1024,
        tie_embeddings=True,
        act="gelu",
    )
