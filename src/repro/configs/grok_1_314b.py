"""grok-1-314b [hf:xai-org/grok-1; unverified].

8 experts top-2, 64L, d_model 6144, 48 heads (GQA kv=8), expert FFN
32768, vocab 131072, logit softcap 30. The 8-expert stack does not divide
the 16-way model axis, so the sharding rules fall back to TP *inside*
each expert (DESIGN.md SS4) — exercised by the dry-run.
"""
from repro.config import ModelConfig, MoEConfig, register_arch


@register_arch("grok-1-314b")
def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        head_dim=128,
        rope_theta=10000.0,
        logit_softcap=30.0,
        moe=MoEConfig(num_experts=8, num_shared=0, top_k=2,
                      d_expert=32768, num_dense_layers=0),
    )
