"""deepseek-moe-16b [arXiv:2401.06066; hf].

Fine-grained MoE: 64 routed experts (top-6) + 2 shared experts, expert
width 1408; the first layer is a dense FFN (paper SS3.2). GQA kv=16 (MHA
at this size). 28L, d_model 2048, vocab 102400.
"""
from repro.config import ModelConfig, MoEConfig, register_arch


@register_arch("deepseek-moe-16b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=10944,                      # dense layer-0 FFN width
        vocab_size=102400,
        head_dim=128,
        rope_theta=10000.0,
        moe=MoEConfig(num_experts=64, num_shared=2, top_k=6,
                      d_expert=1408, num_dense_layers=1),
    )
