"""internvl2-2b [arXiv:2404.16821; hf].

InternViT-300M (STUB frontend: precomputed patch embeddings) +
InternLM2-1.8B language backbone: 24L, d_model 2048, 16H kv=8, d_ff
8192, vocab 92553 (padded to 92672 = next multiple of 128 for MXU/mesh
divisibility; see decoder.padded_vocab).
"""
from repro.config import ModelConfig, register_arch


@register_arch("internvl2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=92553,
        head_dim=128,
        rope_theta=1_000_000.0,
        frontend="vision_patches",
    )
