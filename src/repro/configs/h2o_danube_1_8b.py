"""h2o-danube-1.8b [arXiv:2401.16818; hf].

Llama/Mistral-style dense decoder with sliding-window attention
(window 4096), 24L, d_model 2560, 32 heads (GQA kv=8, head_dim 80),
vocab 32000. The SWA window bounds the KV working set, which is what
makes long_500k runnable (ring cache of 4096).
"""
from repro.config import AttentionKind, ModelConfig, register_arch


@register_arch("h2o-danube-1.8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        num_layers=24,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        head_dim=80,
        rope_theta=10000.0,
        attention_kind=AttentionKind.SLIDING,
        window_size=4096,
    )
