"""xlstm-350m [arXiv:2405.04517; unverified].

xLSTM[7:1]: 7 mLSTM blocks per sLSTM block, 24L, d_model 1024, 4 heads,
no separate FFN (d_ff=0 — the blocks carry their own projections),
vocab 50304. No softmax score vector over n keys exists in either block
type, so A^3 is inapplicable by construction (DESIGN.md SS5) — the arch
runs WITHOUT the technique.
"""
from repro.config import BlockKind, ModelConfig, register_arch

_PATTERN = (BlockKind.MLSTM,) * 7 + (BlockKind.SLSTM,)


@register_arch("xlstm-350m")
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        head_dim=256,
        block_pattern=_PATTERN,
        tie_embeddings=True,
    )
