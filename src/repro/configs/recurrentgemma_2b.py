"""recurrentgemma-2b [arXiv:2402.19427 (Griffin); hf].

Hybrid: repeating (RG-LRU, RG-LRU, local attention) pattern — 1
attention per 3 blocks ("1:2" ratio assigned), window 2048, GQA kv=1
(MQA), head_dim 256, d_model 2560, vocab 256000. RG-LRU blocks carry no
QK search, so A^3 applies only to the attention third (DESIGN.md SS5).
"""
from repro.config import AttentionKind, BlockKind, ModelConfig, register_arch


@register_arch("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        d_ff=7680,
        vocab_size=256000,
        head_dim=256,
        rope_theta=10000.0,
        attention_kind=AttentionKind.SLIDING,
        window_size=2048,
        block_pattern=(BlockKind.RGLRU, BlockKind.RGLRU,
                       BlockKind.ATTENTION),
        tie_embeddings=True,
        act="gelu",
    )
