"""phi4-mini-3.8b [arXiv:2412.08905; hf].

Dense decoder: RoPE, SwiGLU, GQA kv=8, 32L, d_model 3072, 200k vocab.
The canonical full-attention target for A^3 (DESIGN.md SS5).
"""
from repro.config import ModelConfig, register_arch


@register_arch("phi4-mini-3.8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=200064,
        head_dim=128,
        rope_theta=10000.0,
    )
