"""musicgen-medium [arXiv:2306.05284; hf].

Decoder-only transformer over EnCodec tokens: 48L, d_model 1536, 24
heads (MHA, kv=24, head_dim 64), d_ff 6144, vocab 2048 (one codebook).
The EnCodec frontend is a STUB — ``input_specs`` provides precomputed
frame embeddings (assignment note); the backbone is what we build.
"""
from repro.config import ModelConfig, register_arch


@register_arch("musicgen-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        head_dim=64,
        rope_theta=10000.0,
        frontend="audio_frames",
        num_codebooks=4,
        act="gelu",
    )
