"""Configuration system for the repro framework.

Plain dataclasses (no external deps), a registry for named architecture
configs, and the shape suites assigned to this paper. Everything the
launcher / dry-run / tests consume flows through these types.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Attention / block variants
# ---------------------------------------------------------------------------

class AttentionKind(str, enum.Enum):
    FULL = "full"                  # global causal attention
    SLIDING = "sliding"            # sliding-window attention
    LOCAL_GLOBAL = "local_global"  # pattern of local + global layers (gemma3)


class BlockKind(str, enum.Enum):
    ATTENTION = "attention"
    RGLRU = "rglru"        # RecurrentGemma RG-LRU block
    MLSTM = "mlstm"        # xLSTM matrix-memory block
    SLSTM = "slstm"        # xLSTM scalar-memory block


class A3Mode(str, enum.Enum):
    OFF = "off"                     # exact attention
    CONSERVATIVE = "conservative"   # paper: M = n/2, T = 5%
    AGGRESSIVE = "aggressive"       # paper: M = n/8, T = 10%
    CUSTOM = "custom"


@dataclass(frozen=True)
class A3Config:
    """Configuration for the paper's approximation scheme."""
    mode: A3Mode = A3Mode.OFF
    # M: candidate-selection iteration count. In the paper M is given as a
    # fraction of n; `m_fraction` expresses that; `m_absolute` overrides.
    m_fraction: float = 0.5
    m_absolute: Optional[int] = None
    # T (%): post-scoring threshold. t = -ln(T/100).
    threshold_pct: float = 5.0
    # Fixed-point quantization (paper: i=4, f=4). None disables fake-quant.
    int_bits: Optional[int] = None
    frac_bits: Optional[int] = None
    # Use the 2-LUT exponent decomposition numerics for softmax.
    lut_exponent: bool = False
    # Block size used by the block-sparse TPU kernel (MXU granularity).
    block_q: int = 128
    block_k: int = 128
    # Distributed selection (SSPerf H3.v4): the KV ring is split into
    # ``select_shards`` blocks (aligned with the model mesh axis), keys
    # are column-sorted per block at comprehension time, and each shard
    # runs the greedy walk + top-(C/NS) gather locally — no global
    # top_k collectives. 1 = single-shard (paper-literal) selection.
    select_shards: int = 1

    def m_for(self, n: int) -> int:
        if self.m_absolute is not None:
            return min(self.m_absolute, n)
        return max(1, int(round(self.m_fraction * n)))

    @property
    def threshold_nats(self) -> float:
        import math
        return -math.log(self.threshold_pct / 100.0)

    @staticmethod
    def conservative() -> "A3Config":
        return A3Config(mode=A3Mode.CONSERVATIVE, m_fraction=0.5, threshold_pct=5.0)

    @staticmethod
    def aggressive() -> "A3Config":
        return A3Config(mode=A3Mode.AGGRESSIVE, m_fraction=0.125, threshold_pct=10.0)


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int            # routed experts
    num_shared: int = 0         # always-on shared experts (deepseek-moe)
    top_k: int = 2
    d_expert: int = 0           # per-expert FFN hidden dim (0 -> d_ff)
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    # First k dense layers (deepseek-moe uses 1 dense layer at the bottom).
    num_dense_layers: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # moe | dense | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    max_seq_len: int = 131072
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attention_kind: AttentionKind = AttentionKind.FULL
    window_size: int = 4096                  # for sliding / local layers
    local_global_pattern: int = 0            # gemma3: 5 => 5 local : 1 global
    # Block layout. Empty -> all attention. Otherwise a repeating pattern,
    # e.g. recurrentgemma (rglru, rglru, attention).
    block_pattern: Tuple[BlockKind, ...] = ()
    moe: Optional[MoEConfig] = None
    # Modality frontend stub: tokens are replaced by precomputed embeddings.
    frontend: Optional[str] = None           # None | "audio_frames" | "vision_patches"
    num_codebooks: int = 1                   # musicgen parallel codebooks
    # activation / misc
    act: str = "swiglu"                      # swiglu | gelu
    logit_softcap: float = 0.0
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    def block_kind(self, layer_idx: int) -> BlockKind:
        if not self.block_pattern:
            return BlockKind.ATTENTION
        return self.block_pattern[layer_idx % len(self.block_pattern)]

    def layer_is_global(self, layer_idx: int) -> bool:
        """For LOCAL_GLOBAL patterns: every (pattern+1)-th layer is global."""
        if self.attention_kind != AttentionKind.LOCAL_GLOBAL:
            return self.attention_kind == AttentionKind.FULL
        p = self.local_global_pattern
        return (layer_idx % (p + 1)) == p

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, h = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        attn = d * (n_q * h) + 2 * d * (n_kv * h) + (n_q * h) * d
        if self.act == "swiglu":
            ffn_dense = 3 * self.d_model * self.d_ff
        else:
            ffn_dense = 2 * self.d_model * self.d_ff
        total = 0
        for i in range(self.num_layers):
            kind = self.block_kind(i)
            if kind == BlockKind.ATTENTION:
                total += attn
            elif kind == BlockKind.RGLRU:
                # conv1d + gates: in/out proj (d->d_rnn->d), rg-lru params
                d_rnn = n_q * h
                total += 2 * d * d_rnn + 4 * d_rnn
            elif kind == BlockKind.MLSTM:
                total += d * (n_q * h) * 3 + (n_q * h) * d + 2 * d * 2 * d
            elif kind == BlockKind.SLSTM:
                total += 4 * d * d + 4 * d * d
            # FFN
            if kind in (BlockKind.MLSTM, BlockKind.SLSTM) and self.d_ff == 0:
                pass  # xlstm has no separate FFN
            elif self.moe is not None and i >= self.moe.num_dense_layers:
                de = self.moe.d_expert or self.d_ff
                n_exp = self.moe.num_experts + self.moe.num_shared
                total += 3 * self.d_model * de * n_exp + d * self.moe.num_experts
            else:
                total += ffn_dense
            total += 2 * d  # norms
        total += self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        de = self.moe.d_expert or self.d_ff
        n_exp = self.moe.num_experts + self.moe.num_shared
        n_act = self.moe.top_k + self.moe.num_shared
        moe_layers = self.num_layers - self.moe.num_dense_layers
        dead = 3 * self.d_model * de * (n_exp - n_act) * moe_layers
        return self.param_count() - dead


# ---------------------------------------------------------------------------
# Shapes (assigned suites)
# ---------------------------------------------------------------------------

class ShapeKind(str, enum.Enum):
    TRAIN = "train"
    PREFILL = "prefill"
    DECODE = "decode"


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: ShapeKind
    seq_len: int
    global_batch: int

    @property
    def tokens_per_step(self) -> int:
        if self.kind == ShapeKind.DECODE:
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


SHAPE_SUITE: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", ShapeKind.TRAIN, 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", ShapeKind.PREFILL, 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", ShapeKind.DECODE, 32768, 128),
    "long_500k": ShapeConfig("long_500k", ShapeKind.DECODE, 524288, 1),
}

# Archs allowed to run long_500k (sub-quadratic attention); see DESIGN.md §6.
LONG_CONTEXT_ARCHS = frozenset(
    {"recurrentgemma-2b", "xlstm-350m", "h2o-danube-1.8b", "gemma3-4b"}
)


def applicable_shapes(arch: str) -> List[str]:
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        names.append("long_500k")
    return names


# ---------------------------------------------------------------------------
# Training / runtime configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    # dtypes for optimizer state ("float32" | "bfloat16")
    m_dtype: str = "float32"
    v_dtype: str = "float32"


@dataclass(frozen=True)
class ShardingConfig:
    # logical parallelism knobs
    fsdp: bool = True            # shard params/opt-state over the data axis
    tensor_parallel: bool = True
    expert_parallel: bool = True
    sequence_parallel: bool = False   # shard sequence/KV over the data axis
    remat: str = "full"          # none | full | dots
    grad_compression: bool = False    # int8 + error-feedback on the pod axis
    microbatches: int = 1             # >1 enables gradient accumulation
    # perf knobs (SSPerf hillclimbs)
    attn_chunk: int = 1024            # flash-attention KV chunk length
    ce_chunk: int = 512               # chunked cross-entropy tokens/chunk
    attn_dtype: str = "float32"       # score/accumulator dtype in attention
    # mesh-axis name mapping (alternative mesh factorizations, SSPerf H2):
    # logical role -> mesh axis name(s). Defaults match the production
    # (pod, data, model) mesh.
    dp_axes: Tuple[str, ...] = ("pod", "data", "ep")
    fsdp_axis: str = "data"
    tp_axis: str = "model"
    ep_axis: str = "model"


@dataclass(frozen=True)
class CheckpointConfig:
    directory: str = "/tmp/repro_ckpt"
    save_every: int = 100
    keep: int = 3
    async_save: bool = True


@dataclass(frozen=True)
class FaultToleranceConfig:
    step_timeout_s: float = 1800.0     # watchdog deadline per step
    max_restarts: int = 10
    elastic: bool = True               # allow restore onto a different mesh


@dataclass(frozen=True)
class ServeConfig:
    """Engine / admission-prefill knobs (``serve.engine.ServeEngine``)."""
    slots: int = 4
    max_len: int = 2048
    # Admission-prefill granularity: prompts prefill in chunks of this
    # many tokens, one ragged batched dispatch per engine tick, so a
    # long prompt never stalls decoding slots for more than one chunk
    # and multiple queued prompts share a single padded dispatch.
    # Chunked admission covers EVERY arch (the per-segment mixer-state
    # interface carries recurrent mid-prompt state across chunks).
    # None = default admission chunk of min(max_len, 512) — same
    # dispatch, no separate code path; prompts <= 512 tokens still
    # admit in a single dispatch.
    prefill_chunk: Optional[int] = None
    # Adaptive admission chunking: when set, ticks where >= 1 slot is
    # actively decoding shrink the effective chunk to this floor (bounds
    # the admission stall those decoders see), while a cold queue (no
    # decoders to stall) drains at the full prefill_chunk. None
    # disables the policy (fixed chunk).
    prefill_chunk_min: Optional[int] = None
    # Paged prefix cache (serve.prefix_cache): token positions per page
    # (trie edge length — admitted prompts are recorded and matched at
    # page granularity) and the total page budget of the device pool
    # (LRU eviction above it). cache_pages=0 disables prefix reuse.
    page_size: int = 64
    cache_pages: int = 0
    # A^3: decode steps a slot may accumulate past its sorted_upto
    # watermark before its key columns are re-sorted (in-graph: the
    # watermark check and the fold both live inside the decode dispatch).
    resort_every: int = 64
    # Decode steps per jitted dispatch (``decoder.decode_block``): the
    # T-step inner loop runs device-resident under one ``lax.scan`` with
    # in-graph sampling, and the host syncs once per block to harvest
    # the [slots, T] token ring — host syncs per token ~ 1/T.
    decode_block: int = 1
    # Dispatch pipelining: how many decode-block ring harvests may stay
    # in flight (device-side, unharvested) behind the tick loop. 0 is
    # the synchronous engine — each tick blocks on its own ring
    # (bit-identical to the historical behavior, pinned by test). At
    # depth d, tick N's ring is harvested only after tick N+d's
    # dispatches are issued, so the device pipelines d+1 blocks while
    # the host does bookkeeping on the harvested (delayed) view; the
    # next block's input tokens come from the device-resident carry, so
    # no harvest ever sits on the dispatch critical path.
    pipeline_depth: int = 0
    # Route decode attention through the fused single-pass Pallas kernel
    # (TPU; the jnp reference path is the CPU/CI default).
    use_kernel: bool = False
    # Sampling: temperature == 0 pins greedy argmax (the conformance-
    # tested path); temperature > 0 draws in-graph from the tempered
    # softmax, keyed per (seed, request uid, position) so draws are
    # invariant to how steps are blocked into dispatches and
    # decorrelated across requests.
    temperature: float = 0.0
    sample_seed: int = 0
    # Bounded admission: maximum QUEUED requests (0 = unbounded, the
    # historical behavior). When the bound is hit, ``shed_policy``
    # picks the load-shedding victim: "reject-new" sheds the arriving
    # request, "evict-oldest-queued" sheds the queue head (freshest-
    # first service under overload). Shed requests terminate REJECTED
    # — ``submit`` still returns a uid, it does not raise.
    max_queue: int = 0
    shed_policy: str = "reject-new"
    # Default per-request deadline: a request not FINISHED within this
    # many engine ticks of submission terminates EXPIRED (enforced at
    # tick boundaries; ``submit(deadline_ticks=...)`` overrides per
    # request). None = no deadline.
    deadline_ticks: Optional[int] = None
    # Prefix-cache pool precision: "int8" stores KV pages (and A^3
    # sorted-key leaf snapshots) as int8 with per-page / per-sorted-
    # column fp32 scales — ~2x pages held at equal HBM — dequantized
    # inside the one-dispatch warm gather. "none" keeps the pool in the
    # serving dtype (token-for-token identical to no cache). Slot ring
    # K/V always stays in the serving dtype; only the pool quantizes.
    kv_quant: str = "none"
    # Host-RAM L2 page tier (serve.page_store): byte budget for
    # checksummed blobs of evicted prefix-cache pages — eviction
    # demotes instead of freeing, and a later lookup promotes verified
    # blobs back into the device pool (corrupt blobs degrade that node
    # to cold prefill, never wrong tokens). 0 disables the tier
    # (historical free-on-evict).
    l2_bytes: int = 0
    # Telemetry plane (serve.telemetry): metrics registry + per-request
    # span tracing + A^3 approximation-quality probes. Off (default) is
    # bit-identical to the untelemetered engine; on adds host-side
    # bookkeeping only — the A^3 probe rides the existing deferred ring
    # harvest, so stats["host_syncs"] is pinned either way.
    telemetry: bool = False
    # Sample the in-graph A^3 quality probe on every N-th decode-block
    # dispatch (1 = every block; larger = cheaper, sparser samples).
    telemetry_every: int = 8
    # Ring-buffer capacity of the structured trace-event log (oldest
    # events drop first; the log is a flight recorder, not an archive).
    trace_events: int = 4096
    # Bounded retention of terminal per-request bookkeeping: keep at
    # most this many terminal entries in the status/result maps (FIFO
    # eviction), and pop results on first read. 0 = historical
    # unbounded maps (a long-running engine grows without bound).
    retain_results: int = 0

    def __post_init__(self):
        # fail at construction, not three layers deep in the engine: a
        # nonsensical knob silently admitted here used to surface as a
        # shape error (or worse, a zero-length lane) at dispatch time
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {self.max_len}")
        if self.prefill_chunk is not None and self.prefill_chunk <= 0:
            raise ValueError(
                f"prefill_chunk must be positive, got "
                f"{self.prefill_chunk} (use None for the default chunk)")
        if self.prefill_chunk_min is not None:
            if self.prefill_chunk_min <= 0:
                raise ValueError(
                    f"prefill_chunk_min must be positive, got "
                    f"{self.prefill_chunk_min} (use None to disable the "
                    f"adaptive policy)")
            if self.prefill_chunk is not None \
                    and self.prefill_chunk_min > self.prefill_chunk:
                raise ValueError(
                    f"prefill_chunk_min ({self.prefill_chunk_min}) must "
                    f"not exceed prefill_chunk ({self.prefill_chunk})")
        if self.decode_block < 1:
            raise ValueError(
                f"decode_block must be >= 1, got {self.decode_block}")
        if self.pipeline_depth < 0:
            raise ValueError(
                f"pipeline_depth must be >= 0, got {self.pipeline_depth} "
                f"(0 = synchronous harvest)")
        if self.page_size < 1:
            raise ValueError(
                f"page_size must be >= 1, got {self.page_size}")
        if self.cache_pages < 0:
            raise ValueError(
                f"cache_pages must be >= 0, got {self.cache_pages} "
                f"(0 disables the prefix cache)")
        if self.temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.max_queue < 0:
            raise ValueError(
                f"max_queue must be >= 0, got {self.max_queue} "
                f"(0 = unbounded queue)")
        if self.shed_policy not in ("reject-new", "evict-oldest-queued"):
            raise ValueError(
                f"shed_policy must be 'reject-new' or "
                f"'evict-oldest-queued', got {self.shed_policy!r}")
        if self.deadline_ticks is not None and self.deadline_ticks < 1:
            raise ValueError(
                f"deadline_ticks must be >= 1, got "
                f"{self.deadline_ticks} (use None for no deadline)")
        if self.kv_quant not in ("none", "int8"):
            raise ValueError(
                f"kv_quant must be 'none' or 'int8', got "
                f"{self.kv_quant!r}")
        if self.l2_bytes < 0:
            raise ValueError(
                f"l2_bytes must be >= 0, got {self.l2_bytes} "
                f"(0 disables the host-RAM L2 page tier)")
        if self.telemetry_every < 1:
            raise ValueError(
                f"telemetry_every must be >= 1, got "
                f"{self.telemetry_every}")
        if self.trace_events < 1:
            raise ValueError(
                f"trace_events must be >= 1, got {self.trace_events}")
        if self.retain_results < 0:
            raise ValueError(
                f"retain_results must be >= 0, got "
                f"{self.retain_results} (0 = unbounded retention)")


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    a3: A3Config = field(default_factory=A3Config)
    serve: ServeConfig = field(default_factory=ServeConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    checkpoint: CheckpointConfig = field(default_factory=CheckpointConfig)
    fault: FaultToleranceConfig = field(default_factory=FaultToleranceConfig)
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_arch(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_arch(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw: Dict[str, Any] = dict(
        num_layers=min(cfg.num_layers, 2 if not cfg.block_pattern
                       else len(cfg.block_pattern)),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        max_seq_len=512,
        window_size=64,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            d_expert=64 if cfg.moe.d_expert else 0,
            num_dense_layers=min(cfg.moe.num_dense_layers, 1))
    return dataclasses.replace(cfg, **kw)
