"""Fault tolerance: step watchdog + restart-from-checkpoint supervisor.

On a real multi-pod deployment the failure modes are (a) a host dies ->
the coordinator re-launches and every process restores from the latest
checkpoint, possibly onto a smaller mesh (elastic), and (b) a straggler
holds the step hostage -> a deadline fires and the step is treated as
failed. Both reduce to the same control flow, which is what we implement
and test here:

  run_with_restarts(body)  — calls ``body(restart_count)``; on any
      exception (including WatchdogTimeout) re-invokes up to
      ``max_restarts`` times. ``body`` is responsible for restoring from
      the CheckpointManager (see train_loop).

  Watchdog — wraps a step callable; if a step's wall time exceeds the
      deadline the *next* call raises WatchdogTimeout. (JAX dispatch is
      async; we time the blocking result fetch, which is where a hung
      collective manifests.)
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional


class WatchdogTimeout(RuntimeError):
    pass


class Watchdog:
    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s

    def run(self, fn: Callable[[], Any]) -> Any:
        """Run ``fn`` under a deadline. Returns its result or raises
        WatchdogTimeout. The runaway thread is abandoned (daemonized) —
        on real hardware the process would be killed by the supervisor."""
        result: list = [None]
        error: list = [None]
        done = threading.Event()

        def target():
            try:
                result[0] = fn()
            except BaseException as e:          # noqa: BLE001
                error[0] = e
            finally:
                done.set()

        t = threading.Thread(target=target, daemon=True)
        t.start()
        if not done.wait(self.timeout_s):
            raise WatchdogTimeout(
                f"step exceeded {self.timeout_s}s deadline (straggler/hang)")
        if error[0] is not None:
            raise error[0]
        return result[0]


def run_with_restarts(body: Callable[[int], Any], max_restarts: int = 10,
                      on_restart: Optional[Callable[[int, BaseException],
                                                    None]] = None) -> Any:
    """Supervisor loop: call ``body(attempt)``; restart on failure."""
    attempt = 0
    while True:
        try:
            return body(attempt)
        except BaseException as e:              # noqa: BLE001
            attempt += 1
            if attempt > max_restarts:
                raise
            if on_restart is not None:
                on_restart(attempt, e)
