"""Training loop: data pipeline + train step + checkpointing + watchdog,
wired for restart-from-checkpoint fault tolerance.

``train_loop`` is the single-invocation loop; ``train_with_recovery``
wraps it in the restart supervisor so an injected failure (tests) or a
real crash resumes from the latest checkpoint with the data pipeline
seeked to the right step.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.config import RunConfig
from repro.data.synthetic import SyntheticLM, make_lm_batch
from repro.models.decoder import padded_vocab
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import Watchdog, run_with_restarts
from repro.train.step import TrainState, init_train_state, make_train_step


def train_loop(
    run: RunConfig,
    *,
    mesh=None,
    num_steps: Optional[int] = None,
    state: Optional[TrainState] = None,
    start_step: int = 0,
    ckpt: Optional[CheckpointManager] = None,
    hooks: Optional[List[Callable[[int, Dict], None]]] = None,
    fail_at_step: Optional[int] = None,       # test hook: inject a crash
) -> Dict[str, Any]:
    cfg = run.model
    num_steps = num_steps or run.optimizer.total_steps
    step_fn = make_train_step(run, mesh)
    if state is None:
        state = init_train_state(jax.random.PRNGKey(run.seed), run)
    if ckpt is None:
        ckpt = CheckpointManager(run.checkpoint)
    watchdog = Watchdog(run.fault.step_timeout_s)

    pipeline = SyntheticLM(run.shape.global_batch, run.shape.seq_len,
                           cfg.vocab_size, seed=run.seed,
                           start_step=start_step)
    losses: List[float] = []
    try:
        for step in range(start_step, num_steps):
            batch = next(pipeline)
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")

            def do_step(state=state, batch=batch):
                new_state, metrics = step_fn(state, batch)
                # block on the loss: a hung collective manifests here
                return new_state, jax.device_get(metrics["loss"]), metrics

            state, loss, metrics = watchdog.run(do_step)
            losses.append(float(loss))
            if hooks:
                m = {k: v for k, v in metrics.items()}
                for h in hooks:
                    h(step, m)
            if (step + 1) % run.checkpoint.save_every == 0:
                ckpt.save(step + 1, state, extra={"data": pipeline.state})
    finally:
        pipeline.close()
        ckpt.wait()
    return {"state": state, "losses": losses, "final_step": num_steps}


def train_with_recovery(run: RunConfig, *, mesh=None,
                        num_steps: Optional[int] = None,
                        fail_at_step: Optional[int] = None,
                        ) -> Dict[str, Any]:
    """Restart supervisor around train_loop. Restores the latest
    checkpoint (params+opt+data cursor) on each restart."""
    ckpt = CheckpointManager(run.checkpoint)
    restarts: List[int] = []

    def body(attempt: int):
        start, state = 0, None
        latest = ckpt.latest_step()
        if latest is not None:
            target = jax.eval_shape(
                lambda: init_train_state(jax.random.PRNGKey(run.seed), run))
            state, extra = ckpt.restore(target)
            start = latest
        # only inject the failure on the first attempt
        fail = fail_at_step if attempt == 0 else None
        return train_loop(run, mesh=mesh, num_steps=num_steps, state=state,
                          start_step=start, ckpt=ckpt, fail_at_step=fail)

    out = run_with_restarts(body, run.fault.max_restarts,
                            on_restart=lambda a, e: restarts.append(a))
    out["restarts"] = restarts
    return out
