"""Train step builder: loss -> grads -> AdamW, with microbatch gradient
accumulation, per-layer remat, and optional int8-compressed cross-pod
gradient reduction.

Compute/communication overlap note (DESIGN.md SS4): because the layer
stack is a ``lax.scan`` and grads are produced per scanned layer, XLA's
SPMD partitioner emits one reduce-scatter/all-reduce per layer-stack leaf
*inside* the backward scan — the collective for layer i overlaps the
backward compute of layer i-1. We do not hand-schedule this; the HLO is
checked in the dry-run (EXPERIMENTS.md SSDry-run).

When ``grad_compression`` is on and the mesh has a ``pod`` axis, the
whole step runs in a partial-auto ``shard_map``: manual over ``pod``
(per-pod grads -> int8 psum with error feedback), automatic GSPMD over
``data``/``model``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, RunConfig
from repro.models import decoder
from repro.optim.adamw import OptState, adamw_init, adamw_update
from repro.sharding.compression import psum_compressed
from repro.sharding.rules import param_specs, shardings_for


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    err: Optional[Any] = None          # error-feedback state (compression)


def init_train_state(key, run: RunConfig) -> TrainState:
    params = decoder.init_params(key, run.model)
    opt = adamw_init(params, run.optimizer)
    err = None
    if run.sharding.grad_compression:
        err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params, opt, err)


def init_train_state_shape(run: RunConfig) -> TrainState:
    """ShapeDtypeStruct version for the dry-run."""
    return jax.eval_shape(lambda k: init_train_state(k, run),
                          jax.random.PRNGKey(0))


def _loss_fn(params, cfg: ModelConfig, batch, remat: str, attn_chunk: int,
             ce_chunk: int = 512):
    total, aux = decoder.lm_loss(params, cfg, batch.get("tokens"),
                                 batch["labels"],
                                 inputs_embeds=batch.get("embeds"),
                                 remat=remat, attn_chunk=attn_chunk,
                                 ce_chunk=ce_chunk)
    return total, aux


def _grads_one(params, cfg, batch, remat, attn_chunk, ce_chunk=512):
    (loss, aux), grads = jax.value_and_grad(
        _loss_fn, has_aux=True)(params, cfg, batch, remat, attn_chunk,
                                ce_chunk)
    return loss, aux, grads


def _grads_accumulated(params, cfg, batch, remat, attn_chunk, n_micro):
    """Gradient accumulation via lax.scan over microbatches."""
    def split(x):
        b = x.shape[0]
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = jax.tree.map(split, batch)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(acc, mb):
        loss_a, grads_a = acc
        loss, aux, grads = _grads_one(params, cfg, mb, remat, attn_chunk)
        grads_a = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / n_micro,
                               grads_a, grads)
        return (loss_a + loss / n_micro, grads_a), None

    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), micro)
    return loss, {}, grads


def make_train_step(
    run: RunConfig,
    mesh: Optional[Mesh] = None,
    *,
    attn_chunk: int = 1024,
    donate: bool = True,
) -> Callable[[TrainState, Dict[str, jax.Array]],
              Tuple[TrainState, Dict[str, jax.Array]]]:
    """Build the jitted train step. With a mesh, params/opt-state get
    rule-based shardings; without, plain jit (single device)."""
    cfg = run.model
    remat = run.sharding.remat
    attn_chunk = run.sharding.attn_chunk
    ce_chunk = run.sharding.ce_chunk
    n_micro = run.sharding.microbatches
    compress = run.sharding.grad_compression and mesh is not None and \
        "pod" in getattr(mesh, "axis_names", ())

    from repro.models.common import activation_shardings
    from repro.sharding.rules import act_specs
    a_specs = act_specs(cfg, run.shape, mesh, run.sharding) if mesh is not None else {}

    def step_inner(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if n_micro > 1:
            loss, aux, grads = _grads_accumulated(
                state.params, cfg, batch, remat, attn_chunk, n_micro)
        else:
            loss, aux, grads = _grads_one(state.params, cfg, batch, remat,
                                          attn_chunk, ce_chunk)
        err = state.err
        if compress:
            grads, err = psum_compressed(grads, "pod", err)
            loss = jax.lax.pmean(loss, "pod")
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, run.optimizer)
        metrics = {"loss": loss, **opt_metrics}
        return TrainState(new_params, new_opt, err), metrics

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        with activation_shardings(a_specs):
            return step_inner(state, batch)

    if mesh is None:
        return jax.jit(step, donate_argnums=(0,) if donate else ())

    state_shape = init_train_state_shape(run)
    pspecs = param_specs(state_shape.params, run.sharding, mesh)
    p_shard = shardings_for(pspecs, mesh)
    opt_shard = OptState(
        step=NamedSharding(mesh, P()),
        m=p_shard, v=p_shard)
    err_shard = p_shard if state_shape.err is not None else None
    state_shard = TrainState(p_shard, opt_shard, err_shard)

    from repro.sharding.rules import batch_spec
    bs = batch_spec(run.shape, mesh, run.sharding)
    bspec = NamedSharding(mesh, bs)
    if cfg.frontend:
        espec = NamedSharding(mesh, P(*bs, None))
        batch_shard = {"embeds": espec, "labels": bspec}
    else:
        batch_shard = {"tokens": bspec, "labels": bspec}
    metric_shard = None   # let the compiler pick (scalars)

    step_fn = step
    if compress:
        from jax.experimental.shard_map import shard_map
        # manual over pod, auto over data/model: per-pod grads + int8 psum
        auto = frozenset(a for a in mesh.axis_names if a != "pod")
        step_fn = shard_map(step, mesh=mesh,
                            in_specs=(P(), P("pod")),   # batch split by pod
                            out_specs=(P(), P()), check_rep=False,
                            auto=auto)

    return jax.jit(
        step_fn,
        in_shardings=(state_shard, batch_shard),
        out_shardings=(state_shard, metric_shard),
        donate_argnums=(0,) if donate else (),
    )
