from repro.train.step import TrainState, init_train_state, make_train_step
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import train_loop
from repro.train.fault import Watchdog, run_with_restarts

__all__ = ["TrainState", "init_train_state", "make_train_step",
           "CheckpointManager", "train_loop", "Watchdog",
           "run_with_restarts"]
