"""Checkpoint manager: step-granular, async, elastic.

Format: one directory per step containing
  manifest.json   — leaf paths, shapes, dtypes, aux metadata
  <leaf>.npy      — full (unsharded) arrays

Saving device_gets the addressable shards and writes the *logical* array,
so a checkpoint taken on a (data=16, model=16) mesh restores onto any
other mesh ("elastic resharding"): ``restore`` device_puts each leaf with
the sharding derived from the rules for the *new* mesh. Writes go to a
temp dir + atomic rename; an interrupted save can never corrupt the
latest-complete pointer.

Async mode hands the (already host-transferred) arrays to a writer
thread so the train loop continues; ``wait()`` joins before exit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.config import CheckpointConfig

_SEP = "::"


def _flatten(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = _SEP.join(
            p.key if hasattr(p, "key") else
            (p.name if hasattr(p, "name") else str(p.idx))
            for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- paths ------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.cfg.directory, f"step_{step:09d}")

    def all_steps(self) -> List[int]:
        steps = []
        for d in os.listdir(self.cfg.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    steps.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save -------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict] = None,
             blocking: Optional[bool] = None):
        blocking = (not self.cfg.async_save) if blocking is None else blocking
        # host transfer happens NOW (consistent snapshot), write may be async
        host = [(name, np.asarray(jax.device_get(leaf)))
                for name, leaf in _flatten(state)]

        def write():
            tmp = self._step_dir(step) + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "extra": extra or {}, "leaves": []}
            for i, (name, arr) in enumerate(host):
                fn = f"leaf_{i:05d}.npy"
                to_save = arr
                if arr.dtype.kind not in "biufc":   # bf16/f8 (ml_dtypes)
                    to_save = arr.view(f"u{arr.dtype.itemsize}")
                np.save(os.path.join(tmp, fn), to_save)
                manifest["leaves"].append(
                    {"name": name, "file": fn, "shape": list(arr.shape),
                     "dtype": str(arr.dtype)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.cfg.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings`` (same structure) enables elastic
        resharding onto any mesh."""
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.cfg.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {l["name"]: l for l in manifest["leaves"]}

        names = [n for n, _ in _flatten(target)]
        leaves_t, treedef = jax.tree_util.tree_flatten(target)
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves_t))
        out = []
        for name, tgt, shd in zip(names, leaves_t, shard_leaves):
            meta = by_name.get(name)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            arr = np.load(os.path.join(d, meta["file"]))
            true_dtype = jax.numpy.dtype(meta["dtype"])
            if arr.dtype != true_dtype:
                arr = arr.view(true_dtype)      # bf16/f8 saved as uint view
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(
                    f"shape mismatch for {name}: ckpt {arr.shape} vs "
                    f"target {tgt.shape}")
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.device_put(arr.astype(tgt.dtype)))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
