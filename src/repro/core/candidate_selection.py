"""A³ greedy candidate selection (paper §IV).

Two functionally-identical implementations:

* :func:`select_candidates_oracle` — a direct Python transcription of the
  paper's Figure 7 priority-queue algorithm (maxQ + symmetric minQ + the
  cumulative-sum heuristic). Used as the ground-truth oracle in tests and
  for the cycle-faithful benchmark model.

* :func:`select_candidates` — the TPU-native vectorized equivalent.
  The key observation (DESIGN.md §2): the paper's maxQ walk is a k-way
  merge of `d` per-column descending product lists, so its M pops are
  exactly the global top-M elements of the element-wise product matrix —
  and each of those lives in the per-column prefix of length ≤ M of the
  *sorted* key columns. We therefore gather only the `L = min(M, n)`
  prefix per column (`O(dM)` work, independent of `n` at query time,
  preserving the paper's asymptotic claim), take a global
  ``jax.lax.top_k``, and `segment-sum` into greedy scores. The
  cumulative-sum heuristic is reproduced exactly with a length-M
  ``lax.scan`` over the merged pop sequence.

Preprocessing (`sort_key_columns`) happens at *comprehension time*, mirroring
the paper's off-critical-path sort of each key column.
"""
from __future__ import annotations

import heapq
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class SortedKeys(NamedTuple):
    """Per-column ascending sort of the key matrix (paper Fig. 8).

    values: [n, d] — column j holds sort(key[:, j]) ascending.
    rows:   [n, d] int32 — original row index of each sorted value.
    """
    values: jax.Array
    rows: jax.Array

    @property
    def n(self) -> int:
        return self.values.shape[0]

    @property
    def d(self) -> int:
        return self.values.shape[1]


def sort_key_columns(key: jax.Array) -> SortedKeys:
    """Preprocess: sort each column of ``key`` [n, d] (comprehension time)."""
    order = jnp.argsort(key, axis=0)                    # [n, d] ascending
    values = jnp.take_along_axis(key, order, axis=0)
    return SortedKeys(values=values, rows=order.astype(jnp.int32))


def quantize_sorted_keys(sk: SortedKeys) -> Tuple[SortedKeys, jax.Array]:
    """Quantize sorted key columns to int8 with per-column fp32 scales.

    Round-to-nearest is monotone, so each quantized column stays validly
    ascending and the int8 ``SortedKeys`` can feed the same greedy walk.
    Returns (int8 SortedKeys, scales [d]) — pass the scales back to
    :func:`select_candidates`, which folds them into the query so the
    walk runs *directly on the int8 values* (scoring int8 keys against a
    scale-folded query is bit-identical to scoring the dequantized
    keys; no dequantized key matrix is ever materialized).
    """
    from repro.core.quantization import quantize_int8_block
    q, scale = quantize_int8_block(sk.values, axes=(0,))     # per column
    return SortedKeys(values=q, rows=sk.rows), scale.reshape(-1)


def slice_sorted_keys(sk: SortedKeys, keep_rows: jax.Array) -> SortedKeys:
    """Restrict a per-column sort to a subset of ring rows (the paged
    prefix-cache's page-boundary restore).

    ``keep_rows`` [n] bool marks ring rows that remain valid after
    truncating the cache at a page boundary. Dropped rows are re-valued
    to 0 — exactly what an *unwritten* ring row reads as — and each
    column is re-sorted, so the result equals
    ``sort_key_columns(where(keep_rows[:, None], key, 0))`` without
    needing the key matrix itself: the comprehension-time sort of a
    shorter prefix is *recovered from the longer prompt's sorted
    snapshot*, not recomputed from keys. (Entries tied at exactly 0 may
    order differently than a from-keys sort; zero products never enter
    the greedy walk — ``select_candidates`` masks ``> 0`` / ``< 0`` —
    so candidate selection is unaffected.)
    """
    keep = keep_rows[sk.rows]                           # [n, d] bool
    vals = jnp.where(keep, sk.values, jnp.zeros((), sk.values.dtype))
    order = jnp.argsort(vals, axis=0)                   # stable ascending
    return SortedKeys(
        values=jnp.take_along_axis(vals, order, axis=0),
        rows=jnp.take_along_axis(sk.rows, order, axis=0))


# ---------------------------------------------------------------------------
# Oracle: faithful priority-queue transcription of Figure 7
# ---------------------------------------------------------------------------

def select_candidates_oracle(
    key: np.ndarray,
    query: np.ndarray,
    m_iters: int,
    use_heuristic: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Paper Figure 7 (plus the symmetric minQ and §IV-C heuristic).

    Returns (candidate_mask [n] bool, greedy_score [n] float64).
    Pure numpy/heapq — the testing oracle.
    """
    key = np.asarray(key, dtype=np.float64)
    query = np.asarray(query, dtype=np.float64)
    n, d = key.shape
    order = np.argsort(key, axis=0)
    svals = np.take_along_axis(key, order, axis=0)      # ascending per column

    greedy = np.zeros(n, dtype=np.float64)

    # max side: start at the end that makes products descending.
    max_ptr = np.where(query > 0, n - 1, 0)
    max_step = np.where(query > 0, -1, 1)
    # min side: the opposite end (products ascending).
    min_ptr = np.where(query > 0, 0, n - 1)
    min_step = np.where(query > 0, 1, -1)

    maxq: list = []   # (-product, col) so heapq pops the largest product
    minq: list = []   # (product, col)
    for j in range(d):
        maxq.append((-svals[max_ptr[j], j] * query[j], j))
        minq.append((svals[min_ptr[j], j] * query[j], j))
    heapq.heapify(maxq)
    heapq.heapify(minq)
    max_used = np.zeros(d, dtype=np.int64)   # pops consumed per column
    min_used = np.zeros(d, dtype=np.int64)

    cum = 0.0
    for _ in range(m_iters):
        # --- maxQ pop (always) ---
        if maxq:
            neg, j = heapq.heappop(maxq)
            val = -neg
            row = order[max_ptr[j], j]
            if val > 0:
                greedy[row] += val
                cum += val
            max_used[j] += 1
            if max_used[j] < n:
                max_ptr[j] += max_step[j]
                heapq.heappush(maxq, (-svals[max_ptr[j], j] * query[j], j))
        # --- minQ pop (skipped when cum < 0, per the paper's heuristic) ---
        if (not use_heuristic) or cum >= 0:
            if minq:
                val, j = heapq.heappop(minq)
                row = order[min_ptr[j], j]
                if val < 0:
                    greedy[row] += val
                    cum += val
                min_used[j] += 1
                if min_used[j] < n:
                    min_ptr[j] += min_step[j]
                    heapq.heappush(minq, (svals[min_ptr[j], j] * query[j], j))

    return greedy > 0, greedy


# ---------------------------------------------------------------------------
# Vectorized TPU-native equivalent
# ---------------------------------------------------------------------------

def _prefix_products(
    sk: SortedKeys, query: jax.Array, length: int, side: str
) -> Tuple[jax.Array, jax.Array]:
    """Per-column product prefix in pop order.

    side="max": products descending per column (the maxQ walk order).
    side="min": products ascending per column (the minQ walk order).
    Returns (products [L, d], rows [L, d]).
    """
    n = sk.n
    # static slices (not gathers): the walk only ever touches the top-L
    # or bottom-L of each sorted column, so HBM traffic is O(L d), which
    # is the paper's query-time-independent-of-n property.
    top = sk.values[n - length:][::-1]                   # descending
    bot = sk.values[:length]                             # ascending
    top_r = sk.rows[n - length:][::-1]
    bot_r = sk.rows[:length]
    qpos = (query > 0)[None, :]                          # [1, d]
    if side == "max":
        vals = jnp.where(qpos, top, bot)
        rows = jnp.where(qpos, top_r, bot_r)
    else:
        vals = jnp.where(qpos, bot, top)
        rows = jnp.where(qpos, bot_r, top_r)
    if not jnp.issubdtype(vals.dtype, jnp.floating):
        # int8 sorted keys (kv_quant): score directly on the integer
        # values — the per-column scale is already folded into ``query``
        vals = vals.astype(jnp.float32)
    return vals * query[None, :], rows


def _heuristic_masks(a_vals: jax.Array, b_vals: jax.Array):
    """Reproduce the paper's cumulative-sum heuristic with a lax.scan.

    a_vals: [M] max-side pop values in descending order.
    b_vals: [M] min-side pop values in ascending order.
    Returns (a_mask [M], b_mask [M]) — which pops are accumulated.
    """
    m = a_vals.shape[0]

    def step(carry, k):
        cum, j = carry
        a = a_vals[k]
        a_add = a > 0
        cum = cum + jnp.where(a_add, a, 0.0)
        do_min = cum >= 0
        b = b_vals[jnp.minimum(j, m - 1)]
        b_add = do_min & (b < 0)
        cum = cum + jnp.where(b_add, b, 0.0)
        j = j + jnp.where(do_min, 1, 0)
        return (cum, j), (a_add, do_min, b_add)

    (_, _), (a_mask, do_min, b_add) = jax.lax.scan(
        step, (jnp.float32(0.0), jnp.int32(0)), jnp.arange(m))
    # Map per-step min-consumption events back onto b-index space:
    # the b element consumed at step k (when do_min) is cumsum(do_min)-1.
    j_at_step = jnp.cumsum(do_min.astype(jnp.int32)) - 1
    b_mask = jnp.zeros((m,), dtype=bool).at[
        jnp.clip(j_at_step, 0, m - 1)
    ].max(jnp.where(do_min, b_add, False))
    return a_mask, b_mask


def select_candidates(
    sorted_keys: SortedKeys,
    query: jax.Array,
    m_iters: int,
    use_heuristic: bool = True,
    prefix_cap: Optional[int] = None,
    scales: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Vectorized greedy candidate selection — exact equivalent of the oracle.

    Returns (candidate_mask [n] bool, greedy_score [n] f32).

    ``prefix_cap`` bounds the per-column sorted prefix that is scanned.
    The exact equivalence needs length min(M, n) (one column could absorb
    every pop), but the M pops are *shared* across d columns, so a cap of
    c*M/d (c ~ 4) captures the walk with high probability at O(M) instead
    of O(M d) work — the production decode path uses this (SSPerf H3.v2);
    ``None`` keeps the oracle-exact behaviour.

    ``scales`` [d] (``kv_quant=int8``): per-column fp32 scales for int8
    ``sorted_keys`` (see :func:`quantize_sorted_keys`). The scale is
    positive, so folding it into the query preserves each column's walk
    order — the selection runs directly on the int8 values and is
    bit-identical to selecting over the dequantized keys.
    """
    n, d = sorted_keys.n, sorted_keys.d
    if scales is not None:
        query = query.astype(jnp.float32) * scales
    m = int(min(m_iters, n * d))
    length = int(min(m, n))
    if prefix_cap is not None:
        length = int(min(length, max(1, prefix_cap)))
        m = int(min(m, length * d))

    prod_max, rows_max = _prefix_products(sorted_keys, query, length, "max")
    prod_min, rows_min = _prefix_products(sorted_keys, query, length, "min")

    a_vals, a_idx = jax.lax.top_k(prod_max.reshape(-1), m)     # descending
    a_rows = rows_max.reshape(-1)[a_idx]
    nb_vals, b_idx = jax.lax.top_k(-prod_min.reshape(-1), m)
    b_vals = -nb_vals                                          # ascending
    b_rows = rows_min.reshape(-1)[b_idx]

    if use_heuristic:
        a_mask, b_mask = _heuristic_masks(a_vals, b_vals)
    else:
        a_mask = a_vals > 0
        b_mask = b_vals < 0

    greedy = jnp.zeros((n,), dtype=jnp.float32)
    greedy = greedy.at[a_rows].add(jnp.where(a_mask, a_vals, 0.0))
    greedy = greedy.at[b_rows].add(jnp.where(b_mask, b_vals, 0.0))
    return greedy > 0, greedy


def select_candidates_batch(
    sorted_keys: SortedKeys,
    queries: jax.Array,
    m_iters: int,
    use_heuristic: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """vmap of :func:`select_candidates` over a [q, d] query batch."""
    fn = lambda q: select_candidates(sorted_keys, q, m_iters, use_heuristic)
    return jax.vmap(fn)(queries)
