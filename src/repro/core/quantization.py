"""A³ fixed-point quantization and the two-LUT exponent (paper §III-A/B).

The ASIC computes in fixed point with ``i`` integer and ``f`` fraction bits
(plus sign) for inputs, widening per stage so that *no additional* precision
is lost after input quantization:

    temp      = key*query      -> 2i int, 2f frac
    dot       = sum_d temp     -> 2i + log2(d) int, 2f frac
    dot - max                  -> one extra int bit
    score     = exp(dot-max)   -> in (0, 1], 2f frac
    expsum    = sum_n score    -> log2(n) int bits
    weight    = score/expsum   -> in [0, 1], 2f frac
    output    = sum weight*val -> i + log2(n) int, 3f frac

On TPU we *simulate* these numerics with fake quantization (values stay in
f32 but are rounded/clipped to the fixed-point grid), which is bit-faithful
for accuracy studies while the deployment dtype remains bf16.

The exponent unit decomposes ``e^x = e^{x_hi} * e^{x_lo}`` over the split
fixed-point fraction so two small LUTs replace one huge one (§III-A).
Footnote 1's error bound (quantization error shrinks through exp for
non-positive inputs) is verified in tests/test_quantization.py.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_fixed_point(
    x: jax.Array, int_bits: int, frac_bits: int
) -> jax.Array:
    """Round-to-nearest fixed point with ``int_bits``/``frac_bits`` + sign.

    Representable range: [-(2^i - 2^-f), 2^i - 2^-f].

    The rounding grid is built in f32 regardless of the input dtype: a
    bf16 input times the weak-typed Python scalar ``2**frac_bits`` stays
    bf16, whose 8-bit mantissa cannot hold ``x * 2^f`` — fake
    quantization would silently degrade to a no-op / wrong grid. Compute
    internally in f32, cast back to the input dtype.
    """
    x = jnp.asarray(x)
    scale = 2.0 ** frac_bits
    limit = 2.0 ** int_bits - 2.0 ** (-frac_bits)
    xf = x.astype(jnp.float32)
    q = jnp.round(xf * scale) / scale
    return jnp.clip(q, -limit, limit).astype(x.dtype)


class LutExp(NamedTuple):
    """Two-LUT exponent for non-positive fixed-point inputs.

    The input ``x <= 0`` is represented as ``-k * 2^-frac_bits`` with
    ``k`` an unsigned integer of ``total_bits`` bits. ``k`` is split into
    high/low halves; each half indexes a small table and the results are
    multiplied:  e^{-(hi+lo)·2^-f} = LUT_hi[hi] · LUT_lo[lo].
    """
    hi_table: jax.Array          # [2^hi_bits]
    lo_table: jax.Array          # [2^lo_bits]
    frac_bits: int
    lo_bits: int
    total_bits: int
    out_frac_bits: int

    def __call__(self, x: jax.Array) -> jax.Array:
        """exp(x) for x <= 0 via the two tables (vectorized).

        Index and output-register arithmetic run in f32 (``x * 2^f``
        overflows a bf16 mantissa); the result is cast back to the
        input dtype.
        """
        x = jnp.asarray(x)
        scale = 2.0 ** self.frac_bits
        kmax = 2 ** self.total_bits - 1
        xf = x.astype(jnp.float32)
        k = jnp.clip(jnp.round(-xf * scale), 0, kmax).astype(jnp.int32)
        lo = k & ((1 << self.lo_bits) - 1)
        hi = k >> self.lo_bits
        y = (self.hi_table[hi] * self.lo_table[lo]).astype(jnp.float32)
        # the ASIC multiplier output register keeps out_frac_bits fraction bits
        oscale = 2.0 ** self.out_frac_bits
        return (jnp.round(y * oscale) / oscale).astype(x.dtype)

    @property
    def table_entries(self) -> int:
        return self.hi_table.shape[0] + self.lo_table.shape[0]


def make_lut_exp(
    frac_bits: int,
    total_bits: int,
    lo_bits: Optional[int] = None,
    out_frac_bits: Optional[int] = None,
    dtype=jnp.float32,
) -> LutExp:
    """Build the two tables.

    frac_bits: fraction bits of the (non-positive) input representation —
        the paper uses 2f here (the dot-product register width).
    total_bits: total index width; 2^total_bits entries would be the naive
        single-table size the decomposition avoids.
    """
    if lo_bits is None:
        lo_bits = total_bits // 2
    hi_bits = total_bits - lo_bits
    if out_frac_bits is None:
        out_frac_bits = frac_bits
    step = 2.0 ** (-frac_bits)
    lo_idx = jnp.arange(2 ** lo_bits, dtype=dtype)
    hi_idx = jnp.arange(2 ** hi_bits, dtype=dtype)
    lo_table = jnp.exp(-lo_idx * step)
    hi_table = jnp.exp(-hi_idx * step * (2.0 ** lo_bits))
    return LutExp(hi_table=hi_table, lo_table=lo_table, frac_bits=frac_bits,
                  lo_bits=lo_bits, total_bits=total_bits,
                  out_frac_bits=out_frac_bits)


@functools.lru_cache(maxsize=None)
def cached_lut_exp(frac_bits: int, total_bits: int) -> LutExp:
    """Module-level cached :func:`make_lut_exp` keyed on
    ``(frac_bits, total_bits)``.

    ``softmax_fixed_point`` (and the decode dispatches built on it) used
    to rebuild the default tables inside every traced call — each trace
    re-materialized both LUTs as fresh constants. The cache returns the
    SAME table arrays every call, so jit closes over one constant pair
    and repeated dispatches reuse it instead of re-deriving it per tick.
    """
    return make_lut_exp(frac_bits=frac_bits, total_bits=total_bits)


def softmax_fixed_point(
    scores: jax.Array,
    frac_bits: int,
    lut: Optional[LutExp] = None,
    mask: Optional[jax.Array] = None,
    axis: int = -1,
) -> jax.Array:
    """Softmax with the paper's quantized exponent path.

    scores are assumed already quantized to 2*frac_bits fraction bits
    (the dot-product register). The max is subtracted (overflow guard,
    §III-A), the exponent computed via the LUT pair, and the weights kept
    at 2*frac_bits fraction bits.
    """
    if lut is None:
        # Index width = fraction bits of the score register + enough integer
        # bits to cover the useful exponent range (e^-32 ~ 1e-14 underflows
        # any fixed-point weight register, so 5 integer bits suffice).
        lut = cached_lut_exp(2 * frac_bits, 2 * frac_bits + 5)
    # Internal arithmetic in f32: the 2f-bit weight grid (and the max-
    # subtract) are not representable in a bf16 mantissa — compute wide,
    # cast the final weights back to the input dtype.
    out_dtype = jnp.asarray(scores).dtype
    scores = jnp.asarray(scores).astype(jnp.float32)
    neg_inf = jnp.finfo(jnp.float32).min
    if mask is not None:
        scores = jnp.where(mask, scores, neg_inf)
    mx = jnp.max(scores, axis=axis, keepdims=True)
    shifted = scores - mx
    e = lut(shifted)
    if mask is not None:
        e = jnp.where(mask, e, 0.0)
    denom = jnp.sum(e, axis=axis, keepdims=True)
    w = e / jnp.maximum(denom, jnp.finfo(jnp.float32).tiny)
    scale = 2.0 ** (2 * frac_bits)
    return (jnp.round(w * scale) / scale).astype(out_dtype)


# ---------------------------------------------------------------------------
# Int8 block quantization for the serving cache (``kv_quant=int8``)
# ---------------------------------------------------------------------------
#
# The paged prefix cache stores KV pages (and A^3 sorted-key column
# snapshots) as int8 with fp32 amax scales per block — per page for KV
# rows, per sorted-column block for sorted keys. Symmetric round-to-
# nearest: q = round(x / s), s = amax/127, so |x - s*q| <= s/2 and the
# warm-restored ring differs from the cold one by at most half a
# quantization step per element.

def quantize_int8_block(
    x: jax.Array, axes: Tuple[int, ...]
) -> Tuple[jax.Array, jax.Array]:
    """Quantize ``x`` to int8 with one fp32 scale per block.

    ``axes`` are the dimensions reduced into each scale (the block);
    the returned ``scale`` keeps those dims at size 1 so
    ``dequantize_int8_block`` broadcasts without bookkeeping.
    """
    xf = jnp.asarray(x).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axes, keepdims=True)
    scale = jnp.maximum(amax / 127.0, jnp.float32(1e-12))
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_block(
    q: jax.Array, scale: jax.Array, dtype=jnp.float32
) -> jax.Array:
    """Inverse of :func:`quantize_int8_block` (scale broadcasts)."""
    return (q.astype(jnp.float32) * scale).astype(dtype)
