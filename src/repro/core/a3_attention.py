"""A³ attention — the paper's full pipeline as a composable JAX module.

Pipeline (paper Fig. 10):

    sorted keys --(candidate selection, §IV-C)--> candidate mask
    q·Kᵀ on candidates --(post-scoring, §IV-D)--> kept mask
    masked softmax (optionally quantized 2-LUT path, §III) --> weights
    weights · V --> output

This module is the *semantic reference*: it computes dense-masked math so
it is exact, differentiable where applicable, and trivially shardable. The
FLOP savings the ASIC realizes by skipping rows are realized on TPU by the
block-sparse Pallas kernel in ``repro.kernels.a3_attention``, which consumes
the same candidate masks at block granularity.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import A3Config, A3Mode
from repro.core.candidate_selection import (
    SortedKeys,
    select_candidates,
    select_candidates_batch,
    sort_key_columns,
)
from repro.core.post_scoring import masked_softmax, post_scoring_mask
from repro.core.quantization import (
    LutExp,
    cached_lut_exp,
    quantize_fixed_point,
    softmax_fixed_point,
)


class A3State(NamedTuple):
    """Comprehension-time state: the preprocessed (sorted) key matrix."""
    sorted_keys: SortedKeys
    key: jax.Array
    value: jax.Array


def preprocess(key: jax.Array, value: jax.Array) -> A3State:
    """Comprehension-time preprocessing (off the critical path)."""
    return A3State(sorted_keys=sort_key_columns(key), key=key, value=value)


def _maybe_quantize(x: jax.Array, cfg: A3Config) -> jax.Array:
    if cfg.int_bits is not None and cfg.frac_bits is not None:
        return quantize_fixed_point(x, cfg.int_bits, cfg.frac_bits)
    return x


def a3_attention_single(
    state: A3State,
    query: jax.Array,
    cfg: A3Config,
    lut: Optional[LutExp] = None,
) -> Tuple[jax.Array, dict]:
    """One query against one (key, value) memory — the accelerator's unit op.

    Returns (output [d_v], aux dict with masks/weights for analysis).
    """
    key, value = state.key, state.value
    n = key.shape[0]
    q = _maybe_quantize(query, cfg)
    k = _maybe_quantize(key, cfg)

    if cfg.mode == A3Mode.OFF:
        cand = jnp.ones((n,), dtype=bool)
        greedy = jnp.zeros((n,), dtype=jnp.float32)
    else:
        m = cfg.m_for(n)
        cand, greedy = select_candidates(state.sorted_keys, q, m)

    scores = k @ q                                         # [n]
    if cfg.frac_bits is not None:
        scores = quantize_fixed_point(
            scores, 2 * (cfg.int_bits or 4) + int(math.ceil(math.log2(max(key.shape[1], 2)))),
            2 * cfg.frac_bits)

    if cfg.mode == A3Mode.OFF:
        keep = cand
    else:
        keep = post_scoring_mask(scores, cfg.threshold_nats, cand)

    if cfg.lut_exponent and cfg.frac_bits is not None:
        weights = softmax_fixed_point(scores, cfg.frac_bits, lut=lut, mask=keep)
    else:
        weights = masked_softmax(scores, keep)

    out = weights @ _maybe_quantize(value, cfg)
    aux = dict(candidates=cand, kept=keep, weights=weights,
               greedy_score=greedy, scores=scores)
    return out, aux


def a3_attention_batch(
    state: A3State, queries: jax.Array, cfg: A3Config
) -> Tuple[jax.Array, dict]:
    """vmap of the unit op over a [q, d] query batch (pipelined queries)."""
    # cached builder: every dispatch (and every trace) closes over the
    # SAME two tables instead of re-deriving them per call
    lut = cached_lut_exp(2 * cfg.frac_bits, 2 * cfg.frac_bits + 5) if (
        cfg.lut_exponent and cfg.frac_bits is not None) else None
    fn = lambda q: a3_attention_single(state, q, cfg, lut)
    return jax.vmap(fn)(queries)


# ---------------------------------------------------------------------------
# Self-attention integration (BERT/LM case, paper §VI — n queries share K)
# ---------------------------------------------------------------------------

def candidate_block_map(
    cand_mask: jax.Array, block_q: int, block_k: int
) -> jax.Array:
    """Reduce a per-(query, key) candidate mask to block granularity.

    cand_mask: [q, n] bool. Returns [q/block_q, n/block_k] bool where a
    block is live iff any (query, key) pair within it is a candidate. This
    is the TPU-granularity analogue of the ASIC's per-row skipping and is
    what the Pallas kernel's scalar-prefetch grid consumes.
    """
    qlen, n = cand_mask.shape
    nq, nk = qlen // block_q, n // block_k
    m = cand_mask[: nq * block_q, : nk * block_k]
    m = m.reshape(nq, block_q, nk, block_k)
    return jnp.any(m, axis=(1, 3))


def a3_self_attention(
    q: jax.Array,      # [q, d]
    k: jax.Array,      # [n, d]
    v: jax.Array,      # [n, d_v]
    cfg: A3Config,
    causal: bool = False,
    scale: Optional[float] = None,
) -> Tuple[jax.Array, dict]:
    """Self-attention with the A³ pipeline applied per query.

    Scores are scaled by 1/sqrt(d) as in standard attention; the A³
    selection runs on the *scaled* score space so that threshold_nats keeps
    its paper meaning (post-softmax relative weight).
    """
    qlen, d = q.shape
    n = k.shape[0]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    qs = q * scale

    if cfg.mode == A3Mode.OFF:
        cand = jnp.ones((qlen, n), dtype=bool)
    else:
        sk = sort_key_columns(k)
        m = cfg.m_for(n)
        cand, _ = select_candidates_batch(sk, qs, m)

    scores = qs @ k.T                                      # [q, n]
    if causal:
        pos_q = jnp.arange(qlen)[:, None]
        pos_k = jnp.arange(n)[None, :]
        causal_mask = pos_k <= pos_q + (n - qlen)
        cand = cand & causal_mask

    if cfg.mode == A3Mode.OFF:
        keep = cand
    else:
        keep = post_scoring_mask(scores, cfg.threshold_nats, cand)

    weights = masked_softmax(scores, keep)
    out = weights @ v
    aux = dict(candidates=cand, kept=keep, weights=weights)
    return out, aux


def flop_savings(aux: dict, n: int, d: int) -> dict:
    """Accounting used by the Fig. 14 benchmark: avoided MACs per query."""
    cand = aux["candidates"]
    kept = aux["kept"]
    c = jnp.sum(cand, axis=-1).astype(jnp.float32)
    kk = jnp.sum(kept, axis=-1).astype(jnp.float32)
    full = float(2 * n * d)
    approx = 2.0 * c * d / full
    out_frac = kk * d / (n * d)
    return dict(
        mean_candidates=jnp.mean(c),
        mean_kept=jnp.mean(kk),
        score_flop_fraction=jnp.mean(approx),
        output_flop_fraction=jnp.mean(out_frac),
    )
