"""A³ post-scoring selection (paper §IV-D).

After exact scores are computed for the candidate rows, drop any row whose
score trails the max by more than ``t`` nats — i.e. whose post-softmax
weight would be below ``T% = 100·e^{-t}`` of the top row's weight. This is
the dynamic scheme the paper argues for (a static top-k misbehaves when the
score distribution is flat or peaky).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def post_scoring_mask(
    scores: jax.Array,
    threshold_nats: float,
    candidate_mask: Optional[jax.Array] = None,
    axis: int = -1,
) -> jax.Array:
    """Boolean mask of rows kept by post-scoring selection.

    scores: [..., n] exact dot-product scores.
    candidate_mask: rows already selected by candidate selection; rows
        outside it are ignored both for the max and the output.
    """
    neg_inf = jnp.finfo(jnp.float32).min
    s = scores.astype(jnp.float32)
    if candidate_mask is not None:
        s = jnp.where(candidate_mask, s, neg_inf)
    mx = jnp.max(s, axis=axis, keepdims=True)
    keep = s >= (mx - threshold_nats)
    if candidate_mask is not None:
        keep = keep & candidate_mask
    return keep


def masked_softmax(
    scores: jax.Array,
    mask: Optional[jax.Array],
    axis: int = -1,
) -> jax.Array:
    """Numerically-stable softmax over ``mask``-selected entries.

    Rows with an all-False mask return all-zero weights (the engine treats
    such queries as "no relevant memory", matching the accelerator's
    behaviour of emitting a zero output vector).
    """
    s = scores.astype(jnp.float32)
    neg_inf = jnp.finfo(jnp.float32).min
    if mask is not None:
        s = jnp.where(mask, s, neg_inf)
    mx = jnp.max(s, axis=axis, keepdims=True)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    e = jnp.exp(s - mx)
    if mask is not None:
        e = jnp.where(mask, e, 0.0)
    denom = jnp.sum(e, axis=axis, keepdims=True)
    return e / jnp.maximum(denom, jnp.finfo(jnp.float32).tiny)


def top_weight_stats(
    weights: jax.Array, true_weights: jax.Array, k: int
) -> Tuple[jax.Array, jax.Array]:
    """Fig. 13b metric: fraction of the true top-k entries kept.

    Returns (recall_at_k, kept_fraction).
    """
    n = weights.shape[-1]
    k = min(k, n)
    _, true_top = jax.lax.top_k(true_weights, k)
    kept = jnp.take_along_axis(weights, true_top, axis=-1) > 0
    recall = jnp.mean(kept.astype(jnp.float32), axis=-1)
    kept_fraction = jnp.mean((weights > 0).astype(jnp.float32), axis=-1)
    return recall, kept_fraction
