"""Core A³ algorithm: candidate selection, post-scoring, quantization."""
from repro.core.a3_attention import (
    A3State,
    a3_attention_batch,
    a3_attention_single,
    a3_self_attention,
    candidate_block_map,
    flop_savings,
    preprocess,
)
from repro.core.candidate_selection import (
    SortedKeys,
    quantize_sorted_keys,
    select_candidates,
    select_candidates_batch,
    select_candidates_oracle,
    sort_key_columns,
)
from repro.core.post_scoring import masked_softmax, post_scoring_mask, top_weight_stats
from repro.core.quantization import (
    LutExp,
    cached_lut_exp,
    dequantize_int8_block,
    make_lut_exp,
    quantize_fixed_point,
    quantize_int8_block,
    softmax_fixed_point,
)

__all__ = [
    "A3State", "a3_attention_batch", "a3_attention_single", "a3_self_attention",
    "candidate_block_map", "flop_savings", "preprocess",
    "SortedKeys", "quantize_sorted_keys", "select_candidates",
    "select_candidates_batch", "select_candidates_oracle",
    "sort_key_columns",
    "masked_softmax", "post_scoring_mask", "top_weight_stats",
    "LutExp", "cached_lut_exp", "make_lut_exp", "quantize_fixed_point",
    "softmax_fixed_point", "quantize_int8_block", "dequantize_int8_block",
]
