"""Common model building blocks (pure JAX, no flax).

Parameters are plain nested dicts of jnp arrays; every constructor has an
``init`` (returns params) and an ``apply``-style function. Layer stacks are
stored with a leading ``layers`` axis and consumed by ``lax.scan``.

The attention here is the *analyzable-HLO* path used by training and the
dry-run: a chunked online-softmax (flash) attention written in jnp +
``lax.scan`` so that the S×S score matrix is never materialized and
``cost_analysis()`` sees the real FLOPs. The Pallas kernels in
``repro.kernels`` are the deployment path (``use_pallas=True``).
"""
from __future__ import annotations

import math
import threading
from contextlib import contextmanager
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# activation sharding constraints
#
# GSPMD propagation from params/inputs alone replicates activations inside
# the remat'd layer scan (observed: per-device attention FLOPs 16x too
# high on the 256-chip dry-run). Production frameworks pin activations
# explicitly; ``activation_shardings`` installs a dict of NamedShardings
# that ``shard_act`` applies at the few load-bearing points (block
# inputs, q/k/v, CE chunks). Active during tracing; a no-op when empty.
# ---------------------------------------------------------------------------

_ACT = threading.local()


@contextmanager
def activation_shardings(specs: Optional[Dict[str, Any]]):
    old = getattr(_ACT, "specs", None)
    _ACT.specs = specs or {}
    try:
        yield
    finally:
        _ACT.specs = old


def shard_act(x: jax.Array, kind: str) -> jax.Array:
    specs = getattr(_ACT, "specs", None)
    if not specs:
        return x
    s = specs.get(kind)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return out.astype(dtype) * params["scale"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: [..., S] int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                  # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    angles = angles[..., None, :]                              # [..., S, 1, Dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked flash attention in pure jnp (analyzable HLO, bounded memory)
# ---------------------------------------------------------------------------

def attention_xla_flash(
    q: jax.Array,                   # [B, Hq, Sq, D]
    k: jax.Array,                   # [B, Hkv, Sk, D]
    v: jax.Array,                   # [B, Hkv, Sk, Dv]
    *,
    causal: bool = True,
    window: Optional[jax.Array] = None,   # scalar (may be traced) or None
    scale: Optional[float] = None,
    chunk: int = 1024,
    q_offset: Optional[jax.Array] = None,  # abs position of q row 0
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    chunk = min(chunk, sk)
    n_chunks = (sk + chunk - 1) // chunk
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))

    # keep the GQA group as its own axis: [B, Hkv, G, Sq, D]. Folding it
    # into Sq (the obvious trick) materializes tiled masks and breaks
    # sequence-sharding constraints under GSPMD (observed: 4x activation
    # memory on MQA archs).
    qf = (q.astype(jnp.float32) * scale).reshape(b, hkv, group, sq, d)

    rows = jnp.arange(sq, dtype=jnp.int32)
    if q_offset is None:
        q_offset = jnp.int32(sk - sq)
    abs_rows = rows + q_offset                                 # [Sq]

    kc = k.reshape(b, hkv, n_chunks, chunk, d).astype(jnp.float32)
    vc = v.reshape(b, hkv, n_chunks, chunk, dv).astype(jnp.float32)
    kc = jnp.moveaxis(kc, 2, 0)                                # [C,B,Hkv,ck,d]
    vc = jnp.moveaxis(vc, 2, 0)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, ci = xs
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kb)    # [B,Hkv,G,Sq,ck]
        cols = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        mask = cols[None, :] < sk
        if causal:
            mask = mask & (cols[None, :] <= abs_rows[:, None])
        if window is not None:
            mask = mask & (cols[None, :] > abs_rows[:, None] - window)
        # mask: [Sq, ck], broadcast over batch/head/group
        mb = mask[None, None, None]
        s = jnp.where(mb, s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mb, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, group, sq, 1), -1e30, dtype=jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq, 1), dtype=jnp.float32)
    a0 = jnp.zeros((b, hkv, group, sq, dv), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc, vc, jnp.arange(n_chunks, dtype=jnp.int32)))
    safe = jnp.where(l == 0.0, 1.0, l)
    out = jnp.where(l == 0.0, 0.0, acc / safe)
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (GQA + RoPE), shared by all transformer archs
# ---------------------------------------------------------------------------

def attention_init(key, d_model: int, n_q: int, n_kv: int, head_dim: int,
                   dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d_model, n_q * head_dim, dtype),
        "wk": dense_init(k2, d_model, n_kv * head_dim, dtype),
        "wv": dense_init(k3, d_model, n_kv * head_dim, dtype),
        "wo": dense_init(k4, n_q * head_dim, d_model, dtype,
                         scale=1.0 / math.sqrt(n_q * head_dim)),
    }


def attention_qkv(params: Params, x: jax.Array, positions: jax.Array,
                  n_q: int, n_kv: int, head_dim: int, rope_theta: float
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, n_q, head_dim)
    k = (x @ params["wk"]).reshape(b, s, n_kv, head_dim)
    v = (x @ params["wv"]).reshape(b, s, n_kv, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    # -> [B, H, S, Dh]
    return (jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1))


def attention_out(params: Params, o: jax.Array) -> jax.Array:
    # o: [B, H, S, Dh] -> [B, S, D]
    b, h, s, hd = o.shape
    return jnp.moveaxis(o, 1, 2).reshape(b, s, h * hd) @ params["wo"]


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def ffn_init(key, d_model: int, d_ff: int, dtype, act: str = "swiglu") -> Params:
    if act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype,
                                 scale=1.0 / math.sqrt(d_ff)),
        }
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype,
                             scale=1.0 / math.sqrt(d_ff)),
    }


def ffn_apply(params: Params, x: jax.Array, act: str = "swiglu") -> jax.Array:
    if act == "swiglu":
        g = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
        u = (x @ params["w_up"]).astype(jnp.float32)
        return ((g * u).astype(x.dtype)) @ params["w_down"]
    h = jax.nn.gelu((x @ params["w_up"]).astype(jnp.float32))
    return h.astype(x.dtype) @ params["w_down"]


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    lf = logits.astype(jnp.float32)
    return (jnp.tanh(lf / cap) * cap).astype(logits.dtype)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       ignore_index: int = -1) -> jax.Array:
    """logits [..., V] (any dtype), labels [...] int32. Mean over valid."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    safe_labels = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(lf, safe_labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    valid = (labels != ignore_index).astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
