"""Mixture-of-Experts FFN (DeepSeekMoE / Grok style) in pure JAX.

Design goals:
  * FLOP-faithful: only ``top_k (+ shared)`` experts' MACs appear in the
    HLO (capacity-based gather dispatch — no dense all-expert einsum), so
    ``cost_analysis`` reports true active FLOPs for the roofline.
  * EP-shardable: expert weight stacks carry a leading ``experts`` axis
    that the sharding rules place on the ``model`` mesh axis when
    divisible; dispatch/combine are gathers XLA turns into all-to-alls
    under pjit.
  * Fine-grained experts (DeepSeekMoE): ``num_shared`` always-on experts
    fused into one dense SwiGLU of width ``num_shared * d_expert``.

Routing: softmax router, top-k, capacity factor with token dropping
(dropped tokens pass through the residual only), auxiliary load-balance
loss (Switch-style), optional router jitter at train time.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import MoEConfig
from repro.models.common import Params, dense_init, ffn_apply, ffn_init


def moe_init(key, d_model: int, moe: MoEConfig, dtype) -> Params:
    d_e = moe.d_expert or 0
    assert d_e > 0, "MoEConfig.d_expert must be set"
    keys = jax.random.split(key, 5)
    params: Params = {
        "router": dense_init(keys[0], d_model, moe.num_experts, jnp.float32,
                             scale=1.0 / math.sqrt(d_model)),
        # stacked routed experts: [E, ...]
        "w_gate": _stack_init(keys[1], moe.num_experts, d_model, d_e, dtype),
        "w_up": _stack_init(keys[2], moe.num_experts, d_model, d_e, dtype),
        "w_down": _stack_init(keys[3], moe.num_experts, d_e, d_model, dtype,
                              scale=1.0 / math.sqrt(d_e)),
    }
    if moe.num_shared > 0:
        params["shared"] = ffn_init(keys[4], d_model, moe.num_shared * d_e,
                                    dtype, act="swiglu")
    return params


def _stack_init(key, e: int, d_in: int, d_out: int, dtype,
                scale: Optional[float] = None):
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def moe_apply(
    params: Params,
    x: jax.Array,                   # [B, S, D]
    moe: MoEConfig,
    *,
    capacity_factor: float = 1.25,
    rng: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.top_k
    xt = x.reshape(t, d)

    # ---- routing ----
    logits = (xt.astype(jnp.float32) @ params["router"])       # [T, E]
    if moe.router_jitter > 0 and rng is not None:
        logits += moe.router_jitter * jax.random.normal(rng, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                      # [T, k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # ---- capacity + sort-based dispatch ----
    cap = int(math.ceil(t * k / e * capacity_factor))
    cap = max(cap, 4)
    cap = ((cap + 63) // 64) * 64          # shardable over the DP axes
    flat_e = top_e.reshape(-1)                                  # [T*k]
    # stable sort by expert; rank within expert = position - expert start
    sort_idx = jnp.argsort(flat_e, stable=True)                 # [T*k]
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=e)                     # [E]
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k) - starts[sorted_e]                 # [T*k]
    keep = rank < cap
    token_of = sort_idx // k                                    # [T*k]
    slot = sorted_e * cap + rank                                # [T*k]
    slot = jnp.where(keep, slot, e * cap)                       # overflow bin

    # gather tokens into [E*cap(+1), D]
    buf = jnp.zeros((e * cap + 1, d), dtype=x.dtype)
    buf = buf.at[slot].set(xt[token_of])
    expert_in = buf[: e * cap].reshape(e, cap, d)

    # ---- expert FFN (batched over the expert axis) ----
    g = jax.nn.silu(jnp.einsum(
        "ecd,edf->ecf", expert_in, params["w_gate"]).astype(jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"]).astype(jnp.float32)
    h = (g * u).astype(x.dtype)
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # [E,cap,D]

    # ---- combine ----
    flat_out = jnp.concatenate(
        [expert_out.reshape(e * cap, d),
         jnp.zeros((1, d), dtype=x.dtype)], axis=0)              # +overflow
    gathered = flat_out[slot]                                    # [T*k, D]
    w = top_p.reshape(-1)[sort_idx]                              # [T*k]
    w = jnp.where(keep, w, 0.0)
    combined = jnp.zeros((t, d), dtype=jnp.float32)
    combined = combined.at[token_of].add(
        gathered.astype(jnp.float32) * w[:, None])
    out = combined.astype(x.dtype)

    # ---- shared experts (always on) ----
    if "shared" in params:
        out = out + ffn_apply(params["shared"], xt, act="swiglu")

    # ---- aux: Switch load-balance loss ----
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=0)
    aux_loss = e * jnp.sum(frac_tokens * frac_probs) * moe.load_balance_coef
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return out.reshape(b, s, d), {"moe_aux_loss": aux_loss,
                                  "moe_drop_fraction": dropped}
