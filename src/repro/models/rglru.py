"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Train/prefill uses ``jax.lax.associative_scan`` over the diagonal linear
recurrence (TPU-parallel); decode carries (conv buffer, h) state.

    r_t = sigmoid(x_t W_a)            # recurrence gate
    i_t = sigmoid(x_t W_x)            # input gate
    a_t = exp(c * r_t * log(sigmoid(Lambda)))      (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Block layout (Griffin recurrent block): in-proj to (gate, rnn) branches,
causal depthwise conv(4) on the rnn branch, RG-LRU, gelu(gate) * h, out-proj.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init

CONV_WIDTH = 4
LRU_C = 8.0


def rglru_init(key, d_model: int, d_rnn: int, dtype) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "w_in_gate": dense_init(ks[0], d_model, d_rnn, dtype),
        "w_in_rnn": dense_init(ks[1], d_model, d_rnn, dtype),
        "conv_w": (jax.random.normal(ks[2], (CONV_WIDTH, d_rnn)) /
                   math.sqrt(CONV_WIDTH)).astype(dtype),
        "conv_b": jnp.zeros((d_rnn,), dtype=dtype),
        "w_a": dense_init(ks[3], d_rnn, d_rnn, dtype),
        "w_x": dense_init(ks[4], d_rnn, d_rnn, dtype),
        # Lambda init so that a = sigmoid(Lambda)^c spans [0.9, 0.999]
        # (Griffin §2.4): sigmoid(lam) = exp(log(a_target)/c)
        "lam": jnp.asarray(
            jax.scipy.special.logit(
                jnp.exp(jnp.log(jnp.linspace(0.9, 0.999, d_rnn)) / LRU_C)),
            dtype=jnp.float32),
        "w_out": dense_init(ks[5], d_rnn, d_model, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 buf: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv, width CONV_WIDTH. x: [B, S, C].
    buf: [B, CONV_WIDTH-1, C] previous context (decode) or None (zero pad)."""
    bsz, s, c = x.shape
    if buf is None:
        buf = jnp.zeros((bsz, CONV_WIDTH - 1, c), dtype=x.dtype)
    xp = jnp.concatenate([buf, x], axis=1)               # [B, S+3, C]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(CONV_WIDTH):
        out = out + xp[:, i:i + s].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _lru_gates(params: Params, xc: jax.Array):
    r = jax.nn.sigmoid((xc @ params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xc @ params["w_x"]).astype(jnp.float32))
    log_a_base = jax.nn.log_sigmoid(params["lam"].astype(jnp.float32))
    log_a = LRU_C * r * log_a_base                      # [B, S, C], <= 0
    a = jnp.exp(log_a)
    gated_x = i * xc.astype(jnp.float32)
    b_term = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated_x
    return a, b_term


def _lru_scan(a: jax.Array, b: jax.Array,
              h0: Optional[jax.Array]) -> jax.Array:
    """Associative scan of h_t = a_t h_{t-1} + b_t along axis 1."""
    if h0 is not None:
        # fold h0 in as a virtual step 0 with a=1, b=h0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None].astype(jnp.float32), b], axis=1)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h[:, 1:] if h0 is not None else h


def _lru_scan_chunked(a: jax.Array, b: jax.Array, h0: jax.Array,
                      chunk: int) -> jax.Array:
    """Chunkwise associative scan: ``associative_scan`` within a chunk
    (TPU-parallel), ``lax.scan`` across chunks carrying h — bounding the
    scan's live intermediates to O(chunk) instead of O(S) (the
    unchunked version peaked at 184 GiB/device on the 500k dry-run).
    Falls back to a single scan when S doesn't divide."""
    bsz, s, _ = a.shape
    L = min(chunk, s)
    if s % L != 0:
        return _lru_scan(a, b, h0)
    n = s // L
    ac = jnp.moveaxis(a.reshape(bsz, n, L, -1), 1, 0)
    bc = jnp.moveaxis(b.reshape(bsz, n, L, -1), 1, 0)

    def step(carry, xs):
        ai, bi = xs
        hi = _lru_scan(ai, bi, carry)
        return hi[:, -1], hi

    _, hs = jax.lax.scan(step, h0, (ac, bc))
    return jnp.moveaxis(hs, 0, 1).reshape(bsz, s, -1)


def rglru_apply_scan(
    params: Params, x: jax.Array,
    h0: Optional[jax.Array] = None,
    conv_buf: Optional[jax.Array] = None,
    chunk: int = 512,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence RG-LRU block. x: [B, S, D].
    Returns (out [B, S, D], h_last [B, C], conv_buf_last [B, 3, C]).

    The recurrence runs chunkwise (:func:`_lru_scan_chunked`), bounding
    live intermediates to O(chunk) instead of O(S).
    """
    bsz, s, _ = x.shape
    gate = jax.nn.gelu((x @ params["w_in_gate"]).astype(jnp.float32))
    xr = x @ params["w_in_rnn"]
    xc = _causal_conv(xr, params["conv_w"], params["conv_b"], conv_buf)
    a, b = _lru_gates(params, xc)
    if h0 is None:
        h0 = jnp.zeros((bsz, a.shape[-1]), jnp.float32)
    h = _lru_scan_chunked(a, b, h0, chunk)
    out = (gate * h).astype(x.dtype) @ params["w_out"]
    prev = conv_buf if conv_buf is not None else jnp.zeros(
        (x.shape[0], CONV_WIDTH - 1, xr.shape[-1]), xr.dtype)
    new_buf = jnp.concatenate([prev, xr], axis=1)[:, -(CONV_WIDTH - 1):]
    return out, h[:, -1].astype(jnp.float32), new_buf


def rglru_chunk_step(
    params: Params, x: jax.Array,
    h0: jax.Array, conv_buf: jax.Array,
    valid: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Ragged mid-prompt chunk with carried state (chunked admission).

    x: [B, C, D]; h0: [B, C_rnn] f32; conv_buf: [B, CONV_WIDTH-1, C_rnn];
    valid: [B, C] bool — pad slots past a lane's chunk length. Pad
    positions carry the recurrence through unchanged (a=1, b=0), so
    ``h_last`` is the state after each lane's last *valid* token, and
    the conv buffer advances to each lane's last CONV_WIDTH-1 valid
    ``xr`` rows (a lane with no valid tokens keeps its buffer rows —
    the caller additionally reselects its state bit-identically).

    Returns (out [B, C, D], h_last [B, C_rnn] f32, new_buf).
    """
    gate = jax.nn.gelu((x @ params["w_in_gate"]).astype(jnp.float32))
    xr = x @ params["w_in_rnn"]                          # [B, C, C_rnn]
    xc = _causal_conv(xr, params["conv_w"], params["conv_b"], conv_buf)
    a, b = _lru_gates(params, xc)
    v = valid[..., None]
    a = jnp.where(v, a, 1.0)
    b = jnp.where(v, b, 0.0)
    h = _lru_scan_chunked(a, b, h0.astype(jnp.float32), chunk=512)
    out = (gate * h).astype(x.dtype) @ params["w_out"]
    # per-lane conv-tail gather: extended[b, j] = buf[j] for j < W-1 else
    # xr[j - (W-1)]; rows [length, length+W-2] are the last W-1 valid ones
    length = jnp.sum(valid.astype(jnp.int32), axis=1)    # [B]
    ext = jnp.concatenate([conv_buf.astype(xr.dtype), xr], axis=1)
    idx = (length[:, None]
           + jnp.arange(CONV_WIDTH - 1, dtype=jnp.int32)[None, :])
    new_buf = jnp.take_along_axis(ext, idx[..., None], axis=1)
    return out, h[:, -1], new_buf


def rglru_decode_step(
    params: Params, x: jax.Array,
    h: jax.Array, conv_buf: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token step. x: [B, 1, D]; h: [B, C]; conv_buf: [B, 3, C]."""
    gate = jax.nn.gelu((x @ params["w_in_gate"]).astype(jnp.float32))
    xr = x @ params["w_in_rnn"]                          # [B, 1, C]
    xc = _causal_conv(xr, params["conv_w"], params["conv_b"], conv_buf)
    a, b = _lru_gates(params, xc)                        # [B, 1, C]
    h_new = a[:, 0] * h + b[:, 0]
    out = (gate * h_new[:, None]).astype(x.dtype) @ params["w_out"]
    new_buf = jnp.concatenate([conv_buf, xr], axis=1)[:, 1:]
    return out, h_new, new_buf
