"""Modality frontend STUBS (per assignment: ``[audio]``/``[vlm]`` entries
specify the transformer backbone only; the frontend supplies precomputed
frame/patch embeddings).

* musicgen-medium: EnCodec tokenizer -> 4 parallel codebooks at 50 Hz.
  Stub: the four codebook embeddings are summed into one frame embedding
  (MusicGen's "delay" interleaving collapses to a single stream for the
  backbone); ``audio_frame_embeds`` returns deterministic pseudo-frames.
* internvl2-2b: InternViT-300M patch encoder. Stub: ``vision_patch_embeds``
  returns pseudo patch embeddings already projected to the LM width; the
  text tokens follow them (prefix-LM layout collapsed to causal decode).

The dry-run's ``input_specs()`` only needs shapes; these helpers exist so
smoke tests and examples can run real values end-to-end.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def audio_frame_embeds(key, batch: int, frames: int, cfg: ModelConfig,
                       num_codebooks: Optional[int] = None) -> jax.Array:
    """Stub EnCodec frontend: [B, frames, d_model] summed codebook embeds."""
    nc = num_codebooks or cfg.num_codebooks
    ks = jax.random.split(key, nc)
    out = jnp.zeros((batch, frames, cfg.d_model), jnp.float32)
    for i in range(nc):
        out = out + jax.random.normal(ks[i], (batch, frames, cfg.d_model))
    return (out / jnp.sqrt(float(nc))).astype(jnp.dtype(cfg.dtype))


def vision_patch_embeds(key, batch: int, patches: int,
                        cfg: ModelConfig) -> jax.Array:
    """Stub InternViT frontend: [B, patches, d_model] patch embeddings."""
    x = jax.random.normal(key, (batch, patches, cfg.d_model))
    return x.astype(jnp.dtype(cfg.dtype))


def vlm_sequence(key, batch: int, seq_len: int, num_patches: int,
                 cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """[vision patches; text embeddings] layout used by internvl2 examples.

    Returns (inputs_embeds [B, S, D], text_tokens [B, S-num_patches]).
    """
    k1, k2 = jax.random.split(key)
    vis = vision_patch_embeds(k1, batch, num_patches, cfg)
    n_text = seq_len - num_patches
    toks = jax.random.randint(k2, (batch, n_text), 0, cfg.vocab_size)
    return vis, toks
