"""End-to-End Memory Network (Sukhbaatar et al.) — the paper's primary
workload (SSVI-A, bAbI QA).

The attention inside each hop is *exactly* the paper's Figure-1 kernel:
one query vector against an n x d key matrix and an n x d value matrix.
``answer_with_a3`` routes that hop through ``repro.core.a3_attention`` so
the accuracy experiments (Fig 11/12/13) exercise the real approximation
pipeline, including candidate selection on the pre-sorted key matrix and
post-scoring selection.

Sentences are embedded as position-weighted bags of words (the paper's
"PE" encoding); adjacent-weight tying (A^{k+1} = C^k) as in the original.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import A3Config, A3Mode
from repro.core.a3_attention import A3State, a3_attention_single, preprocess
from repro.core.post_scoring import masked_softmax
from repro.models.common import Params, dense_init


class MemN2NConfig(NamedTuple):
    vocab_size: int
    d_embed: int = 64
    num_hops: int = 3
    max_sentences: int = 50      # n
    max_words: int = 12          # words per sentence


def init_params(key, cfg: MemN2NConfig) -> Params:
    ks = jax.random.split(key, cfg.num_hops + 4)
    scale = 0.1
    emb = lambda k: (jax.random.normal(
        k, (cfg.vocab_size, cfg.d_embed)) * scale).astype(jnp.float32)
    # adjacent tying: embeddings[0] = A^1, embeddings[i] = C^i = A^{i+1}
    # temporal encoding T_A/T_C (Sukhbaatar SS4.1): memories are tagged by
    # recency so "most recent supporting fact" is learnable.
    tkey = jax.random.split(ks[cfg.num_hops + 3], cfg.num_hops + 1)
    temporal = jnp.stack([
        (jax.random.normal(tk, (cfg.max_sentences, cfg.d_embed)) * scale)
        for tk in tkey])
    return {
        "embeddings": jnp.stack([emb(ks[i])
                                 for i in range(cfg.num_hops + 1)]),
        "temporal": temporal.astype(jnp.float32),
        "query_embed": emb(ks[cfg.num_hops + 1]),
        "w_final": dense_init(ks[cfg.num_hops + 2], cfg.d_embed,
                              cfg.vocab_size, jnp.float32),
    }


def position_encoding(cfg: MemN2NConfig) -> jax.Array:
    """bAbI position-encoding weights l_kj (Sukhbaatar eq. PE)."""
    J, d = cfg.max_words, cfg.d_embed
    j = jnp.arange(1, J + 1, dtype=jnp.float32)[:, None]
    k = jnp.arange(1, d + 1, dtype=jnp.float32)[None, :]
    return (1 - j / J) - (k / d) * (1 - 2 * j / J)            # [J, d]


def embed_sentences(embed: jax.Array, sentences: jax.Array,
                    cfg: MemN2NConfig,
                    temporal: Optional[jax.Array] = None) -> jax.Array:
    """sentences: [n, J] int32 (0 = pad) -> [n, d]."""
    pe = position_encoding(cfg)
    vecs = embed[sentences] * pe[None]                        # [n, J, d]
    mask = (sentences > 0)[..., None].astype(jnp.float32)
    out = jnp.sum(vecs * mask, axis=1)
    if temporal is not None:
        # recency index: most recent valid sentence -> T[0]
        valid = jnp.any(sentences > 0, axis=-1)
        count = jnp.sum(valid.astype(jnp.int32))
        idx = jnp.clip(count - 1 - jnp.arange(sentences.shape[0]), 0,
                       cfg.max_sentences - 1)
        out = out + temporal[idx] * valid[:, None]
    return out


def answer(params: Params, sentences: jax.Array, question: jax.Array,
           cfg: MemN2NConfig, sentence_mask: Optional[jax.Array] = None,
           linear: bool = False) -> jax.Array:
    """Exact (training) forward. sentences [n, J], question [J].
    Returns answer logits [V].

    ``linear=True`` is the original paper's "linear start" (LS): the
    softmax is removed early in training so the retrieval circuit gets
    first-order gradient, then training switches to softmax.
    """
    q = jnp.sum(params["query_embed"][question]
                * (question > 0)[:, None].astype(jnp.float32), axis=0)
    u = q
    for hop in range(cfg.num_hops):
        key_mat = embed_sentences(params["embeddings"][hop], sentences, cfg,
                                  params["temporal"][hop])
        val_mat = embed_sentences(params["embeddings"][hop + 1], sentences,
                                  cfg, params["temporal"][hop + 1])
        scores = key_mat @ u                                   # [n]
        mask = sentence_mask if sentence_mask is not None else (
            jnp.any(sentences > 0, axis=-1))
        if linear:
            w = jnp.where(mask, scores, 0.0)
            w = w / jnp.maximum(jnp.sum(mask), 1.0)
        else:
            w = masked_softmax(scores, mask)
        o = w @ val_mat
        u = u + o
    return u @ params["w_final"]


def answer_with_a3(params: Params, sentences: jax.Array, question: jax.Array,
                   cfg: MemN2NConfig, a3: A3Config) -> Tuple[jax.Array, Dict]:
    """Inference forward with the A^3 pipeline in each hop."""
    q = jnp.sum(params["query_embed"][question]
                * (question > 0)[:, None].astype(jnp.float32), axis=0)
    u = q
    aux_all = {}
    mask = jnp.any(sentences > 0, axis=-1)
    for hop in range(cfg.num_hops):
        key_mat = embed_sentences(params["embeddings"][hop], sentences, cfg,
                                  params["temporal"][hop])
        val_mat = embed_sentences(params["embeddings"][hop + 1], sentences,
                                  cfg, params["temporal"][hop + 1])
        # empty (padded) sentences get a strongly negative key so the
        # greedy selection never picks them
        key_mat = jnp.where(mask[:, None], key_mat, 0.0)
        state = preprocess(key_mat, val_mat)
        out, aux = a3_attention_single(state, u, a3)
        u = u + out
        aux_all[f"hop{hop}"] = aux
    return u @ params["w_final"], aux_all


def loss_fn(params: Params, batch: Dict[str, jax.Array],
            cfg: MemN2NConfig, linear: bool = False) -> jax.Array:
    """batch: sentences [B, n, J], question [B, J], answer [B]."""
    logits = jax.vmap(lambda s, q: answer(params, s, q, cfg,
                                          linear=linear))(
        batch["sentences"], batch["question"])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["answer"][:, None], 1)[:, 0]
    return jnp.mean(lse - gold)


def accuracy(params: Params, batch: Dict[str, jax.Array], cfg: MemN2NConfig,
             a3: Optional[A3Config] = None) -> jax.Array:
    if a3 is None or a3.mode == A3Mode.OFF:
        logits = jax.vmap(lambda s, q: answer(params, s, q, cfg))(
            batch["sentences"], batch["question"])
    else:
        logits = jax.vmap(
            lambda s, q: answer_with_a3(params, s, q, cfg, a3)[0])(
            batch["sentences"], batch["question"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["answer"])
                    .astype(jnp.float32))
