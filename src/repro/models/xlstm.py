"""xLSTM blocks (mLSTM + sLSTM) in pure JAX.

mLSTM (matrix memory): per head a d_k x d_v matrix memory C with
exponential input/forget gates in log space (stabilizer m):

    f_t = exp-gate, i_t = exp-gate
    m_t = max(log f_t + m_{t-1}, log i_t)
    C_t = f'_t C_{t-1} + i'_t (k_t v_t^T),  f' = exp(log f + m_{t-1} - m_t)
    n_t = f'_t n_{t-1} + i'_t k_t
    h_t = C_t^T q_t / max(|n_t . q_t|, 1)

Parallel (training/prefill) form: the same recurrence expressed as masked
attention with log-gate cumulative sums (the "parallel mLSTM" of the
paper, eq. 26-28) — O(S^2) like attention but with gate decay instead of
softmax. There is no softmax score vector over n keys, hence A^3 is
inapplicable (DESIGN.md SS5).

sLSTM (scalar memory): per-channel recurrence with exponential gating and
a stabilizer; block-diagonal recurrent weights (num_heads blocks). This
one is inherently sequential -> lax.scan over time.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, d_model: int, num_heads: int, head_dim: int, dtype) -> Params:
    ks = jax.random.split(key, 7)
    dh = num_heads * head_dim
    return {
        "wq": dense_init(ks[0], d_model, dh, dtype),
        "wk": dense_init(ks[1], d_model, dh, dtype),
        "wv": dense_init(ks[2], d_model, dh, dtype),
        # scalar gates per head, computed from x
        "w_i": dense_init(ks[3], d_model, num_heads, jnp.float32),
        "w_f": dense_init(ks[4], d_model, num_heads, jnp.float32),
        "b_i": jnp.zeros((num_heads,), jnp.float32),
        # forget bias init positive => long memory at init
        "b_f": jnp.full((num_heads,), 3.0, jnp.float32),
        "w_o": dense_init(ks[5], d_model, dh, dtype),     # output gate
        "w_out": dense_init(ks[6], dh, d_model, dtype,
                            scale=1.0 / math.sqrt(dh)),
        "ln_scale": jnp.ones((num_heads, head_dim), jnp.float32),
    }


def _mlstm_gates(params: Params, x: jax.Array):
    """log input gate and log-sigmoid forget gate, [B, S, H] f32."""
    xf = x.astype(jnp.float32)
    log_i = xf @ params["w_i"] + params["b_i"]                # pre-act; i=exp()
    f_pre = xf @ params["w_f"] + params["b_f"]
    log_f = jax.nn.log_sigmoid(f_pre)                         # <= 0
    return log_i, log_f


def _headwise_ln(h: jax.Array, scale: jax.Array, eps: float = 1e-6):
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    return (h - mu) * jax.lax.rsqrt(var + eps) * scale


def mlstm_chunkwise(params: Params, x: jax.Array, num_heads: int,
                    head_dim: int, *, chunk: int = 256, state=None,
                    valid: Optional[jax.Array] = None):
    """Chunkwise-parallel mLSTM forward -> (out [B, S, D], final state).

    Intra-chunk: quadratic gate-decay attention over a [chunk, chunk] tile.
    Inter-chunk: the (C, n, m) matrix-memory state is carried by a scan —
    the TPU-friendly linear-cost formulation (memory O(S * chunk), not
    O(S^2)), which is also what makes the 500k-token shape runnable.

    ``state`` resumes from a carried (C, n, m) — the chunked-admission
    mid-prompt case. ``valid`` [B, S] masks ragged pad positions with
    the same gate trick used for tile padding (i-gate = -inf: no state
    write; f-gate = 0: carry state through), so the returned state is
    the state after each lane's last *valid* token. A lane with no valid
    tokens is the caller's job to reselect bit-identically (an all-pad
    lane whose carried ``m`` is already the -1e30 init would otherwise
    hit the exp(-1e30 + 1e30) = 1 degeneracy and absorb pad keys).
    """
    b, s, _ = x.shape
    dh = num_heads * head_dim
    L = min(chunk, s)
    n_chunks = (s + L - 1) // L
    pad = n_chunks * L - s

    q = (x @ params["wq"]).reshape(b, s, num_heads, head_dim)
    k = (x @ params["wk"]).reshape(b, s, num_heads, head_dim)
    v = (x @ params["wv"]).reshape(b, s, num_heads, head_dim)
    log_i, log_f = _mlstm_gates(params, x)                    # [B, S, H]
    if valid is not None:
        log_i = jnp.where(valid[..., None], log_i, -1e30)
        log_f = jnp.where(valid[..., None], log_f, 0.0)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded steps: i-gate = -inf (no write), f-gate = 0 (keep state)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))

    sp = n_chunks * L
    # streams stay in the model dtype (bf16 in production): the f32 cast
    # happens per chunk tile inside the scan — halves the HBM bytes of
    # the scanned q/k/v arrays (SSPerf H1)
    q = jnp.moveaxis(q, 2, 1)                                 # [B,H,Sp,Dh]
    k = jnp.moveaxis(k, 2, 1)
    v = jnp.moveaxis(v, 2, 1)
    log_i = jnp.moveaxis(log_i, 2, 1)                         # [B,H,Sp]
    log_f = jnp.moveaxis(log_f, 2, 1)

    def split(t, feat):                                       # -> [C,B,H,L,...]
        t = t.reshape(b, num_heads, n_chunks, L, *feat)
        return jnp.moveaxis(t, 2, 0)

    qc, kc, vc = (split(t, (head_dim,)) for t in (q, k, v))
    lic, lfc = split(log_i, ()), split(log_f, ())

    if state is None:
        state = mlstm_init_state(b, num_heads, head_dim)

    idx = jnp.arange(L)
    causal = idx[:, None] >= idx[None, :]                     # [L, L]

    def step(carry, xs):
        C, n, m = carry                                       # [B,H,Dk,Dv], [B,H,Dk], [B,H]
        qb, kb, vb, li, lf = xs                               # [B,H,L,*]
        qb = qb.astype(jnp.float32)
        kb = kb.astype(jnp.float32) / math.sqrt(head_dim)
        vb = vb.astype(jnp.float32)
        F = jnp.cumsum(lf, axis=-1)                           # [B,H,L]
        Ftot = F[..., -1]
        # intra-chunk decay D[t,u] = F[t] - F[u] + li[u], u <= t
        D = F[..., :, None] - F[..., None, :] + li[..., None, :]
        D = jnp.where(causal, D, -1e30)
        intra_max = jnp.max(D, axis=-1)                       # [B,H,L]
        inter_log = F + m[..., None]                          # decay of carried state
        m_row = jnp.maximum(intra_max, inter_log)             # [B,H,L]
        w = jnp.exp(D - m_row[..., None])                     # [B,H,L,L]
        scores = jnp.einsum("bhtd,bhud->bhtu", qb, kb) * w
        inter_w = jnp.exp(inter_log - m_row)                  # [B,H,L]
        num = (jnp.einsum("bhtu,bhud->bhtd", scores, vb)
               + inter_w[..., None]
               * jnp.einsum("bhkv,bhtk->bhtv", C, qb))
        den = (jnp.sum(scores, axis=-1)
               + inter_w * jnp.einsum("bhk,bhtk->bht", n, qb))
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_row))
        h = num / den[..., None]                              # [B,H,L,Dv]
        # ---- state update to end of chunk ----
        wr_log = Ftot[..., None] - F + li                     # [B,H,L]
        m_new = jnp.maximum(Ftot + m, jnp.max(wr_log, axis=-1))
        f_eff = jnp.exp(Ftot + m - m_new)
        wr = jnp.exp(wr_log - m_new[..., None])               # [B,H,L]
        C_new = (f_eff[..., None, None] * C
                 + jnp.einsum("bhu,bhuk,bhuv->bhkv", wr, kb, vb))
        n_new = f_eff[..., None] * n + jnp.einsum("bhu,bhuk->bhk", wr, kb)
        return (C_new, n_new, m_new), h

    state, hs = jax.lax.scan(step, state, (qc, kc, vc, lic, lfc))
    hs = jnp.moveaxis(hs, 0, 2).reshape(b, num_heads, sp, head_dim)
    hs = hs[:, :, :s]
    h = _headwise_ln(hs, params["ln_scale"][None, :, None, :])
    o = jax.nn.sigmoid((x @ params["w_o"]).astype(jnp.float32))
    h = jnp.moveaxis(h, 1, 2).reshape(b, s, dh) * o
    return h.astype(x.dtype) @ params["w_out"], state


def mlstm_parallel(params: Params, x: jax.Array, num_heads: int,
                   head_dim: int, chunk: int = 256,
                   state=None) -> jax.Array:
    """Output-only view of :func:`mlstm_chunkwise` (train / forward)."""
    return mlstm_chunkwise(params, x, num_heads, head_dim, chunk=chunk,
                           state=state)[0]


def mlstm_init_state(batch: int, num_heads: int, head_dim: int):
    C = jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32)
    n = jnp.zeros((batch, num_heads, head_dim), jnp.float32)
    m = jnp.full((batch, num_heads), -1e30, jnp.float32)
    return (C, n, m)


def mlstm_decode_step(params: Params, x: jax.Array, state,
                      num_heads: int, head_dim: int):
    """One-token recurrent step. x: [B, 1, D]. Returns (out, new_state)."""
    b = x.shape[0]
    C, n, m = state
    q = (x @ params["wq"]).reshape(b, num_heads, head_dim).astype(jnp.float32)
    k = (x @ params["wk"]).reshape(b, num_heads, head_dim).astype(jnp.float32)
    k = k / math.sqrt(head_dim)
    v = (x @ params["wv"]).reshape(b, num_heads, head_dim).astype(jnp.float32)
    log_i, log_f = _mlstm_gates(params, x)                    # [B, 1, H]
    log_i, log_f = log_i[:, 0], log_f[:, 0]                   # [B, H]

    m_new = jnp.maximum(log_f + m, log_i)
    f_eff = jnp.exp(log_f + m - m_new)                        # [B, H]
    i_eff = jnp.exp(log_i - m_new)
    C_new = f_eff[..., None, None] * C + i_eff[..., None, None] * (
        k[..., :, None] * v[..., None, :])                    # [B,H,Dk,Dv]
    n_new = f_eff[..., None] * n + i_eff[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C_new, q)
    qn = jnp.einsum("bhk,bhk->bh", n_new, q)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    h = num / denom[..., None]                                # [B, H, Dv]
    h = _headwise_ln(h, params["ln_scale"][None])
    o = jax.nn.sigmoid((x @ params["w_o"]).astype(jnp.float32))[:, 0]
    h = (h.reshape(b, num_heads * head_dim) * o)
    out = h.astype(x.dtype) @ params["w_out"]
    return out[:, None, :], (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, d_model: int, num_heads: int, dtype) -> Params:
    """Block-diagonal recurrent sLSTM; hidden dim == d_model."""
    assert d_model % num_heads == 0
    dh = d_model // num_heads
    ks = jax.random.split(key, 3)
    wx = dense_init(ks[0], d_model, 4 * d_model, jnp.float32)
    # recurrent block-diagonal: [H, dh, 4*dh]
    wr = (jax.random.normal(ks[1], (num_heads, dh, 4 * dh)) /
          math.sqrt(dh)).astype(jnp.float32)
    bias = jnp.zeros((4 * d_model,), jnp.float32)
    # forget-gate bias chunk positive
    bias = bias.at[2 * d_model:3 * d_model].set(3.0)
    return {"wx": wx, "wr": wr, "b": bias,
            "w_out": dense_init(ks[2], d_model, d_model, dtype),
            "ln_scale": jnp.ones((d_model,), jnp.float32)}


def slstm_init_state(batch: int, d_model: int):
    z = jnp.zeros((batch, d_model), jnp.float32)
    return (z, z, jnp.full((batch, d_model), -1e30), z)  # c, n, m, h


def _slstm_cell(params: Params, xg: jax.Array, state, num_heads: int):
    """xg: [B, 4D] precomputed input contribution."""
    c, n, m, h = state
    b, d4 = xg.shape
    d = d4 // 4
    dh = d // num_heads
    hb = h.reshape(b, num_heads, dh)
    rec = jnp.einsum("bhd,hdf->bhf", hb, params["wr"]).reshape(b, 4 * d)
    z, i_pre, f_pre, o_pre = jnp.split(xg + rec + params["b"], 4, axis=-1)
    log_i = i_pre
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, log_i)
    i_eff = jnp.exp(log_i - m_new)
    f_eff = jnp.exp(log_f + m - m_new)
    c_new = f_eff * c + i_eff * jnp.tanh(z)
    n_new = f_eff * n + i_eff
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new)


def slstm_apply_scan(params: Params, x: jax.Array, num_heads: int,
                     state=None,
                     valid: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, tuple]:
    """x: [B, S, D] -> ([B, S, D], final_state). Sequential lax.scan.

    ``valid`` [B, S] masks ragged pad positions (chunked admission):
    a pad step reselects the carried state bit-identically, so the
    final state is the state after each lane's last valid token."""
    b, s, d = x.shape
    xg = (x.astype(jnp.float32) @ params["wx"])               # [B, S, 4D]
    if state is None:
        state = slstm_init_state(b, d)

    if valid is None:
        def step(carry, xt):
            new = _slstm_cell(params, xt, carry, num_heads)
            return new, new[3]

        xs = jnp.moveaxis(xg, 1, 0)
    else:
        def step(carry, xs_t):
            xt, vt = xs_t
            new = _slstm_cell(params, xt, carry, num_heads)
            new = tuple(jnp.where(vt[:, None], n, o)
                        for n, o in zip(new, carry))
            return new, new[3]

        xs = (jnp.moveaxis(xg, 1, 0), jnp.moveaxis(valid, 1, 0))

    state, hs = jax.lax.scan(step, state, xs)
    hs = jnp.moveaxis(hs, 0, 1)                               # [B, S, D]
    mu = jnp.mean(hs, -1, keepdims=True)
    var = jnp.var(hs, -1, keepdims=True)
    hs = (hs - mu) * jax.lax.rsqrt(var + 1e-6) * params["ln_scale"]
    return hs.astype(x.dtype) @ params["w_out"], state


def slstm_decode_step(params: Params, x: jax.Array, state, num_heads: int):
    """x: [B, 1, D]."""
    xg = (x[:, 0].astype(jnp.float32) @ params["wx"])
    new = _slstm_cell(params, xg, state, num_heads)
    h = new[3]
    mu = jnp.mean(h, -1, keepdims=True)
    var = jnp.var(h, -1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + 1e-6) * params["ln_scale"]
    out = (h.astype(x.dtype) @ params["w_out"])[:, None]
    return out, new
