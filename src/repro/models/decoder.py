"""Unified decoder stack covering all assigned architectures.

A model is a sequence of *segments*: maximal runs of layers sharing the
same (block kind, ffn kind, attention window) signature. Each segment's
layer parameters are stacked on a leading ``layers`` axis and executed
with ``lax.scan`` (compact HLO for the 512-device dry-run; remat applies
per layer). Examples:

  phi4-mini        -> 1 segment  (attention + dense FFN, full window)
  deepseek-moe     -> 2 segments (1 dense-FFN layer, 27 MoE layers)
  gemma3           -> 12 segments (5 local / 1 global alternating)
  recurrentgemma   -> 17 segments (rglru pairs / attention, 1:2)
  xlstm            -> alternating mLSTM / sLSTM segments

KV caches are **ring buffers** sized ``min(max_len, window)`` per
segment — sliding-window layers at 500k context keep an O(window) cache,
which is what makes ``long_500k`` runnable for SWA/hybrid archs.

Approximation (the paper's technique) is applied at inference only
(paper SSVI-B); ``decode_step`` takes an ``A3Config`` and routes windowless
attention layers through ``a3_decode_attention``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import A3Config, A3Mode, AttentionKind, BlockKind, ModelConfig
from repro.kernels.decode_attention.ops import a3_decode_attention
from repro.models import xlstm as xl
from repro.models.common import (
    Params,
    shard_act,
    attention_init,
    attention_out,
    attention_qkv,
    attention_xla_flash,
    cross_entropy_loss,
    dense_init,
    embed_init,
    ffn_apply,
    ffn_init,
    rmsnorm,
    rmsnorm_init,
    softcap,
)
from repro.models.moe import moe_apply, moe_init
from repro.models.rglru import (
    CONV_WIDTH,
    rglru_apply_scan,
    rglru_decode_step,
    rglru_init,
)

FULL_WINDOW = 1 << 30


def padded_vocab(v: int) -> int:
    """Pad vocab to a multiple of 128 (MXU lane + mesh divisibility)."""
    return ((v + 127) // 128) * 128


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    kind: BlockKind
    ffn: str                 # "dense" | "moe" | "none"
    window: int              # FULL_WINDOW for global attention
    layers: Tuple[int, ...]  # absolute layer indices

    @property
    def count(self) -> int:
        return len(self.layers)


def _layer_signature(cfg: ModelConfig, i: int) -> Tuple:
    kind = cfg.block_kind(i)
    if kind in (BlockKind.MLSTM, BlockKind.SLSTM):
        ffn = "dense" if cfg.d_ff else "none"
    elif cfg.moe is not None and i >= cfg.moe.num_dense_layers:
        ffn = "moe"
    else:
        ffn = "dense"
    window = FULL_WINDOW
    if kind == BlockKind.ATTENTION:
        if cfg.attention_kind == AttentionKind.SLIDING:
            window = cfg.window_size
        elif cfg.attention_kind == AttentionKind.LOCAL_GLOBAL:
            window = FULL_WINDOW if cfg.layer_is_global(i) else cfg.window_size
    return (kind, ffn, window)


def build_segments(cfg: ModelConfig) -> List[SegmentSpec]:
    segs: List[SegmentSpec] = []
    cur: List[int] = []
    cur_sig = None
    for i in range(cfg.num_layers):
        sig = _layer_signature(cfg, i)
        if sig != cur_sig and cur:
            segs.append(SegmentSpec(cur_sig[0], cur_sig[1], cur_sig[2],
                                    tuple(cur)))
            cur = []
        cur_sig = sig
        cur.append(i)
    if cur:
        segs.append(SegmentSpec(cur_sig[0], cur_sig[1], cur_sig[2], tuple(cur)))
    return segs


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, seg: SegmentSpec) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": rmsnorm_init(d, dtype)}
    if seg.kind == BlockKind.ATTENTION:
        p["attn"] = attention_init(ks[0], d, cfg.num_heads, cfg.num_kv_heads,
                                   hd, dtype)
    elif seg.kind == BlockKind.RGLRU:
        p["rnn"] = rglru_init(ks[0], d, cfg.num_heads * hd, dtype)
    elif seg.kind == BlockKind.MLSTM:
        p["mlstm"] = xl.mlstm_init(ks[0], d, cfg.num_heads, hd, dtype)
    elif seg.kind == BlockKind.SLSTM:
        p["slstm"] = xl.slstm_init(ks[0], d, cfg.num_heads, dtype)
    if seg.ffn != "none":
        p["ln2"] = rmsnorm_init(d, dtype)
    if seg.ffn == "dense":
        p["ffn"] = ffn_init(ks[1], d, cfg.d_ff, dtype, act=cfg.act)
    elif seg.ffn == "moe":
        moe_cfg = cfg.moe
        if (moe_cfg.d_expert or 0) == 0:
            moe_cfg = dataclasses.replace(moe_cfg, d_expert=cfg.d_ff)
        p["moe"] = moe_init(ks[1], d, moe_cfg, dtype)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    vp = padded_vocab(cfg.vocab_size)
    segs = build_segments(cfg)
    n_keys = 2 + len(segs)
    keys = jax.random.split(key, n_keys)
    params: Params = {
        "embed": embed_init(keys[0], vp, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, vp, dtype)
    for si, seg in enumerate(segs):
        lkeys = jax.random.split(keys[2 + si], seg.count)
        stacked = jax.vmap(lambda k: _layer_init(k, cfg, seg))(lkeys)
        params[f"seg{si}"] = stacked
    return params


def init_params_shape(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct pytree of the params (no allocation; dry-run)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _moe_cfg(cfg: ModelConfig):
    m = cfg.moe
    if m is not None and (m.d_expert or 0) == 0:
        m = dataclasses.replace(m, d_expert=cfg.d_ff)
    return m


def _block_forward(lp: Params, h: jax.Array, positions: jax.Array,
                   cfg: ModelConfig, seg: SegmentSpec,
                   attn_chunk: int) -> Tuple[jax.Array, jax.Array]:
    """One layer forward (full sequence). Returns (h, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = shard_act(h, "hidden")
    hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
    if seg.kind == BlockKind.ATTENTION:
        q, k, v = attention_qkv(lp["attn"], hn, positions, cfg.num_heads,
                                cfg.num_kv_heads, cfg.resolved_head_dim,
                                cfg.rope_theta)
        q = shard_act(q, "q")
        k = shard_act(k, "kv")
        v = shard_act(v, "kv")
        window = None if seg.window >= FULL_WINDOW else jnp.int32(seg.window)
        o = attention_xla_flash(q, k, v, causal=True, window=window,
                                chunk=attn_chunk)
        h = h + attention_out(lp["attn"], o)
    elif seg.kind == BlockKind.RGLRU:
        o, _, _ = rglru_apply_scan(lp["rnn"], hn)
        h = h + o
    elif seg.kind == BlockKind.MLSTM:
        h = h + xl.mlstm_parallel(lp["mlstm"], hn, cfg.num_heads,
                                  cfg.resolved_head_dim)
    elif seg.kind == BlockKind.SLSTM:
        o, _ = xl.slstm_apply_scan(lp["slstm"], hn, cfg.num_heads)
        h = h + o
    if seg.ffn == "dense":
        hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        h = h + ffn_apply(lp["ffn"], hn, act=cfg.act)
    elif seg.ffn == "moe":
        hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        o, moe_aux = moe_apply(lp["moe"], hn, _moe_cfg(cfg))
        h = h + o
        aux = aux + moe_aux["moe_aux_loss"]
    return h, aux


def _run_segment(params_seg: Params, h: jax.Array, positions: jax.Array,
                 cfg: ModelConfig, seg: SegmentSpec, remat: str,
                 attn_chunk: int) -> Tuple[jax.Array, jax.Array]:
    def body(carry, lp):
        out, aux = _block_forward(lp, carry, positions, cfg, seg, attn_chunk)
        return out, aux

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    h, auxs = jax.lax.scan(body, h, params_seg)
    return h, jnp.sum(auxs)


def embed_tokens(params: Params, cfg: ModelConfig, tokens: jax.Array
                 ) -> jax.Array:
    h = params["embed"][tokens]
    return h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)


def unembed(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    logits = softcap(logits, cfg.logit_softcap)
    vp = logits.shape[-1]
    if vp != cfg.vocab_size:       # mask the vocab-padding columns
        pad_mask = jnp.arange(vp) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype),
                           logits)
    return logits


def forward_hidden(
    params: Params,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,        # [B, S] int32
    inputs_embeds: Optional[jax.Array] = None,  # [B, S, D] (frontend stubs)
    *,
    positions: Optional[jax.Array] = None,
    remat: str = "none",
    attn_chunk: int = 1024,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence forward up to (not including) the unembed.
    Returns (hidden [B, S, D], aux)."""
    if inputs_embeds is not None:
        h = inputs_embeds.astype(jnp.dtype(cfg.dtype))
        b, s, _ = h.shape
    else:
        b, s = tokens.shape
        h = embed_tokens(params, cfg, tokens)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux_total = jnp.zeros((), jnp.float32)
    for si, seg in enumerate(build_segments(cfg)):
        h, aux = _run_segment(params[f"seg{si}"], h, positions, cfg, seg,
                              remat, attn_chunk)
        aux_total = aux_total + aux
    return h, {"moe_aux_loss": aux_total}


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    inputs_embeds: Optional[jax.Array] = None,
    *,
    positions: Optional[jax.Array] = None,
    remat: str = "none",
    attn_chunk: int = 1024,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence forward -> (logits [B, S, Vp], aux)."""
    h, aux = forward_hidden(params, cfg, tokens, inputs_embeds,
                            positions=positions, remat=remat,
                            attn_chunk=attn_chunk)
    return unembed(params, cfg, h), aux


def chunked_ce(params: Params, cfg: ModelConfig, h: jax.Array,
               labels: jax.Array, ce_chunk: int = 512) -> jax.Array:
    """Cross-entropy without materializing [B, S, Vp] logits.

    The unembed + log-softmax runs per sequence-chunk under a
    ``lax.scan`` with ``jax.checkpoint``: peak logits memory drops from
    O(S x Vp) to O(ce_chunk x Vp) (e.g. 90 GiB -> 350 MiB per device on
    internlm2 train_4k), and the backward recomputes each chunk's logits
    instead of keeping them. This is a production-LM-framework standard;
    the dry-run memory analysis in EXPERIMENTS.md quantifies it.
    """
    b, s, _ = h.shape
    c = min(ce_chunk, s)
    if s % c != 0:
        c = s                                # fallback: single chunk
    n = s // c

    def chunk_nll(hc, lc):
        hc = shard_act(hc, "hidden")
        logits = unembed(params, cfg, hc)              # [B, c, Vp]
        lf = logits.astype(jnp.float32)
        m = jnp.max(lf, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
        safe = jnp.maximum(lc, 0)
        gold = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
        valid = (lc != -1).astype(jnp.float32)
        return jnp.sum((lse - gold) * valid), jnp.sum(valid)

    chunk_nll = jax.checkpoint(chunk_nll, prevent_cse=False)

    if n == 1:
        nll, cnt = chunk_nll(h, labels)
        return nll / jnp.maximum(cnt, 1.0)

    hc = jnp.moveaxis(h.reshape(b, n, c, h.shape[-1]), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)

    def body(carry, xs):
        nll, cnt = chunk_nll(*xs)
        return (carry[0] + nll, carry[1] + cnt), None

    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), (hc, lc))
    return nll / jnp.maximum(cnt, 1.0)


def lm_loss(params: Params, cfg: ModelConfig, tokens: jax.Array,
            labels: jax.Array, *, inputs_embeds: Optional[jax.Array] = None,
            remat: str = "none", attn_chunk: int = 1024,
            ce_chunk: int = 512) -> Tuple[jax.Array, Dict]:
    h, aux = forward_hidden(params, cfg, tokens, inputs_embeds, remat=remat,
                            attn_chunk=attn_chunk)
    loss = chunked_ce(params, cfg, h, labels, ce_chunk)
    total = loss + aux["moe_aux_loss"]
    return total, {"lm_loss": loss, **aux}


# ---------------------------------------------------------------------------
# KV / recurrent caches
# ---------------------------------------------------------------------------

def cache_len_for(seg: SegmentSpec, max_len: int) -> int:
    if seg.kind != BlockKind.ATTENTION:
        return 0
    return min(max_len, seg.window)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None, a3: bool = False) -> Dict[str, Any]:
    """Per-segment decode state. Attention: ring-buffer K/V sized
    min(max_len, window). Recurrent: carried states.

    ``a3=True`` additionally allocates the *sorted key matrix* for
    global-attention segments (the paper's comprehension-time
    preprocessing, kept alongside the cache exactly like the ASIC's
    40KB sorted-key SRAM next to the 20KB key SRAM) plus the
    ``sorted_upto`` watermark for the exact fresh-tail policy."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    cache: Dict[str, Any] = {}
    for si, seg in enumerate(build_segments(cfg)):
        L = seg.count
        if seg.kind == BlockKind.ATTENTION:
            w = cache_len_for(seg, max_len)
            cache[f"seg{si}"] = {
                "k": jnp.zeros((L, batch, cfg.num_kv_heads, w, hd), dtype),
                "v": jnp.zeros((L, batch, cfg.num_kv_heads, w, hd), dtype),
            }
            if a3 and seg.window >= FULL_WINDOW:
                cache[f"seg{si}"]["sk_vals"] = jnp.zeros(
                    (L, batch, cfg.num_kv_heads, w, hd), dtype)
                cache[f"seg{si}"]["sk_rows"] = jnp.zeros(
                    (L, batch, cfg.num_kv_heads, w, hd), jnp.int32)
                cache[f"seg{si}"]["sorted_upto"] = jnp.zeros(
                    (L, batch), jnp.int32)
        elif seg.kind == BlockKind.RGLRU:
            d_rnn = cfg.num_heads * hd
            cache[f"seg{si}"] = {
                "h": jnp.zeros((L, batch, d_rnn), jnp.float32),
                "conv": jnp.zeros((L, batch, CONV_WIDTH - 1, d_rnn), dtype),
            }
        elif seg.kind == BlockKind.MLSTM:
            cache[f"seg{si}"] = {
                "C": jnp.zeros((L, batch, cfg.num_heads, hd, hd), jnp.float32),
                "n": jnp.zeros((L, batch, cfg.num_heads, hd), jnp.float32),
                "m": jnp.full((L, batch, cfg.num_heads), -1e30, jnp.float32),
            }
        elif seg.kind == BlockKind.SLSTM:
            d = cfg.d_model
            z = jnp.zeros((L, batch, d), jnp.float32)
            cache[f"seg{si}"] = {
                "c": z, "n": z, "m": jnp.full((L, batch, d), -1e30,
                                              jnp.float32), "h": z,
            }
    return cache


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def _ring_slot_positions(w: int, pos: jax.Array) -> jax.Array:
    """Position held by each ring slot after writing position ``pos``.

    Slot s holds position p(s) = largest p' <= pos with p' % w == s.
    ``pos`` may be a scalar (-> [w]) or a per-batch vector [B] (-> [B, w]).
    """
    slots = jnp.arange(w, dtype=jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)[..., None]
    return pos - jnp.mod(pos - slots, w)


def _ring_valid_mask(w: int, pos: jax.Array, window: int) -> jax.Array:
    """Validity of ring slots after writing position ``pos`` at pos % w.

    Valid iff p(s) >= 0 (written) and p(s) > pos - window. ``pos`` may be
    scalar or per-batch [B] (ragged decode); the mask gains a matching
    leading batch dim.
    """
    slot_pos = _ring_slot_positions(w, pos)
    pos = jnp.asarray(pos, jnp.int32)[..., None]
    return (slot_pos >= 0) & (slot_pos > pos - window)


def _attn_decode_block(lp: Params, cache: Dict[str, jax.Array], h: jax.Array,
                       pos: jax.Array, cfg: ModelConfig, seg: SegmentSpec,
                       a3: A3Config, use_kernel: bool
                       ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b = h.shape[0]
    hd = cfg.resolved_head_dim
    hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
    positions = pos[:, None]                                   # [B, 1]
    q, k, v = attention_qkv(lp["attn"], hn, positions, cfg.num_heads,
                            cfg.num_kv_heads, hd, cfg.rope_theta)
    q = shard_act(q, "q")
    w = cache["k"].shape[2]
    # per-slot ring write: each sequence writes its own token at its own
    # ring slot (ragged continuous batching — one dispatch serves slots
    # at arbitrary position skew). Lanes with pos < 0 (idle/prefilling
    # engine slots riding along in the batch) scatter out of bounds and
    # are dropped, so mid-prefill cache rows are never clobbered.
    slot = jnp.where(pos >= 0, jnp.mod(pos, w), w)             # [B]
    bidx = jnp.arange(b, dtype=jnp.int32)
    kc = cache["k"].at[bidx, :, slot].set(k[:, :, 0], mode="drop")
    vc = cache["v"].at[bidx, :, slot].set(v[:, :, 0], mode="drop")
    kc = shard_act(kc, "kv_cache")
    vc = shard_act(vc, "kv_cache")
    valid = _ring_valid_mask(w, pos, seg.window)               # [B, w]
    # A^3 approximate decode only on global-attention layers: windowed
    # layers already bound the search (DESIGN.md SS5).
    use_a3 = a3.mode != A3Mode.OFF and seg.window >= FULL_WINDOW
    # NOTE: read-only leaves (sk_*, sorted_upto) are NOT returned — the
    # caller keeps them out of the scan ys (passing them through forced
    # a full copy of the sorted-key cache per layer iteration).
    new_slice = {"k": kc, "v": vc}
    if use_a3 and "sk_vals" in cache:
        # comprehension-time sorted keys cached at prefill (paper SSIV-C);
        # rows written since the last re-sort get exact treatment.
        from repro.core.candidate_selection import SortedKeys
        from repro.kernels.decode_attention.ops import \
            a3_decode_attention_compact
        slot_pos = _ring_slot_positions(w, pos)                 # [B, w]
        fresh = slot_pos >= cache["sorted_upto"][:, None]       # [B, w]
        sk = SortedKeys(values=shard_act(cache["sk_vals"], "kv_cache"),
                        rows=shard_act(cache["sk_rows"], "kv_cache"))
        o = a3_decode_attention_compact(
            q[:, :, 0], kc, vc, valid, a3, sk, fresh_mask=fresh)
    elif use_a3:
        from repro.core.candidate_selection import sort_key_columns
        # no cached sort available: build inline (single-shot use)
        sorted_keys = jax.vmap(jax.vmap(sort_key_columns))(kc)
        o = a3_decode_attention(q[:, :, 0], kc, vc, valid, a3,
                                sorted_keys=sorted_keys,
                                use_kernel=use_kernel)
    else:
        o = a3_decode_attention(q[:, :, 0], kc, vc, valid, A3Config(),
                                use_kernel=use_kernel)
    h = h + attention_out(lp["attn"], o[:, :, None, :])
    return h, new_slice


def _decode_block(lp: Params, cache_slice: Dict[str, jax.Array],
                  h: jax.Array, pos: jax.Array, cfg: ModelConfig,
                  seg: SegmentSpec, a3: A3Config, use_kernel: bool):
    aux = jnp.zeros((), jnp.float32)
    h = shard_act(h, "hidden")
    if seg.kind == BlockKind.ATTENTION:
        h, new_slice = _attn_decode_block(lp, cache_slice, h, pos, cfg, seg,
                                          a3, use_kernel)
    elif seg.kind == BlockKind.RGLRU:
        hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
        o, h_new, conv_new = rglru_decode_step(
            lp["rnn"], hn, cache_slice["h"], cache_slice["conv"])
        h = h + o
        new_slice = {"h": h_new, "conv": conv_new}
    elif seg.kind == BlockKind.MLSTM:
        hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
        st = (cache_slice["C"], cache_slice["n"], cache_slice["m"])
        o, (C, n, m) = xl.mlstm_decode_step(lp["mlstm"], hn, st,
                                            cfg.num_heads,
                                            cfg.resolved_head_dim)
        h = h + o
        new_slice = {"C": C, "n": n, "m": m}
    elif seg.kind == BlockKind.SLSTM:
        hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
        st = (cache_slice["c"], cache_slice["n"], cache_slice["m"],
              cache_slice["h"])
        o, (c, n, m, hh) = xl.slstm_decode_step(lp["slstm"], hn, st,
                                                cfg.num_heads)
        h = h + o
        new_slice = {"c": c, "n": n, "m": m, "h": hh}
    if seg.ffn == "dense":
        hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        h = h + ffn_apply(lp["ffn"], hn, act=cfg.act)
    elif seg.ffn == "moe":
        hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        o, moe_aux = moe_apply(lp["moe"], hn, _moe_cfg(cfg))
        h = h + o
        aux = moe_aux["moe_aux_loss"]
    return h, new_slice, aux


def decode_step(
    params: Params,
    cfg: ModelConfig,
    cache: Dict[str, Any],
    token: Optional[jax.Array] = None,          # [B] int32
    pos: jax.Array = None,                      # int32 position: scalar or [B]
    *,
    input_embed: Optional[jax.Array] = None,    # [B, D]
    a3: A3Config = A3Config(),
    use_kernel: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One autoregressive step -> (logits [B, Vp], new cache).

    ``pos`` may be a scalar (all sequences at the same position) or a
    per-sequence vector [B] (*ragged* decode): each sequence writes its
    token at its own ring slot and masks its own valid window, so a
    continuous-batching engine can advance slots at arbitrary position
    skew in a single dispatch.
    """
    if input_embed is not None:
        h = input_embed[:, None, :].astype(jnp.dtype(cfg.dtype))
    else:
        h = embed_tokens(params, cfg, token[:, None])
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (h.shape[0],))
    new_cache: Dict[str, Any] = {}
    _RO = ("sk_vals", "sk_rows", "sorted_upto")
    for si, seg in enumerate(build_segments(cfg)):
        seg_cache = cache[f"seg{si}"]
        ro = {k: v for k, v in seg_cache.items() if k in _RO}
        mut = {k: v for k, v in seg_cache.items() if k not in _RO}

        def body(carry, xs):
            lp, cs, ro_s = xs
            out, ns, aux = _decode_block(lp, {**cs, **ro_s}, carry, pos,
                                         cfg, seg, a3, use_kernel)
            return out, ns

        h, new_seg = jax.lax.scan(body, h, (params[f"seg{si}"], mut, ro))
        new_cache[f"seg{si}"] = {**new_seg, **ro}
    logits = unembed(params, cfg, h)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# multi-step scanned decode: T steps per dispatch, sampling in-graph
# ---------------------------------------------------------------------------

def resort_sorted_keys(cache: Dict[str, Any], pos: jax.Array,
                       resort_every: int) -> Dict[str, Any]:
    """In-graph A^3 re-sort: fold each lane's ring into its sorted key
    columns when the exact tail outgrew ``resort_every``.

    The serving-time analogue of the paper's comprehension-time
    preprocessing (SSIV-C), previously scheduled by a host-side read of
    the ``sorted_upto`` watermarks every tick. Here the watermark check
    is part of the dispatch: for each global-attention segment a lane is
    *due* when ``pos - sorted_upto >= resort_every``; a ``lax.cond``
    skips the O(w log w) sort entirely on steps where no lane is due,
    and due lanes select the fresh sort via ``jnp.where`` (others keep
    their matrices and watermark bit-identically). Lanes riding along at
    ``pos < 0`` are never due.

    ``pos`` is the per-lane position about to be written — the sort runs
    *before* the step's ring write, so it sees exactly the ring the
    host-side re-sort used to see between dispatches.
    """
    from repro.core.candidate_selection import sort_key_columns
    new_cache: Dict[str, Any] = {}
    pos = jnp.asarray(pos, jnp.int32)
    for name, sc in cache.items():
        if not isinstance(sc, dict) or "sk_vals" not in sc:
            new_cache[name] = sc
            continue
        due = (pos >= 0) & (pos - sc["sorted_upto"][0] >= resort_every)

        def _fold(op, due=due):
            k, skv, skr, upto = op
            sk = jax.vmap(jax.vmap(jax.vmap(sort_key_columns)))(k)
            d5 = due[None, :, None, None, None]
            return (jnp.where(d5, sk.values, skv),
                    jnp.where(d5, sk.rows, skr),
                    jnp.where(due[None, :], pos[None, :], upto))

        def _keep(op):
            _, skv, skr, upto = op
            return skv, skr, upto

        skv, skr, upto = jax.lax.cond(
            jnp.any(due), _fold, _keep,
            (sc["k"], sc["sk_vals"], sc["sk_rows"], sc["sorted_upto"]))
        new_cache[name] = {**sc, "sk_vals": skv, "sk_rows": skr,
                           "sorted_upto": upto}
    return new_cache


def sample_logits(logits: jax.Array, *, temperature: float = 0.0,
                  rng: Optional[jax.Array] = None,
                  pos: Optional[jax.Array] = None,
                  ids: Optional[jax.Array] = None) -> jax.Array:
    """In-graph next-token sampling -> token ids [B].

    ``temperature == 0`` (or no ``rng``) is greedy argmax — identical to
    the host-side ``argmax`` the engine used to run after a device
    round-trip. With ``temperature > 0`` each lane draws from the
    tempered softmax with a key folded from (``ids``, ``pos``): the
    per-lane request id decorrelates concurrent and successive requests
    (identical prompts do not share a key stream), while folding the
    absolute position — not the step index — keeps a lane's draw at
    position p independent of how decode steps are blocked into
    dispatches or which engine slot the request occupies.
    """
    if temperature <= 0.0 or rng is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if pos is None:
        pos = jnp.zeros((logits.shape[0],), jnp.int32)
    if ids is None:
        ids = jnp.zeros((logits.shape[0],), jnp.int32)
    keys = jax.vmap(lambda u, p: jax.random.fold_in(
        jax.random.fold_in(rng, u), p))(ids, pos)
    draw = lambda k, lg: jax.random.categorical(
        k, lg.astype(jnp.float32) / temperature)
    return jax.vmap(draw)(keys, logits).astype(jnp.int32)


def decode_block(
    params: Params,
    cfg: ModelConfig,
    cache: Dict[str, Any],
    token: jax.Array,                 # [B] int32 last emitted token per lane
    pos: jax.Array,                   # [B] int32 next position; -1 = ride-along
    steps_left: jax.Array,            # [B] int32 steps this lane may advance
    *,
    steps: int,
    a3: A3Config = A3Config(),
    use_kernel: bool = False,
    resort_every: int = 0,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
    sample_ids: Optional[jax.Array] = None,   # [B] per-request sample keys
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run ``steps`` autoregressive decode steps in ONE dispatch via
    ``lax.scan`` -> (token ring [B, steps] int32, new cache).

    The whole inner loop is device-resident: each scan step (a) re-sorts
    due lanes' A^3 key columns in-graph (:func:`resort_sorted_keys` —
    no host watermark read), (b) runs :func:`decode_step`, and (c)
    samples the next token in-graph (:func:`sample_logits`), feeding it
    to the following step. The host syncs once per block to harvest the
    emitted-token ring instead of once (or three times) per token.

    Lanes are masked per step: a lane is *active* while ``pos >= 0`` and
    its ``steps_left`` budget is unspent. Inactive lanes ride along at
    ``pos = -1`` — their ring writes scatter out of bounds and are
    dropped (the ragged-decode machinery), their ring entries read -1,
    and their carried token/pos freeze — so lanes that exhaust budget or
    hit ``max_len`` mid-block leave attention (ring) cache rows
    untouched. Recurrent segments (RG-LRU / xLSTM) carry no per-step
    masking, matching :func:`decode_step`'s existing ``pos = -1``
    semantics: a masked lane's recurrent state keeps advancing on its
    frozen token and must be rewritten at the next admission (the
    engine's whole-prompt prefill does exactly that) before the lane is
    trusted again. With ``steps=1`` this is exactly one
    :func:`decode_step` plus in-graph sampling.
    """
    b = token.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    steps_left = jnp.broadcast_to(jnp.asarray(steps_left, jnp.int32), (b,))
    do_resort = resort_every > 0 and a3.mode != A3Mode.OFF

    def one_step(carry, _):
        token, pos, remaining, cache = carry
        active = (pos >= 0) & (remaining > 0)
        eff_pos = jnp.where(active, pos, -1)
        if do_resort:
            cache = resort_sorted_keys(cache, eff_pos, resort_every)
        logits, cache = decode_step(params, cfg, cache, token, eff_pos,
                                    a3=a3, use_kernel=use_kernel)
        nxt = sample_logits(logits, temperature=temperature, rng=rng,
                            pos=eff_pos, ids=sample_ids)
        emit = jnp.where(active, nxt, -1)
        token = jnp.where(active, nxt, token)
        pos = jnp.where(active, pos + 1, pos)
        remaining = jnp.where(active, remaining - 1, remaining)
        return (token, pos, remaining, cache), emit

    (_, _, _, cache), ring = jax.lax.scan(
        one_step, (token.astype(jnp.int32), pos, steps_left, cache),
        None, length=steps)
    return jnp.moveaxis(ring, 0, 1), cache


# ---------------------------------------------------------------------------
# prefill: full-sequence forward that also fills the decode caches
# ---------------------------------------------------------------------------

def prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    inputs_embeds: Optional[jax.Array] = None,
    *,
    max_len: Optional[int] = None,
    attn_chunk: int = 1024,
    a3: bool = False,
    select_shards: int = 1,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Process a prompt, return (last-token logits [B, Vp], filled cache).
    ``a3=True`` also builds the sorted-key matrices for global-attention
    segments (comprehension-time preprocessing, paper SSIV-C).

    Only the final position's logits are computed (serving needs just
    the next-token distribution; a full [B, S, Vp] logits tensor at 32k
    prompt x 262k vocab would be ~0.5 TB)."""
    if inputs_embeds is not None:
        h = inputs_embeds.astype(jnp.dtype(cfg.dtype))
        b, s, _ = h.shape
    else:
        b, s = tokens.shape
        h = embed_tokens(params, cfg, tokens)
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    hd = cfg.resolved_head_dim
    cache: Dict[str, Any] = {}

    for si, seg in enumerate(build_segments(cfg)):
        if seg.kind == BlockKind.ATTENTION:
            w = cache_len_for(seg, max_len)

            def body(carry, lp, seg=seg, w=w):
                hh = shard_act(carry, "hidden")
                hn = rmsnorm(lp["ln1"], hh, cfg.norm_eps)
                q, k, v = attention_qkv(lp["attn"], hn, positions,
                                        cfg.num_heads, cfg.num_kv_heads, hd,
                                        cfg.rope_theta)
                q = shard_act(q, "q")
                k = shard_act(k, "kv")
                v = shard_act(v, "kv")
                window = (None if seg.window >= FULL_WINDOW
                          else jnp.int32(seg.window))
                o = attention_xla_flash(q, k, v, causal=True, window=window,
                                        chunk=attn_chunk)
                hh = hh + attention_out(lp["attn"], o)
                # ring-write the last min(s, w) positions
                kc = jnp.zeros((k.shape[0], k.shape[1], w, hd), k.dtype)
                vc = jnp.zeros_like(kc)
                take = min(s, w)
                # slots of positions s-take .. s-1
                pos_tail = jnp.arange(s - take, s, dtype=jnp.int32)
                slots = jnp.mod(pos_tail, w)
                kc = kc.at[:, :, slots].set(k[:, :, s - take:])
                vc = vc.at[:, :, slots].set(v[:, :, s - take:])
                extra = {}
                if a3 and seg.window >= FULL_WINDOW:
                    from repro.core.candidate_selection import \
                        sort_key_columns
                    ns = select_shards if w % max(select_shards, 1) == 0 \
                        else 1
                    kb = kc.reshape(kc.shape[0], kc.shape[1], ns, w // ns,
                                    hd)
                    sk = jax.vmap(jax.vmap(jax.vmap(sort_key_columns)))(kb)
                    extra = {
                        "sk_vals": sk.values.reshape(kc.shape),
                        "sk_rows": sk.rows.reshape(kc.shape),  # block-local
                        "sorted_upto": jnp.full((kc.shape[0],), s,
                                                jnp.int32),
                    }
                if seg.ffn == "dense":
                    hn = rmsnorm(lp["ln2"], hh, cfg.norm_eps)
                    hh = hh + ffn_apply(lp["ffn"], hn, act=cfg.act)
                elif seg.ffn == "moe":
                    hn = rmsnorm(lp["ln2"], hh, cfg.norm_eps)
                    oo, _ = moe_apply(lp["moe"], hn, _moe_cfg(cfg))
                    hh = hh + oo
                return hh, {"k": kc, "v": vc, **extra}

            h, seg_cache = jax.lax.scan(body, h, params[f"seg{si}"])
            cache[f"seg{si}"] = seg_cache
        else:
            def body(carry, lp, seg=seg):
                hh = shard_act(carry, "hidden")
                hn = rmsnorm(lp["ln1"], hh, cfg.norm_eps)
                if seg.kind == BlockKind.RGLRU:
                    o, h_last, conv = rglru_apply_scan(lp["rnn"], hn)
                    ns = {"h": h_last, "conv": conv}
                elif seg.kind == BlockKind.MLSTM:
                    # need final state: rerun chunkwise scan capturing state
                    o, st = _mlstm_with_state(lp["mlstm"], hn, cfg)
                    ns = {"C": st[0], "n": st[1], "m": st[2]}
                else:
                    o, st = xl.slstm_apply_scan(lp["slstm"], hn,
                                                cfg.num_heads)
                    ns = {"c": st[0], "n": st[1], "m": st[2], "h": st[3]}
                hh = hh + o
                if seg.ffn == "dense":
                    hn = rmsnorm(lp["ln2"], hh, cfg.norm_eps)
                    hh = hh + ffn_apply(lp["ffn"], hn, act=cfg.act)
                return hh, ns

            h, seg_cache = jax.lax.scan(body, h, params[f"seg{si}"])
            cache[f"seg{si}"] = seg_cache

    logits = unembed(params, cfg, h[:, -1:])[:, 0]
    return logits, cache


# ---------------------------------------------------------------------------
# chunked / ragged admission prefill: extend per-slot caches in place
# ---------------------------------------------------------------------------

def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Chunked prefill extends KV ring buffers from an arbitrary start
    position; recurrent blocks would need carried mid-prompt state, which
    the chunked path does not implement — those archs admit via the
    whole-prompt :func:`prefill`."""
    return all(seg.kind == BlockKind.ATTENTION for seg in build_segments(cfg))


def _attn_prefill_chunk_block(
    lp: Params,
    cache: Dict[str, jax.Array],      # per-layer slices: k/v [B, Hkv, w, D]
    h: jax.Array,                     # [B, C, D]
    positions: jax.Array,             # [B, C] absolute positions
    valid_tok: jax.Array,             # [B, C] chunk-slot validity
    pos: jax.Array,                   # [B] chunk start position
    length: jax.Array,                # [B] valid tokens (0 = untouched lane)
    sort_lanes: jax.Array,            # [B] fold this chunk into the A3 sort
    cfg: ModelConfig,
    seg: SegmentSpec,
    use_a3: bool,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, c, _ = h.shape
    hd = cfg.resolved_head_dim
    hkv, group = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
    q, k, v = attention_qkv(lp["attn"], hn, positions, cfg.num_heads,
                            hkv, hd, cfg.rope_theta)           # [B, H, C, D]
    q = shard_act(q, "q")
    k = shard_act(k, "kv")
    v = shard_act(v, "kv")
    ck, cv = cache["k"], cache["v"]
    # A lane starting a new prompt (pos 0) zeroes its ring rows inside
    # the donated dispatch — the slot may hold a finished request's rows,
    # and whole-prompt-parity (incl. the A3 sort over the full ring)
    # needs unwritten rows to read as zeros. Fused here, this costs no
    # extra HBM sweep, unlike a host-side reset copy per admission.
    fresh = ((pos == 0) & (length > 0))[:, None, None, None]
    zero = jnp.asarray(0, ck.dtype)
    ck = jnp.where(fresh, zero, ck)
    cv = jnp.where(fresh, zero, cv)
    w = ck.shape[2]
    window = seg.window

    # Attention BEFORE the ring write: chunk queries see (a) the ring as
    # it stood before this chunk and (b) in-chunk keys, so a wrapping
    # write can never clobber a position an earlier query still needs.
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(b, hkv, group, c, hd)
    offs = jnp.arange(c, dtype=jnp.int32)
    slots = jnp.arange(w, dtype=jnp.int32)
    last_prev = pos - 1                                        # [B]
    slot_pos = last_prev[:, None] - jnp.mod(
        last_prev[:, None] - slots[None, :], w)                # [B, w]
    ring_mask = (slot_pos[:, None, :] >= 0) & \
        (slot_pos[:, None, :] > positions[:, :, None] - window)  # [B, C, w]
    chunk_mask = (offs[None, :, None] >= offs[None, None, :]) & \
        (offs[None, :, None] - offs[None, None, :] < window) & \
        valid_tok[:, None, :]                                  # [B, C, C]
    mask = jnp.concatenate([ring_mask, chunk_mask], -1)        # [B, C, w+C]

    s_ring = jnp.einsum("bhgqd,bhkd->bhgqk", qf,
                        ck.astype(jnp.float32))                # [B,Hkv,G,C,w]
    s_chunk = jnp.einsum("bhgqd,bhkd->bhgqk", qf,
                         k.astype(jnp.float32))                # [B,Hkv,G,C,C]
    s = jnp.concatenate([s_ring, s_chunk], -1)
    mb = mask[:, None, None]
    s = jnp.where(mb, s, -1e30)
    m = jnp.max(s, -1, keepdims=True)
    p = jnp.where(mb, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, -1, keepdims=True)
    vcat = jnp.concatenate([cv, v], 2).astype(jnp.float32)     # [B,Hkv,w+C,D]
    acc = jnp.einsum("bhgqk,bhkd->bhgqd", p, vcat)
    o = jnp.where(l == 0.0, 0.0, acc / jnp.where(l == 0.0, 1.0, l))
    o = o.reshape(b, cfg.num_heads, c, hd).astype(h.dtype)
    h = h + attention_out(lp["attn"], o)

    # Ragged ring write: pad slots and inactive lanes scatter to index w
    # (out of bounds -> dropped), leaving other slots' rows untouched.
    # When the chunk exceeds the ring (sliding windows) only the last w
    # chunk positions land, as in whole-prompt prefill.
    writable = valid_tok & (positions > (pos + length - 1)[:, None] - w)
    tgt = jnp.where(writable, jnp.mod(positions, w), w)        # [B, C]
    b2 = jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32)[:, None], (b, c))
    kc = ck.at[b2, :, tgt].set(jnp.swapaxes(k, 1, 2), mode="drop")
    vc = cv.at[b2, :, tgt].set(jnp.swapaxes(v, 1, 2), mode="drop")
    new_slice = {"k": kc, "v": vc}

    if use_a3 and "sk_vals" in cache:
        # incremental comprehension-time preprocessing: fold the chunk's
        # keys into the per-column sort for lanes in ``sort_lanes``
        # (whole-ring sort; other lanes keep their sorted state +
        # watermark). The engine only sets sort_lanes on a prompt's
        # final chunk — nothing reads a PREFILLING slot's sort — so the
        # O(w log w) sort runs once per admitted prompt, as in
        # whole-prompt prefill; lax.cond skips it entirely on ticks
        # where no lane finishes.
        from repro.core.candidate_selection import sort_key_columns

        def _fold(_):
            sk = jax.vmap(jax.vmap(sort_key_columns))(kc)
            l4 = sort_lanes[:, None, None, None]
            return (jnp.where(l4, sk.values, cache["sk_vals"]),
                    jnp.where(l4, sk.rows, cache["sk_rows"]),
                    jnp.where(sort_lanes, pos + length,
                              cache["sorted_upto"]))

        def _keep(_):
            return (cache["sk_vals"], cache["sk_rows"],
                    cache["sorted_upto"])

        sk_vals, sk_rows, upto = jax.lax.cond(jnp.any(sort_lanes),
                                              _fold, _keep, None)
        new_slice["sk_vals"] = sk_vals
        new_slice["sk_rows"] = sk_rows
        new_slice["sorted_upto"] = upto
    if seg.ffn == "dense":
        hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        h = h + ffn_apply(lp["ffn"], hn, act=cfg.act)
    elif seg.ffn == "moe":
        hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        o2, _ = moe_apply(lp["moe"], hn, _moe_cfg(cfg))
        h = h + o2
    return h, new_slice


def prefill_chunk(
    params: Params,
    cfg: ModelConfig,
    cache: Dict[str, Any],
    tokens: jax.Array,                # [B, C] int32 (ragged, zero-padded)
    pos: jax.Array,                   # [B] int32 per-slot chunk start
    length: jax.Array,                # [B] int32 valid tokens; 0 = skip lane
    *,
    a3: bool = False,
    sort_lanes: Optional[jax.Array] = None,   # [B] bool; default: length > 0
    update_sort: bool = True,                 # static: False = sk leaves RO
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Extend per-slot decode caches with one ragged batch of prompt chunks.

    Every lane processes ``length[b]`` tokens of its prompt starting at
    absolute position ``pos[b]`` — a single dispatch serves slots at
    arbitrary prompt cursors (ragged admission prefill). Lanes with
    ``length == 0`` are passed through untouched (their cache rows are
    bit-identical on output), so decoding slots can share the dispatch
    batch with prefilling ones. A lane at ``pos == 0`` first zeroes its
    ring rows (a reused slot may hold a finished request's keys).

    With ``a3=True``, lanes in ``sort_lanes`` fold the updated ring into
    the per-column sorted-key matrices and advance ``sorted_upto`` to
    ``pos + length``. The engine passes only lanes on their *final*
    chunk (one sort per admitted prompt); the default sorts every
    active lane's chunk, which is correct but does the sort work
    per-chunk instead of per-prompt. ``update_sort=False`` (a *static*
    flag — a separate jit specialization) additionally keeps the sorted
    leaves out of the layer scan entirely, so non-final chunk ticks do
    not pay a per-layer copy of the sorted-key cache (the same
    read-only-leaf treatment ``decode_step`` applies).

    Chunking is output-invariant: a query's attention set (positions
    ``<= q``, within the segment window) does not depend on where chunk
    boundaries fall, so running a prompt through any chunk split yields
    the same cache rows and logits as :func:`prefill` up to fp
    summation order. With ``a3=True`` the chunk's keys are folded into
    the per-column sorted-key matrices (incremental comprehension-time
    preprocessing) and ``sorted_upto`` advances to ``pos + length``.

    Returns (logits [B, Vp] at each lane's last valid position, cache).
    """
    if not supports_chunked_prefill(cfg):
        raise NotImplementedError(
            f"chunked prefill requires attention-only segments; "
            f"{cfg.name} has recurrent blocks — use prefill()")
    b, c = tokens.shape
    h = embed_tokens(params, cfg, tokens)
    pos = jnp.asarray(pos, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    if sort_lanes is None:
        sort_lanes = length > 0
    sort_lanes = jnp.asarray(sort_lanes, bool)
    offs = jnp.arange(c, dtype=jnp.int32)
    positions = pos[:, None] + offs[None, :]               # [B, C]
    valid_tok = offs[None, :] < length[:, None]            # [B, C]
    new_cache: Dict[str, Any] = {}
    _RO = ("sk_vals", "sk_rows", "sorted_upto")
    for si, seg in enumerate(build_segments(cfg)):
        seg_cache = cache[f"seg{si}"]
        ro = {} if update_sort else \
            {k: v for k, v in seg_cache.items() if k in _RO}
        mut = seg_cache if update_sort else \
            {k: v for k, v in seg_cache.items() if k not in _RO}

        def body(carry, xs, seg=seg):
            lp, cs = xs
            out, ns = _attn_prefill_chunk_block(
                lp, cs, carry, positions, valid_tok, pos, length,
                sort_lanes, cfg, seg, a3)
            return out, ns

        h, new_seg = jax.lax.scan(body, h, (params[f"seg{si}"], mut))
        new_cache[f"seg{si}"] = {**new_seg, **ro}
    bidx = jnp.arange(b, dtype=jnp.int32)
    last = jnp.clip(length - 1, 0, c - 1)
    logits = unembed(params, cfg, h[bidx, last][:, None])[:, 0]
    return logits, new_cache


def _mlstm_with_state(p: Params, x: jax.Array, cfg: ModelConfig):
    """mLSTM forward that also returns the end-of-sequence state by
    replaying the per-step recurrence on top of the parallel output."""
    out = xl.mlstm_parallel(p, x, cfg.num_heads, cfg.resolved_head_dim)
    # state via chunked recurrence (cheap: states only, no outputs)
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    k = ((x @ p["wk"]).reshape(b, s, cfg.num_heads, hd)
         .astype(jnp.float32)) / math.sqrt(hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.num_heads, hd).astype(jnp.float32)
    log_i, log_f = xl._mlstm_gates(p, x)
    F = jnp.cumsum(jnp.moveaxis(log_f, 2, 1), axis=-1)        # [B,H,S]
    li = jnp.moveaxis(log_i, 2, 1)
    Ftot = F[..., -1]
    wr_log = Ftot[..., None] - F + li
    m_new = jnp.maximum(jnp.max(wr_log, axis=-1), -1e30)
    wr = jnp.exp(wr_log - m_new[..., None])                   # [B,H,S]
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)
    C = jnp.einsum("bhu,bhuk,bhuv->bhkv", wr, kh, vh)
    n = jnp.einsum("bhu,bhuk->bhk", wr, kh)
    return out, (C, n, m_new)
