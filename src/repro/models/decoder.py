"""Unified decoder stack covering all assigned architectures.

A model is a sequence of *segments*: maximal runs of layers sharing the
same (block kind, ffn kind, attention window) signature. Each segment's
layer parameters are stacked on a leading ``layers`` axis and executed
with ``lax.scan`` (compact HLO for the 512-device dry-run; remat applies
per layer). Examples:

  phi4-mini        -> 1 segment  (attention + dense FFN, full window)
  deepseek-moe     -> 2 segments (1 dense-FFN layer, 27 MoE layers)
  gemma3           -> 12 segments (5 local / 1 global alternating)
  recurrentgemma   -> 17 segments (rglru pairs / attention, 1:2)
  xlstm            -> alternating mLSTM / sLSTM segments

Every segment kind implements the per-segment **mixer-state interface**
(:mod:`repro.models.mixer`): ``init_state / forward / prefill_full /
prefill_chunk / decode_step``. The four execution paths here — train
forward, whole-prompt prefill, chunked ragged admission prefill, and
ragged decode — are each ONE kind-agnostic loop over segments; per-kind
behavior (KV ring buffers + A^3 sorted columns, conv tail + LRU hidden
state, mLSTM matrix memory, sLSTM cell state) lives entirely behind the
mixer registry, with uniform ragged pad-lane masking. Chunked admission
therefore covers every architecture, including recurrent/hybrid stacks
(the mid-prompt recurrent carry is part of each mixer's
``prefill_chunk``).

KV caches are **ring buffers** sized ``min(max_len, window)`` per
segment — sliding-window layers at 500k context keep an O(window) cache,
which is what makes ``long_500k`` runnable for SWA/hybrid archs.

Approximation (the paper's technique) is applied at inference only
(paper SSVI-B); ``decode_step`` takes an ``A3Config`` and routes windowless
attention layers through ``a3_decode_attention``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import A3Config, A3Mode, BlockKind, ModelConfig
from repro.models import xlstm as xl
from repro.models.common import (
    Params,
    shard_act,
    attention_init,
    cross_entropy_loss,
    dense_init,
    embed_init,
    ffn_apply,
    ffn_init,
    rmsnorm,
    rmsnorm_init,
    softcap,
)
# FULL_WINDOW and cache_len_for are re-exported: they are decoder's
# long-standing public cache-geometry API (ring sizing), now owned by
# the mixer module alongside the segment machinery.
from repro.models.mixer import (  # noqa: F401
    FULL_WINDOW,
    MIXERS,
    SegmentSpec,
    build_segments,
    cache_len_for,
)
from repro.models.moe import moe_apply, moe_init
from repro.models.rglru import rglru_init


def padded_vocab(v: int) -> int:
    """Pad vocab to a multiple of 128 (MXU lane + mesh divisibility)."""
    return ((v + 127) // 128) * 128


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, seg: SegmentSpec) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": rmsnorm_init(d, dtype)}
    if seg.kind == BlockKind.ATTENTION:
        p["attn"] = attention_init(ks[0], d, cfg.num_heads, cfg.num_kv_heads,
                                   hd, dtype)
    elif seg.kind == BlockKind.RGLRU:
        p["rnn"] = rglru_init(ks[0], d, cfg.num_heads * hd, dtype)
    elif seg.kind == BlockKind.MLSTM:
        p["mlstm"] = xl.mlstm_init(ks[0], d, cfg.num_heads, hd, dtype)
    elif seg.kind == BlockKind.SLSTM:
        p["slstm"] = xl.slstm_init(ks[0], d, cfg.num_heads, dtype)
    if seg.ffn != "none":
        p["ln2"] = rmsnorm_init(d, dtype)
    if seg.ffn == "dense":
        p["ffn"] = ffn_init(ks[1], d, cfg.d_ff, dtype, act=cfg.act)
    elif seg.ffn == "moe":
        moe_cfg = cfg.moe
        if (moe_cfg.d_expert or 0) == 0:
            moe_cfg = dataclasses.replace(moe_cfg, d_expert=cfg.d_ff)
        p["moe"] = moe_init(ks[1], d, moe_cfg, dtype)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    vp = padded_vocab(cfg.vocab_size)
    segs = build_segments(cfg)
    n_keys = 2 + len(segs)
    keys = jax.random.split(key, n_keys)
    params: Params = {
        "embed": embed_init(keys[0], vp, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, vp, dtype)
    for si, seg in enumerate(segs):
        lkeys = jax.random.split(keys[2 + si], seg.count)
        stacked = jax.vmap(lambda k: _layer_init(k, cfg, seg))(lkeys)
        params[f"seg{si}"] = stacked
    return params


def init_params_shape(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct pytree of the params (no allocation; dry-run)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _moe_cfg(cfg: ModelConfig):
    m = cfg.moe
    if m is not None and (m.d_expert or 0) == 0:
        m = dataclasses.replace(m, d_expert=cfg.d_ff)
    return m


def _ffn_block(lp: Params, h: jax.Array, cfg: ModelConfig,
               seg: SegmentSpec) -> Tuple[jax.Array, jax.Array]:
    """Kind-independent FFN half of a block. Returns (h, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if seg.ffn == "dense":
        hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        h = h + ffn_apply(lp["ffn"], hn, act=cfg.act)
    elif seg.ffn == "moe":
        hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        o, moe_aux = moe_apply(lp["moe"], hn, _moe_cfg(cfg))
        h = h + o
        aux = aux + moe_aux["moe_aux_loss"]
    return h, aux


def _block_forward(lp: Params, h: jax.Array, positions: jax.Array,
                   cfg: ModelConfig, seg: SegmentSpec,
                   attn_chunk: int) -> Tuple[jax.Array, jax.Array]:
    """One layer forward (full sequence). Returns (h, moe_aux_loss)."""
    h = shard_act(h, "hidden")
    hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
    h = h + MIXERS[seg.kind].forward(lp, hn, cfg=cfg, seg=seg,
                                     positions=positions,
                                     attn_chunk=attn_chunk)
    return _ffn_block(lp, h, cfg, seg)


def _run_segment(params_seg: Params, h: jax.Array, positions: jax.Array,
                 cfg: ModelConfig, seg: SegmentSpec, remat: str,
                 attn_chunk: int) -> Tuple[jax.Array, jax.Array]:
    def body(carry, lp):
        out, aux = _block_forward(lp, carry, positions, cfg, seg, attn_chunk)
        return out, aux

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    h, auxs = jax.lax.scan(body, h, params_seg)
    return h, jnp.sum(auxs)


def embed_tokens(params: Params, cfg: ModelConfig, tokens: jax.Array
                 ) -> jax.Array:
    h = params["embed"][tokens]
    return h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)


def unembed(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    logits = softcap(logits, cfg.logit_softcap)
    vp = logits.shape[-1]
    if vp != cfg.vocab_size:       # mask the vocab-padding columns
        pad_mask = jnp.arange(vp) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype),
                           logits)
    return logits


def forward_hidden(
    params: Params,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,        # [B, S] int32
    inputs_embeds: Optional[jax.Array] = None,  # [B, S, D] (frontend stubs)
    *,
    positions: Optional[jax.Array] = None,
    remat: str = "none",
    attn_chunk: int = 1024,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence forward up to (not including) the unembed.
    Returns (hidden [B, S, D], aux)."""
    if inputs_embeds is not None:
        h = inputs_embeds.astype(jnp.dtype(cfg.dtype))
        b, s, _ = h.shape
    else:
        b, s = tokens.shape
        h = embed_tokens(params, cfg, tokens)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux_total = jnp.zeros((), jnp.float32)
    for si, seg in enumerate(build_segments(cfg)):
        h, aux = _run_segment(params[f"seg{si}"], h, positions, cfg, seg,
                              remat, attn_chunk)
        aux_total = aux_total + aux
    return h, {"moe_aux_loss": aux_total}


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    inputs_embeds: Optional[jax.Array] = None,
    *,
    positions: Optional[jax.Array] = None,
    remat: str = "none",
    attn_chunk: int = 1024,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence forward -> (logits [B, S, Vp], aux)."""
    h, aux = forward_hidden(params, cfg, tokens, inputs_embeds,
                            positions=positions, remat=remat,
                            attn_chunk=attn_chunk)
    return unembed(params, cfg, h), aux


def chunked_ce(params: Params, cfg: ModelConfig, h: jax.Array,
               labels: jax.Array, ce_chunk: int = 512) -> jax.Array:
    """Cross-entropy without materializing [B, S, Vp] logits.

    The unembed + log-softmax runs per sequence-chunk under a
    ``lax.scan`` with ``jax.checkpoint``: peak logits memory drops from
    O(S x Vp) to O(ce_chunk x Vp) (e.g. 90 GiB -> 350 MiB per device on
    internlm2 train_4k), and the backward recomputes each chunk's logits
    instead of keeping them. This is a production-LM-framework standard;
    the dry-run memory analysis in EXPERIMENTS.md quantifies it.
    """
    b, s, _ = h.shape
    c = min(ce_chunk, s)
    if s % c != 0:
        c = s                                # fallback: single chunk
    n = s // c

    def chunk_nll(hc, lc):
        hc = shard_act(hc, "hidden")
        logits = unembed(params, cfg, hc)              # [B, c, Vp]
        lf = logits.astype(jnp.float32)
        m = jnp.max(lf, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
        safe = jnp.maximum(lc, 0)
        gold = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
        valid = (lc != -1).astype(jnp.float32)
        return jnp.sum((lse - gold) * valid), jnp.sum(valid)

    chunk_nll = jax.checkpoint(chunk_nll, prevent_cse=False)

    if n == 1:
        nll, cnt = chunk_nll(h, labels)
        return nll / jnp.maximum(cnt, 1.0)

    hc = jnp.moveaxis(h.reshape(b, n, c, h.shape[-1]), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)

    def body(carry, xs):
        nll, cnt = chunk_nll(*xs)
        return (carry[0] + nll, carry[1] + cnt), None

    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), (hc, lc))
    return nll / jnp.maximum(cnt, 1.0)


def lm_loss(params: Params, cfg: ModelConfig, tokens: jax.Array,
            labels: jax.Array, *, inputs_embeds: Optional[jax.Array] = None,
            remat: str = "none", attn_chunk: int = 1024,
            ce_chunk: int = 512) -> Tuple[jax.Array, Dict]:
    h, aux = forward_hidden(params, cfg, tokens, inputs_embeds, remat=remat,
                            attn_chunk=attn_chunk)
    loss = chunked_ce(params, cfg, h, labels, ce_chunk)
    total = loss + aux["moe_aux_loss"]
    return total, {"lm_loss": loss, **aux}


# ---------------------------------------------------------------------------
# KV / recurrent caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None, a3: bool = False) -> Dict[str, Any]:
    """Per-segment decode state via the mixer interface. Attention:
    ring-buffer K/V sized min(max_len, window). Recurrent: carried
    states.

    ``a3=True`` additionally allocates the *sorted key matrix* for
    global-attention segments (the paper's comprehension-time
    preprocessing, kept alongside the cache exactly like the ASIC's
    40KB sorted-key SRAM next to the 20KB key SRAM) plus the
    ``sorted_upto`` watermark for the exact fresh-tail policy."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    return {f"seg{si}": MIXERS[seg.kind].init_state(cfg, seg, batch,
                                                    max_len, dtype, a3)
            for si, seg in enumerate(build_segments(cfg))}


def init_page_pool(cfg: ModelConfig, pages: int, page_size: int,
                   dtype=None, a3: bool = False,
                   kv_quant: str = "none") -> Dict[str, Any]:
    """Paged prefix-cache pool: the page-axis view of the decode cache.

    Where :func:`init_cache` allocates per-*slot* state (a [L, B, ...]
    leaf per segment), this allocates the per-*page* store the serving
    prefix cache (:mod:`repro.serve.prefix_cache`) copies admitted
    prompts into: a logical page spans ``page_size`` token positions
    across every segment at once, so one page id indexes each attention
    segment's [L, pages, Hkv, page_size, hd] K/V arrays. Segments whose
    per-token state is a fixed-size carry (recurrent kinds) contribute
    no pool arrays — their state is snapshotted at page boundaries by
    the trie, not paged. ``a3`` is accepted for signature symmetry with
    ``init_cache``; sorted-key state is a whole-ring property restored
    at gather time, never paged. With ``kv_quant="int8"`` attention
    pool pages are stored as int8 with per-page fp32 scale leaves
    (``k_scale``/``v_scale``, [L, pages, Hkv, 1, 1]); the gather hook
    dequantizes back to the slot-cache dtype inside the one-dispatch
    warm gather."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    pool: Dict[str, Any] = {}
    for si, seg in enumerate(build_segments(cfg)):
        seg_pages = MIXERS[seg.kind].init_pages(cfg, seg, pages,
                                                page_size, dtype, a3,
                                                kv_quant=kv_quant)
        if seg_pages is not None:
            pool[f"seg{si}"] = seg_pages
    return pool


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def _decode_block(lp: Params, cache_slice: Dict[str, jax.Array],
                  h: jax.Array, pos: jax.Array, cfg: ModelConfig,
                  seg: SegmentSpec, a3: A3Config, use_kernel: bool,
                  probe: bool = False):
    h = shard_act(h, "hidden")
    hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
    o, new_slice = MIXERS[seg.kind].decode_step(
        lp, cache_slice, hn, cfg=cfg, seg=seg, pos=pos, a3=a3,
        use_kernel=use_kernel, probe=probe)
    h = h + o
    h, aux = _ffn_block(lp, h, cfg, seg)
    return h, new_slice, aux


def decode_step(
    params: Params,
    cfg: ModelConfig,
    cache: Dict[str, Any],
    token: Optional[jax.Array] = None,          # [B] int32
    pos: jax.Array = None,                      # int32 position: scalar or [B]
    *,
    input_embed: Optional[jax.Array] = None,    # [B, D]
    a3: A3Config = A3Config(),
    use_kernel: bool = False,
    probe: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One autoregressive step -> (logits [B, Vp], new cache).

    ``pos`` may be a scalar (all sequences at the same position) or a
    per-sequence vector [B] (*ragged* decode): each sequence writes its
    token at its own ring slot and masks its own valid window, so a
    continuous-batching engine can advance slots at arbitrary position
    skew in a single dispatch.

    ``probe=True`` (A^3 global-attention segments only) additionally
    returns ``(logits, cache, (probe_sum [B, 2], n_probed_layers))``:
    the per-layer (candidate count, captured-score-mass ratio) leaves
    summed over every probed layer, for telemetry sampling. The logits
    and cache are computed by the identical ops either way.
    """
    if input_embed is not None:
        h = input_embed[:, None, :].astype(jnp.dtype(cfg.dtype))
    else:
        h = embed_tokens(params, cfg, token[:, None])
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (h.shape[0],))
    new_cache: Dict[str, Any] = {}
    probe_sum, probe_layers = None, 0
    _RO = ("sk_vals", "sk_rows", "sorted_upto")
    for si, seg in enumerate(build_segments(cfg)):
        seg_cache = cache[f"seg{si}"]
        ro = {k: v for k, v in seg_cache.items() if k in _RO}
        mut = {k: v for k, v in seg_cache.items() if k not in _RO}

        def body(carry, xs):
            lp, cs, ro_s = xs
            out, ns, aux = _decode_block(lp, {**cs, **ro_s}, carry, pos,
                                         cfg, seg, a3, use_kernel,
                                         probe=probe)
            return out, ns

        h, new_seg = jax.lax.scan(body, h, (params[f"seg{si}"], mut, ro))
        if probe and "_probe" in new_seg:
            pr = new_seg.pop("_probe")           # [L_seg, B, 2]
            probe_sum = probe_sum + pr.sum(axis=0) if probe_sum is not None \
                else pr.sum(axis=0)
            probe_layers += pr.shape[0]
        new_cache[f"seg{si}"] = {**new_seg, **ro}
    logits = unembed(params, cfg, h)[:, 0]
    if probe:
        if probe_sum is None:
            probe_sum = jnp.zeros((h.shape[0], 2), jnp.float32)
        return logits, new_cache, (probe_sum, probe_layers)
    return logits, new_cache


# ---------------------------------------------------------------------------
# multi-step scanned decode: T steps per dispatch, sampling in-graph
# ---------------------------------------------------------------------------

def resort_sorted_keys(cache: Dict[str, Any], pos: jax.Array,
                       resort_every: int) -> Dict[str, Any]:
    """In-graph A^3 re-sort: fold each lane's ring into its sorted key
    columns when the exact tail outgrew ``resort_every``.

    The serving-time analogue of the paper's comprehension-time
    preprocessing (SSIV-C), previously scheduled by a host-side read of
    the ``sorted_upto`` watermarks every tick. Here the watermark check
    is part of the dispatch: for each global-attention segment a lane is
    *due* when ``pos - sorted_upto >= resort_every``; a ``lax.cond``
    skips the O(w log w) sort entirely on steps where no lane is due,
    and due lanes select the fresh sort via ``jnp.where`` (others keep
    their matrices and watermark bit-identically). Lanes riding along at
    ``pos < 0`` are never due.

    ``pos`` is the per-lane position about to be written — the sort runs
    *before* the step's ring write, so it sees exactly the ring the
    host-side re-sort used to see between dispatches.
    """
    from repro.core.candidate_selection import sort_key_columns
    new_cache: Dict[str, Any] = {}
    pos = jnp.asarray(pos, jnp.int32)
    for name, sc in cache.items():
        if not isinstance(sc, dict) or "sk_vals" not in sc:
            new_cache[name] = sc
            continue
        due = (pos >= 0) & (pos - sc["sorted_upto"][0] >= resort_every)

        def _fold(op, due=due):
            k, skv, skr, upto = op
            sk = jax.vmap(jax.vmap(jax.vmap(sort_key_columns)))(k)
            d5 = due[None, :, None, None, None]
            return (jnp.where(d5, sk.values, skv),
                    jnp.where(d5, sk.rows, skr),
                    jnp.where(due[None, :], pos[None, :], upto))

        def _keep(op):
            _, skv, skr, upto = op
            return skv, skr, upto

        skv, skr, upto = jax.lax.cond(
            jnp.any(due), _fold, _keep,
            (sc["k"], sc["sk_vals"], sc["sk_rows"], sc["sorted_upto"]))
        new_cache[name] = {**sc, "sk_vals": skv, "sk_rows": skr,
                           "sorted_upto": upto}
    return new_cache


# Poison-quarantine sentinel for the decode token ring: emitted (once)
# by a lane whose logits went non-finite (NaN/Inf), then the lane
# freezes exactly like an exhausted ride-along. Distinct from -1
# (inactive lane) so the per-block harvest can tell "no token" from
# "poisoned lane" without any extra device read — the flag rides the
# ring the host already syncs once per block.
POISON = -2


def sample_logits(logits: jax.Array, *, temperature: float = 0.0,
                  rng: Optional[jax.Array] = None,
                  pos: Optional[jax.Array] = None,
                  ids: Optional[jax.Array] = None) -> jax.Array:
    """In-graph next-token sampling -> token ids [B].

    ``temperature == 0`` (or no ``rng``) is greedy argmax — identical to
    the host-side ``argmax`` the engine used to run after a device
    round-trip. With ``temperature > 0`` each lane draws from the
    tempered softmax with a key folded from (``ids``, ``pos``): the
    per-lane request id decorrelates concurrent and successive requests
    (identical prompts do not share a key stream), while folding the
    absolute position — not the step index — keeps a lane's draw at
    position p independent of how decode steps are blocked into
    dispatches or which engine slot the request occupies.
    """
    if temperature <= 0.0 or rng is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if pos is None:
        pos = jnp.zeros((logits.shape[0],), jnp.int32)
    if ids is None:
        ids = jnp.zeros((logits.shape[0],), jnp.int32)
    keys = jax.vmap(lambda u, p: jax.random.fold_in(
        jax.random.fold_in(rng, u), p))(ids, pos)
    draw = lambda k, lg: jax.random.categorical(
        k, lg.astype(jnp.float32) / temperature)
    return jax.vmap(draw)(keys, logits).astype(jnp.int32)


def decode_block(
    params: Params,
    cfg: ModelConfig,
    cache: Dict[str, Any],
    token: jax.Array,                 # [B] int32 last emitted token per lane
    pos: jax.Array,                   # [B] int32 next position; -1 = ride-along
    steps_left: jax.Array,            # [B] int32 steps this lane may advance
    *,
    steps: int,
    a3: A3Config = A3Config(),
    use_kernel: bool = False,
    resort_every: int = 0,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
    sample_ids: Optional[jax.Array] = None,   # [B] per-request sample keys
    probe: bool = False,
) -> Tuple[jax.Array, jax.Array, Dict[str, Any]]:
    """Run ``steps`` autoregressive decode steps in ONE dispatch via
    ``lax.scan`` -> (token ring [B, steps] int32, token carry [B] int32,
    new cache).

    The *carry* is the scan's final per-lane token — exactly the value a
    caller would feed as ``token`` to the next block. Returning it as a
    device array lets a serving loop chain blocks without ever
    harvesting the ring on the critical path: the next dispatch consumes
    the carry directly and the ring read becomes deferrable
    bookkeeping. Frozen lanes (budget spent, ``pos = -1`` ride-alongs,
    poisoned) pass their input token through unchanged, so the carry is
    valid for every lane that was valid on entry.

    The whole inner loop is device-resident: each scan step (a) re-sorts
    due lanes' A^3 key columns in-graph (:func:`resort_sorted_keys` —
    no host watermark read), (b) runs :func:`decode_step`, and (c)
    samples the next token in-graph (:func:`sample_logits`), feeding it
    to the following step. The host syncs once per block to harvest the
    emitted-token ring instead of once (or three times) per token.

    Lanes are masked per step: a lane is *active* while ``pos >= 0`` and
    its ``steps_left`` budget is unspent. Inactive lanes ride along at
    ``pos = -1`` — their ring writes scatter out of bounds and are
    dropped (the ragged-decode machinery), recurrent segments reselect
    their carried state bit-identically (the mixer interface's uniform
    pad-lane masking), their ring entries read -1, and their carried
    token/pos freeze — so lanes that exhaust budget or hit ``max_len``
    mid-block leave ALL cache state untouched, for every segment kind.
    A lane whose logits go non-finite (NaN/Inf — e.g. a corrupted mixer
    state) emits the :data:`POISON` sentinel once and freezes the same
    way; the host reads the sentinel off the ring it already harvests,
    so poison detection costs no extra sync and healthy lanes stay
    bit-identical. With ``steps=1`` this is exactly one
    :func:`decode_step` plus in-graph sampling.

    ``probe=True`` (A^3 telemetry) returns a 4-tuple ``(ring, carry,
    cache, probe [B, 3])`` where the probe accumulates, over the
    block's *advanced* steps only, ``(samples, sum of per-step mean
    candidate count, sum of per-step captured-score-mass ratio)`` per
    lane — in-graph state that lands with the same ring harvest the
    host already performs. The token/cache path runs the identical ops.
    """
    b = token.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    steps_left = jnp.broadcast_to(jnp.asarray(steps_left, jnp.int32), (b,))
    do_resort = resort_every > 0 and a3.mode != A3Mode.OFF

    def one_step(carry, _):
        if probe:
            token, pos, remaining, cache, acc = carry
        else:
            token, pos, remaining, cache = carry
        active = (pos >= 0) & (remaining > 0)
        eff_pos = jnp.where(active, pos, -1)
        if do_resort:
            cache = resort_sorted_keys(cache, eff_pos, resort_every)
        if probe:
            logits, cache, (psum, players) = decode_step(
                params, cfg, cache, token, eff_pos, a3=a3,
                use_kernel=use_kernel, probe=True)
        else:
            logits, cache = decode_step(params, cfg, cache, token, eff_pos,
                                        a3=a3, use_kernel=use_kernel)
        nxt = sample_logits(logits, temperature=temperature, rng=rng,
                            pos=eff_pos, ids=sample_ids)
        # poison quarantine: a lane whose logits went non-finite — or
        # whose handoff token already carried the POISON mark — emits
        # POISON once and freezes like an exhausted ride-along. Healthy
        # lanes take the identical select, so their tokens and cache
        # state are bit-for-bit unchanged by this check.
        ok = jnp.all(jnp.isfinite(logits), axis=-1) & (token != POISON)
        advance = active & ok
        poisoned = active & ~ok
        emit = jnp.where(advance, nxt,
                         jnp.where(poisoned, POISON, -1))
        token = jnp.where(advance, nxt, token)
        pos = jnp.where(advance, pos + 1, pos)
        remaining = jnp.where(poisoned, 0,
                              jnp.where(advance, remaining - 1, remaining))
        if probe:
            nl = max(players, 1)
            step_row = jnp.stack(
                [jnp.ones((b,), jnp.float32),
                 psum[:, 0] / nl,
                 jnp.clip(psum[:, 1] / nl, 0.0, 1.0)], axis=1)
            acc = acc + jnp.where(advance[:, None], step_row, 0.0)
            return (token, pos, remaining, cache, acc), emit
        return (token, pos, remaining, cache), emit

    init = (token.astype(jnp.int32), pos, steps_left, cache)
    if probe:
        init = init + (jnp.zeros((b, 3), jnp.float32),)
        (tok_f, _, _, cache, acc), ring = jax.lax.scan(
            one_step, init, None, length=steps)
        return jnp.moveaxis(ring, 0, 1), tok_f, cache, acc
    (tok_f, _, _, cache), ring = jax.lax.scan(
        one_step, init, None, length=steps)
    return jnp.moveaxis(ring, 0, 1), tok_f, cache


# ---------------------------------------------------------------------------
# prefill: full-sequence forward that also fills the decode caches
# ---------------------------------------------------------------------------

def prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    inputs_embeds: Optional[jax.Array] = None,
    *,
    max_len: Optional[int] = None,
    attn_chunk: int = 1024,
    a3: bool = False,
    select_shards: int = 1,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Process a prompt, return (last-token logits [B, Vp], filled cache).
    ``a3=True`` also builds the sorted-key matrices for global-attention
    segments (comprehension-time preprocessing, paper SSIV-C).

    Only the final position's logits are computed (serving needs just
    the next-token distribution; a full [B, S, Vp] logits tensor at 32k
    prompt x 262k vocab would be ~0.5 TB)."""
    if inputs_embeds is not None:
        h = inputs_embeds.astype(jnp.dtype(cfg.dtype))
        b, s, _ = h.shape
    else:
        b, s = tokens.shape
        h = embed_tokens(params, cfg, tokens)
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cache: Dict[str, Any] = {}

    for si, seg in enumerate(build_segments(cfg)):
        def body(carry, lp, seg=seg):
            hh = shard_act(carry, "hidden")
            hn = rmsnorm(lp["ln1"], hh, cfg.norm_eps)
            o, ns = MIXERS[seg.kind].prefill_full(
                lp, hn, cfg=cfg, seg=seg, positions=positions,
                attn_chunk=attn_chunk, max_len=max_len, a3=a3,
                select_shards=select_shards)
            hh = hh + o
            hh, _ = _ffn_block(lp, hh, cfg, seg)
            return hh, ns

        h, seg_cache = jax.lax.scan(body, h, params[f"seg{si}"])
        cache[f"seg{si}"] = seg_cache

    logits = unembed(params, cfg, h[:, -1:])[:, 0]
    return logits, cache


# ---------------------------------------------------------------------------
# chunked / ragged admission prefill: extend per-slot caches in place
# ---------------------------------------------------------------------------

def prefill_chunk(
    params: Params,
    cfg: ModelConfig,
    cache: Dict[str, Any],
    tokens: jax.Array,                # [B, C] int32 (ragged, zero-padded)
    pos: jax.Array,                   # [B] int32 per-slot chunk start
    length: jax.Array,                # [B] int32 valid tokens; 0 = skip lane
    *,
    a3: bool = False,
    sort_lanes: Optional[jax.Array] = None,   # [B] bool; default: length > 0
    update_sort: bool = True,                 # static: False = sk leaves RO
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Extend per-slot decode caches with one ragged batch of prompt chunks.

    Every lane processes ``length[b]`` tokens of its prompt starting at
    absolute position ``pos[b]`` — a single dispatch serves slots at
    arbitrary prompt cursors (ragged admission prefill). Works for every
    segment kind through the mixer-state interface: attention segments
    extend their KV rings, recurrent segments (RG-LRU conv tail + LRU
    hidden, mLSTM matrix memory, sLSTM cell state) carry their
    mid-prompt state across chunk boundaries, with pad positions masked
    out of the state update per lane. Lanes with ``length == 0`` are
    passed through untouched (their cache rows are bit-identical on
    output), so decoding slots can share the dispatch batch with
    prefilling ones. A lane at ``pos == 0`` first resets its state
    in-graph (a reused slot may hold a finished request's keys or
    recurrent state).

    With ``a3=True``, lanes in ``sort_lanes`` fold the updated ring into
    the per-column sorted-key matrices and advance ``sorted_upto`` to
    ``pos + length``. The engine passes only lanes on their *final*
    chunk (one sort per admitted prompt); the default sorts every
    active lane's chunk, which is correct but does the sort work
    per-chunk instead of per-prompt. ``update_sort=False`` (a *static*
    flag — a separate jit specialization) additionally keeps the sorted
    leaves out of the layer scan entirely, so non-final chunk ticks do
    not pay a per-layer copy of the sorted-key cache (the same
    read-only-leaf treatment ``decode_step`` applies).

    Chunking is output-invariant: a query's attention set (positions
    ``<= q``, within the segment window) does not depend on where chunk
    boundaries fall, so running a prompt through any chunk split yields
    the same cache rows and logits as :func:`prefill` up to fp
    summation order. With ``a3=True`` the chunk's keys are folded into
    the per-column sorted-key matrices (incremental comprehension-time
    preprocessing) and ``sorted_upto`` advances to ``pos + length``.

    Returns (logits [B, Vp] at each lane's last valid position, cache).
    """
    b, c = tokens.shape
    h = embed_tokens(params, cfg, tokens)
    pos = jnp.asarray(pos, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    if sort_lanes is None:
        sort_lanes = length > 0
    sort_lanes = jnp.asarray(sort_lanes, bool)
    offs = jnp.arange(c, dtype=jnp.int32)
    positions = pos[:, None] + offs[None, :]               # [B, C]
    valid_tok = offs[None, :] < length[:, None]            # [B, C]
    new_cache: Dict[str, Any] = {}
    _RO = ("sk_vals", "sk_rows", "sorted_upto")
    for si, seg in enumerate(build_segments(cfg)):
        seg_cache = cache[f"seg{si}"]
        ro = {} if update_sort else \
            {k: v for k, v in seg_cache.items() if k in _RO}
        mut = seg_cache if update_sort else \
            {k: v for k, v in seg_cache.items() if k not in _RO}

        def body(carry, xs, seg=seg):
            lp, cs = xs
            hh = shard_act(carry, "hidden")
            hn = rmsnorm(lp["ln1"], hh, cfg.norm_eps)
            o, ns = MIXERS[seg.kind].prefill_chunk(
                lp, cs, hn, cfg=cfg, seg=seg, positions=positions,
                valid_tok=valid_tok, pos=pos, length=length,
                sort_lanes=sort_lanes, a3=a3)
            hh = hh + o
            hh, _ = _ffn_block(lp, hh, cfg, seg)
            return hh, ns

        h, new_seg = jax.lax.scan(body, h, (params[f"seg{si}"], mut))
        new_cache[f"seg{si}"] = {**new_seg, **ro}
    bidx = jnp.arange(b, dtype=jnp.int32)
    last = jnp.clip(length - 1, 0, c - 1)
    logits = unembed(params, cfg, h[bidx, last][:, None])[:, 0]
    return logits, new_cache
