"""Unified per-segment mixer-state interface.

A model is a sequence of *segments* (maximal runs of layers sharing a
(block kind, ffn kind, attention window) signature — see
:class:`SegmentSpec`). Every segment kind carries decode-time state:

  ATTENTION -> KV ring buffers (+ the A^3 sorted key columns and the
               ``sorted_upto`` watermark on global-window layers)
  RGLRU     -> causal-conv tail + LRU hidden state
  MLSTM     -> (C, n, m) matrix-memory state
  SLSTM     -> (c, n, m, h) cell state

This module makes that state flow through ONE interface per kind — a
:class:`SegmentMixer` with five entry points — so the decoder's four
execution paths (train forward, whole-prompt prefill, chunked ragged
admission prefill, ragged decode) are each a single kind-agnostic loop
instead of three near-duplicate per-kind branches:

  ``init_state``     allocate the per-layer-stacked state pytree
  ``forward``        full-sequence mixer output (train; no state)
  ``prefill_full``   full-sequence output + end-of-prompt state
  ``prefill_chunk``  ragged mid-prompt chunk with carried state
  ``decode_step``    one ragged autoregressive step

Ragged pad-lane masking is uniform: in ``prefill_chunk`` a lane with
``length == 0`` and in ``decode_step`` a lane with ``pos < 0`` returns
its state **bit-identically** (attention: out-of-bounds scatter drop;
recurrent kinds: an explicit per-lane reselect), so idle / prefilling /
budget-exhausted engine slots can ride along in any dispatch without
their state advancing on garbage. A lane starting a fresh prompt
(``pos == 0, length > 0``) resets its state in-graph inside the chunk
dispatch — the slot may hold a finished request's state.

Each mixer consumes the post-``ln1`` normalized hidden ``hn`` and
returns the residual *delta* (the caller owns norm, residual add, and
the FFN half of the block, which is kind-independent).

The interface also carries the **paged prefix-cache hooks**
(:mod:`repro.serve.prefix_cache`): ``init_pages`` allocates a segment's
share of the page pool (attention: per-page K/V rows; recurrent kinds:
no per-token pages — their decode state is a fixed-size carry),
``write_page`` / ``gather_pages`` copy ring rows pool-ward /
slot-ward, and ``snapshot_state`` / ``restore_state`` capture / replay
the per-lane mixer state at a page boundary (the chunked-prefill carry
*is* the snapshot: for recurrent kinds it is the whole state; for
attention everything per-token lives in pages, so the snapshot is
empty and restore is the page gather).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import A3Config, A3Mode, AttentionKind, BlockKind, \
    ModelConfig
from repro.kernels.decode_attention.ops import a3_decode_attention
from repro.models import xlstm as xl
from repro.models.common import (
    Params,
    attention_out,
    attention_qkv,
    attention_xla_flash,
    shard_act,
)
from repro.models.rglru import (
    CONV_WIDTH,
    rglru_apply_scan,
    rglru_chunk_step,
    rglru_decode_step,
)

FULL_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    kind: BlockKind
    ffn: str                 # "dense" | "moe" | "none"
    window: int              # FULL_WINDOW for global attention
    layers: Tuple[int, ...]  # absolute layer indices

    @property
    def count(self) -> int:
        return len(self.layers)


def _layer_signature(cfg: ModelConfig, i: int) -> Tuple:
    kind = cfg.block_kind(i)
    if kind in (BlockKind.MLSTM, BlockKind.SLSTM):
        ffn = "dense" if cfg.d_ff else "none"
    elif cfg.moe is not None and i >= cfg.moe.num_dense_layers:
        ffn = "moe"
    else:
        ffn = "dense"
    window = FULL_WINDOW
    if kind == BlockKind.ATTENTION:
        if cfg.attention_kind == AttentionKind.SLIDING:
            window = cfg.window_size
        elif cfg.attention_kind == AttentionKind.LOCAL_GLOBAL:
            window = FULL_WINDOW if cfg.layer_is_global(i) else cfg.window_size
    return (kind, ffn, window)


def build_segments(cfg: ModelConfig) -> List[SegmentSpec]:
    segs: List[SegmentSpec] = []
    cur: List[int] = []
    cur_sig = None
    for i in range(cfg.num_layers):
        sig = _layer_signature(cfg, i)
        if sig != cur_sig and cur:
            segs.append(SegmentSpec(cur_sig[0], cur_sig[1], cur_sig[2],
                                    tuple(cur)))
            cur = []
        cur_sig = sig
        cur.append(i)
    if cur:
        segs.append(SegmentSpec(cur_sig[0], cur_sig[1], cur_sig[2], tuple(cur)))
    return segs


def cache_len_for(seg: SegmentSpec, max_len: int) -> int:
    if seg.kind != BlockKind.ATTENTION:
        return 0
    return min(max_len, seg.window)


# ---------------------------------------------------------------------------
# ring-buffer geometry (attention)
# ---------------------------------------------------------------------------

def _ring_slot_positions(w: int, pos: jax.Array) -> jax.Array:
    """Position held by each ring slot after writing position ``pos``.

    Slot s holds position p(s) = largest p' <= pos with p' % w == s.
    ``pos`` may be a scalar (-> [w]) or a per-batch vector [B] (-> [B, w]).
    """
    slots = jnp.arange(w, dtype=jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)[..., None]
    return pos - jnp.mod(pos - slots, w)


def _ring_valid_mask(w: int, pos: jax.Array, window: int) -> jax.Array:
    """Validity of ring slots after writing position ``pos`` at pos % w.

    Valid iff p(s) >= 0 (written) and p(s) > pos - window. ``pos`` may be
    scalar or per-batch [B] (ragged decode); the mask gains a matching
    leading batch dim.
    """
    slot_pos = _ring_slot_positions(w, pos)
    pos = jnp.asarray(pos, jnp.int32)[..., None]
    return (slot_pos >= 0) & (slot_pos > pos - window)


def _lane_select(new: jax.Array, old: jax.Array,
                 active: jax.Array) -> jax.Array:
    """Per-lane state select: inactive lanes keep ``old`` bit-identically.
    ``active`` is [B]; leaves are [B, ...]."""
    shape = (old.shape[0],) + (1,) * (old.ndim - 1)
    return jnp.where(active.reshape(shape), new, old)


# ---------------------------------------------------------------------------
# ATTENTION mixer
# ---------------------------------------------------------------------------

def _attn_init_state(cfg: ModelConfig, seg: SegmentSpec, batch: int,
                     max_len: int, dtype, a3: bool) -> Dict[str, jax.Array]:
    L, hd = seg.count, cfg.resolved_head_dim
    w = cache_len_for(seg, max_len)
    state = {
        "k": jnp.zeros((L, batch, cfg.num_kv_heads, w, hd), dtype),
        "v": jnp.zeros((L, batch, cfg.num_kv_heads, w, hd), dtype),
    }
    if a3 and seg.window >= FULL_WINDOW:
        state["sk_vals"] = jnp.zeros((L, batch, cfg.num_kv_heads, w, hd),
                                     dtype)
        state["sk_rows"] = jnp.zeros((L, batch, cfg.num_kv_heads, w, hd),
                                     jnp.int32)
        state["sorted_upto"] = jnp.zeros((L, batch), jnp.int32)
    return state


def _attn_forward(lp: Params, hn: jax.Array, *, cfg: ModelConfig,
                  seg: SegmentSpec, positions: jax.Array,
                  attn_chunk: int, **_) -> jax.Array:
    q, k, v = attention_qkv(lp["attn"], hn, positions, cfg.num_heads,
                            cfg.num_kv_heads, cfg.resolved_head_dim,
                            cfg.rope_theta)
    q = shard_act(q, "q")
    k = shard_act(k, "kv")
    v = shard_act(v, "kv")
    window = None if seg.window >= FULL_WINDOW else jnp.int32(seg.window)
    o = attention_xla_flash(q, k, v, causal=True, window=window,
                            chunk=attn_chunk)
    return attention_out(lp["attn"], o)


def _attn_prefill_full(lp: Params, hn: jax.Array, *, cfg: ModelConfig,
                       seg: SegmentSpec, positions: jax.Array,
                       attn_chunk: int, max_len: int, a3: bool,
                       select_shards: int, **_
                       ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, s, _ = hn.shape
    hd = cfg.resolved_head_dim
    w = cache_len_for(seg, max_len)
    q, k, v = attention_qkv(lp["attn"], hn, positions, cfg.num_heads,
                            cfg.num_kv_heads, hd, cfg.rope_theta)
    q = shard_act(q, "q")
    k = shard_act(k, "kv")
    v = shard_act(v, "kv")
    window = None if seg.window >= FULL_WINDOW else jnp.int32(seg.window)
    o = attention_xla_flash(q, k, v, causal=True, window=window,
                            chunk=attn_chunk)
    # ring-write the last min(s, w) positions
    kc = jnp.zeros((k.shape[0], k.shape[1], w, hd), k.dtype)
    vc = jnp.zeros_like(kc)
    take = min(s, w)
    pos_tail = jnp.arange(s - take, s, dtype=jnp.int32)  # positions s-take..s-1
    slots = jnp.mod(pos_tail, w)
    kc = kc.at[:, :, slots].set(k[:, :, s - take:])
    vc = vc.at[:, :, slots].set(v[:, :, s - take:])
    state = {"k": kc, "v": vc}
    if a3 and seg.window >= FULL_WINDOW:
        from repro.core.candidate_selection import sort_key_columns
        ns = select_shards if w % max(select_shards, 1) == 0 else 1
        kb = kc.reshape(kc.shape[0], kc.shape[1], ns, w // ns, hd)
        sk = jax.vmap(jax.vmap(jax.vmap(sort_key_columns)))(kb)
        state["sk_vals"] = sk.values.reshape(kc.shape)
        state["sk_rows"] = sk.rows.reshape(kc.shape)       # block-local
        state["sorted_upto"] = jnp.full((kc.shape[0],), s, jnp.int32)
    return attention_out(lp["attn"], o), state


def _attn_prefill_chunk(lp: Params, state: Dict[str, jax.Array],
                        hn: jax.Array, *, cfg: ModelConfig,
                        seg: SegmentSpec, positions: jax.Array,
                        valid_tok: jax.Array, pos: jax.Array,
                        length: jax.Array, sort_lanes: jax.Array,
                        a3: bool, **_
                        ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, c, _ = hn.shape
    hd = cfg.resolved_head_dim
    hkv, group = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    q, k, v = attention_qkv(lp["attn"], hn, positions, cfg.num_heads,
                            hkv, hd, cfg.rope_theta)           # [B, H, C, D]
    q = shard_act(q, "q")
    k = shard_act(k, "kv")
    v = shard_act(v, "kv")
    ck, cv = state["k"], state["v"]
    # A lane starting a new prompt (pos 0) zeroes its ring rows inside
    # the donated dispatch — the slot may hold a finished request's rows,
    # and whole-prompt-parity (incl. the A3 sort over the full ring)
    # needs unwritten rows to read as zeros. Fused here, this costs no
    # extra HBM sweep, unlike a host-side reset copy per admission.
    fresh = ((pos == 0) & (length > 0))[:, None, None, None]
    zero = jnp.asarray(0, ck.dtype)
    ck = jnp.where(fresh, zero, ck)
    cv = jnp.where(fresh, zero, cv)
    w = ck.shape[2]
    window = seg.window

    # Attention BEFORE the ring write: chunk queries see (a) the ring as
    # it stood before this chunk and (b) in-chunk keys, so a wrapping
    # write can never clobber a position an earlier query still needs.
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(b, hkv, group, c, hd)
    offs = jnp.arange(c, dtype=jnp.int32)
    slots = jnp.arange(w, dtype=jnp.int32)
    last_prev = pos - 1                                        # [B]
    slot_pos = last_prev[:, None] - jnp.mod(
        last_prev[:, None] - slots[None, :], w)                # [B, w]
    ring_mask = (slot_pos[:, None, :] >= 0) & \
        (slot_pos[:, None, :] > positions[:, :, None] - window)  # [B, C, w]
    chunk_mask = (offs[None, :, None] >= offs[None, None, :]) & \
        (offs[None, :, None] - offs[None, None, :] < window) & \
        valid_tok[:, None, :]                                  # [B, C, C]
    mask = jnp.concatenate([ring_mask, chunk_mask], -1)        # [B, C, w+C]

    s_ring = jnp.einsum("bhgqd,bhkd->bhgqk", qf,
                        ck.astype(jnp.float32))                # [B,Hkv,G,C,w]
    s_chunk = jnp.einsum("bhgqd,bhkd->bhgqk", qf,
                         k.astype(jnp.float32))                # [B,Hkv,G,C,C]
    s = jnp.concatenate([s_ring, s_chunk], -1)
    mb = mask[:, None, None]
    s = jnp.where(mb, s, -1e30)
    m = jnp.max(s, -1, keepdims=True)
    p = jnp.where(mb, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, -1, keepdims=True)
    vcat = jnp.concatenate([cv, v], 2).astype(jnp.float32)     # [B,Hkv,w+C,D]
    acc = jnp.einsum("bhgqk,bhkd->bhgqd", p, vcat)
    o = jnp.where(l == 0.0, 0.0, acc / jnp.where(l == 0.0, 1.0, l))
    o = o.reshape(b, cfg.num_heads, c, hd).astype(hn.dtype)

    # Ragged ring write: pad slots and inactive lanes scatter to index w
    # (out of bounds -> dropped), leaving other slots' rows untouched.
    # When the chunk exceeds the ring (sliding windows) only the last w
    # chunk positions land, as in whole-prompt prefill.
    writable = valid_tok & (positions > (pos + length - 1)[:, None] - w)
    tgt = jnp.where(writable, jnp.mod(positions, w), w)        # [B, C]
    b2 = jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32)[:, None], (b, c))
    kc = ck.at[b2, :, tgt].set(jnp.swapaxes(k, 1, 2), mode="drop")
    vc = cv.at[b2, :, tgt].set(jnp.swapaxes(v, 1, 2), mode="drop")
    new_state = {"k": kc, "v": vc}

    if a3 and "sk_vals" in state:
        # incremental comprehension-time preprocessing: fold the chunk's
        # keys into the per-column sort for lanes in ``sort_lanes``
        # (whole-ring sort; other lanes keep their sorted state +
        # watermark). The engine only sets sort_lanes on a prompt's
        # final chunk — nothing reads a PREFILLING slot's sort — so the
        # O(w log w) sort runs once per admitted prompt, as in
        # whole-prompt prefill; lax.cond skips it entirely on ticks
        # where no lane finishes.
        from repro.core.candidate_selection import sort_key_columns

        def _fold(_):
            sk = jax.vmap(jax.vmap(sort_key_columns))(kc)
            l4 = sort_lanes[:, None, None, None]
            return (jnp.where(l4, sk.values, state["sk_vals"]),
                    jnp.where(l4, sk.rows, state["sk_rows"]),
                    jnp.where(sort_lanes, pos + length,
                              state["sorted_upto"]))

        def _keep(_):
            return (state["sk_vals"], state["sk_rows"],
                    state["sorted_upto"])

        sk_vals, sk_rows, upto = jax.lax.cond(jnp.any(sort_lanes),
                                              _fold, _keep, None)
        new_state["sk_vals"] = sk_vals
        new_state["sk_rows"] = sk_rows
        new_state["sorted_upto"] = upto
    return attention_out(lp["attn"], o), new_state


def _attn_decode_step(lp: Params, state: Dict[str, jax.Array],
                      hn: jax.Array, *, cfg: ModelConfig, seg: SegmentSpec,
                      pos: jax.Array, a3: A3Config, use_kernel: bool,
                      probe: bool = False, **_
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b = hn.shape[0]
    hd = cfg.resolved_head_dim
    positions = pos[:, None]                                   # [B, 1]
    q, k, v = attention_qkv(lp["attn"], hn, positions, cfg.num_heads,
                            cfg.num_kv_heads, hd, cfg.rope_theta)
    q = shard_act(q, "q")
    w = state["k"].shape[2]
    # per-slot ring write: each sequence writes its own token at its own
    # ring slot (ragged continuous batching — one dispatch serves slots
    # at arbitrary position skew). Lanes with pos < 0 (idle/prefilling
    # engine slots riding along in the batch) scatter out of bounds and
    # are dropped, so mid-prefill cache rows are never clobbered.
    slot = jnp.where(pos >= 0, jnp.mod(pos, w), w)             # [B]
    bidx = jnp.arange(b, dtype=jnp.int32)
    kc = state["k"].at[bidx, :, slot].set(k[:, :, 0], mode="drop")
    vc = state["v"].at[bidx, :, slot].set(v[:, :, 0], mode="drop")
    kc = shard_act(kc, "kv_cache")
    vc = shard_act(vc, "kv_cache")
    valid = _ring_valid_mask(w, pos, seg.window)               # [B, w]
    # A^3 approximate decode only on global-attention layers: windowed
    # layers already bound the search (DESIGN.md SS5).
    use_a3 = a3.mode != A3Mode.OFF and seg.window >= FULL_WINDOW
    # NOTE: read-only leaves (sk_*, sorted_upto) are NOT returned — the
    # caller keeps them out of the scan ys (passing them through forced
    # a full copy of the sorted-key cache per layer iteration).
    new_state = {"k": kc, "v": vc}
    if use_a3 and "sk_vals" in state:
        # comprehension-time sorted keys cached at prefill (paper SSIV-C);
        # rows written since the last re-sort get exact treatment.
        from repro.core.candidate_selection import SortedKeys
        from repro.kernels.decode_attention.ops import \
            a3_decode_attention_compact
        slot_pos = _ring_slot_positions(w, pos)                 # [B, w]
        fresh = slot_pos >= state["sorted_upto"][:, None]       # [B, w]
        sk = SortedKeys(values=shard_act(state["sk_vals"], "kv_cache"),
                        rows=shard_act(state["sk_rows"], "kv_cache"))
        if probe:
            # A^3 quality probe (telemetry): captured-score-mass and
            # candidate-count leaves ride the scan ys like any other
            # mutable state and land with the ring harvest — zero
            # extra host syncs. The attention output ops are identical
            # with or without the probe.
            o, pr = a3_decode_attention_compact(
                q[:, :, 0], kc, vc, valid, a3, sk, fresh_mask=fresh,
                return_probe=True)
            new_state["_probe"] = pr
        else:
            o = a3_decode_attention_compact(
                q[:, :, 0], kc, vc, valid, a3, sk, fresh_mask=fresh)
    elif use_a3:
        from repro.core.candidate_selection import sort_key_columns
        # no cached sort available: build inline (single-shot use)
        sorted_keys = jax.vmap(jax.vmap(sort_key_columns))(kc)
        o = a3_decode_attention(q[:, :, 0], kc, vc, valid, a3,
                                sorted_keys=sorted_keys,
                                use_kernel=use_kernel)
    else:
        o = a3_decode_attention(q[:, :, 0], kc, vc, valid, A3Config(),
                                use_kernel=use_kernel)
    return attention_out(lp["attn"], o[:, :, None, :]), new_state


def _attn_init_pages(cfg: ModelConfig, seg: SegmentSpec, pages: int,
                     page_size: int, dtype, a3: bool,
                     kv_quant: str = "none") -> Dict[str, jax.Array]:
    """Attention's share of the paged prefix-cache pool: per-page K/V
    rows. A *logical* page spans ``page_size`` token positions across
    every segment at once; sorted-key state is not paged (it is a
    whole-ring property, restored at gather time).

    ``kv_quant="int8"`` stores the pages as int8 with one fp32 amax
    scale per (layer, page, kv head) — ~4x more pages resident at equal
    HBM, and the warm gather moves 1 byte/element instead of 4.
    ``write_page`` quantizes on record and ``gather_pages`` dequantizes
    inside the same one-dispatch copy (the presence of the scale leaves
    is what routes them)."""
    L, hd = seg.count, cfg.resolved_head_dim
    if kv_quant == "int8":
        shp = (L, pages, cfg.num_kv_heads, page_size, hd)
        return {
            "k": jnp.zeros(shp, jnp.int8),
            "v": jnp.zeros(shp, jnp.int8),
            "k_scale": jnp.zeros((L, pages, cfg.num_kv_heads, 1, 1),
                                 jnp.float32),
            "v_scale": jnp.zeros((L, pages, cfg.num_kv_heads, 1, 1),
                                 jnp.float32),
        }
    return {
        "k": jnp.zeros((L, pages, cfg.num_kv_heads, page_size, hd), dtype),
        "v": jnp.zeros((L, pages, cfg.num_kv_heads, page_size, hd), dtype),
    }


def _attn_write_page(pool_seg: Dict[str, jax.Array],
                     state: Dict[str, jax.Array], si: jax.Array,
                     page_id: jax.Array, rows: jax.Array,
                     valid: jax.Array) -> Dict[str, jax.Array]:
    """Copy one page of slot ``si``'s ring into the pool at ``page_id``.

    ``rows`` [ps] maps page offsets to ring rows (``pos % w``); offsets
    whose position fell out of the ring (``valid`` False — a page wider
    than a sliding window) store zeros, matching what an unwritten ring
    row reads as at restore time.

    On an int8 pool (``k_scale`` present) the copy quantizes in the same
    dispatch: one fp32 amax scale per (layer, head) for this page."""
    v4 = valid[None, None, :, None]

    def put(pages, leaf):
        src = leaf[:, si][:, :, rows]                  # [L, H, ps, hd]
        src = jnp.where(v4, src, jnp.zeros((), leaf.dtype))
        return pages.at[:, page_id].set(src)

    if "k_scale" not in pool_seg:
        return {"k": put(pool_seg["k"], state["k"]),
                "v": put(pool_seg["v"], state["v"])}

    from repro.core.quantization import quantize_int8_block

    def put_q(pages, scales, leaf):
        src = leaf[:, si][:, :, rows]                  # [L, H, ps, hd]
        src = jnp.where(v4, src, jnp.zeros((), leaf.dtype))
        q, scale = quantize_int8_block(src, axes=(2, 3))   # [L, H, 1, 1]
        return (pages.at[:, page_id].set(q),
                scales.at[:, page_id].set(scale))

    k, ks = put_q(pool_seg["k"], pool_seg["k_scale"], state["k"])
    v, vs = put_q(pool_seg["v"], pool_seg["v_scale"], state["v"])
    return {"k": k, "v": v, "k_scale": ks, "v_scale": vs}


def _attn_gather_pages(state: Dict[str, jax.Array],
                       pool_seg: Dict[str, jax.Array], si: jax.Array,
                       t: jax.Array, page_idx: jax.Array,
                       row_off: jax.Array, valid: jax.Array, *,
                       a3: bool, sk_snap=None) -> Dict[str, jax.Array]:
    """Restore slot ``si``'s ring for a matched prefix of length ``t``
    from pool pages — the warm-admission copy.

    ``page_idx`` / ``row_off`` [w] give each ring row's source
    (pool page, in-page offset); rows with ``valid`` False (unwritten at
    position ``t``) are zeroed, so the slot's ring is bit-identical to a
    cold chunked prefill of the same prefix. With ``a3`` the sorted key
    columns are restored too: sliced out of a donor prompt's leaf
    snapshot via :func:`~repro.core.candidate_selection.slice_sorted_keys`
    when one exists (``sk_snap``), else re-derived by a comprehension
    sort of the gathered ring — either way ``sorted_upto`` comes back as
    ``t``, so admission triggers no A^3 re-sort.

    An int8 pool (``k_scale`` present) dequantizes inside this same
    dispatch — per-page fp32 scales broadcast over the gathered rows, so
    the slot ring comes back in its serving dtype and the wire/HBM
    traffic of the gather stays 1 byte/element. Int8 sorted-key
    snapshots (``sk_snap["scale"]``) dequantize per sorted column before
    the boundary slice."""
    v4 = valid[None, None, :, None]
    quant = "k_scale" in pool_seg
    out_dtype = state["k"].dtype

    def take(pages, scales=None):
        g = pages[:, page_idx, :, row_off]             # [w, L, H, hd]
        g = jnp.moveaxis(g, 0, 2)                      # [L, H, w, hd]
        if scales is not None:
            sc = scales[:, page_idx, :, 0, 0]          # [w, L, H]
            sc = jnp.moveaxis(sc, 0, 2)[..., None]     # [L, H, w, 1]
            g = (g.astype(jnp.float32) * sc).astype(out_dtype)
        return jnp.where(v4, g, jnp.zeros((), g.dtype))

    k_slot = take(pool_seg["k"], pool_seg.get("k_scale"))
    new = {"k": state["k"].at[:, si].set(k_slot),
           "v": state["v"].at[:, si].set(
               take(pool_seg["v"], pool_seg.get("v_scale")))}
    if a3 and "sk_vals" in state:
        from repro.core.candidate_selection import SortedKeys, \
            slice_sorted_keys, sort_key_columns
        from repro.core.quantization import dequantize_int8_block
        if sk_snap is not None:
            sk_vals = sk_snap["vals"]
            if "scale" in sk_snap:
                sk_vals = dequantize_int8_block(sk_vals, sk_snap["scale"],
                                                dtype=out_dtype)
            sliced = jax.vmap(jax.vmap(
                lambda v_, r_: slice_sorted_keys(SortedKeys(v_, r_),
                                                 valid)))(
                sk_vals, sk_snap["rows"])
        else:
            sliced = jax.vmap(jax.vmap(sort_key_columns))(k_slot)
        new["sk_vals"] = state["sk_vals"].at[:, si].set(sliced.values)
        new["sk_rows"] = state["sk_rows"].at[:, si].set(sliced.rows)
        new["sorted_upto"] = state["sorted_upto"].at[:, si].set(
            jnp.asarray(t, jnp.int32))
    return {**state, **new}


def _attn_snapshot(state: Dict[str, jax.Array], si: jax.Array
                   ) -> Dict[str, jax.Array]:
    """Attention's per-token decode state lives entirely in pages; the
    boundary snapshot is empty (sorted-key leaf snapshots are captured
    separately by the prefix cache, once per recorded prompt)."""
    return {}


def _attn_restore(state: Dict[str, jax.Array], snap: Dict[str, jax.Array],
                  si: jax.Array) -> Dict[str, jax.Array]:
    return state                                    # pages carry it all


# ---------------------------------------------------------------------------
# RG-LRU mixer
# ---------------------------------------------------------------------------

def _rglru_init_state(cfg: ModelConfig, seg: SegmentSpec, batch: int,
                      max_len: int, dtype, a3: bool) -> Dict[str, jax.Array]:
    L = seg.count
    d_rnn = cfg.num_heads * cfg.resolved_head_dim
    return {
        "h": jnp.zeros((L, batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((L, batch, CONV_WIDTH - 1, d_rnn), dtype),
    }


def _rglru_forward(lp: Params, hn: jax.Array, **_) -> jax.Array:
    return rglru_apply_scan(lp["rnn"], hn)[0]


def _rglru_prefill_full(lp: Params, hn: jax.Array, **_):
    o, h_last, conv = rglru_apply_scan(lp["rnn"], hn)
    return o, {"h": h_last, "conv": conv}


def _rglru_prefill_chunk(lp: Params, state: Dict[str, jax.Array],
                         hn: jax.Array, *, pos: jax.Array,
                         length: jax.Array, valid_tok: jax.Array, **_):
    fresh = (pos == 0) & (length > 0)
    h0 = jnp.where(fresh[:, None], 0.0, state["h"])
    conv = _lane_select(jnp.zeros_like(state["conv"]), state["conv"], fresh)
    o, h_last, new_conv = rglru_chunk_step(lp["rnn"], hn, h0, conv,
                                           valid_tok)
    act = length > 0
    return o, {"h": _lane_select(h_last, state["h"], act),
               "conv": _lane_select(new_conv, state["conv"], act)}


def _rglru_decode_step(lp: Params, state: Dict[str, jax.Array],
                       hn: jax.Array, *, pos: jax.Array, **_):
    o, h_new, conv_new = rglru_decode_step(lp["rnn"], hn, state["h"],
                                           state["conv"])
    act = pos >= 0
    return o, {"h": _lane_select(h_new, state["h"], act),
               "conv": _lane_select(conv_new, state["conv"], act)}


# ---------------------------------------------------------------------------
# mLSTM mixer
# ---------------------------------------------------------------------------

def _mlstm_init_state(cfg: ModelConfig, seg: SegmentSpec, batch: int,
                      max_len: int, dtype, a3: bool) -> Dict[str, jax.Array]:
    L, hd = seg.count, cfg.resolved_head_dim
    return {
        "C": jnp.zeros((L, batch, cfg.num_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((L, batch, cfg.num_heads, hd), jnp.float32),
        "m": jnp.full((L, batch, cfg.num_heads), -1e30, jnp.float32),
    }


def _mlstm_state_tuple(state: Dict[str, jax.Array]):
    return (state["C"], state["n"], state["m"])


def _mlstm_forward(lp: Params, hn: jax.Array, *, cfg: ModelConfig, **_):
    return xl.mlstm_parallel(lp["mlstm"], hn, cfg.num_heads,
                             cfg.resolved_head_dim)


def _mlstm_prefill_full(lp: Params, hn: jax.Array, *, cfg: ModelConfig, **_):
    o, (C, n, m) = xl.mlstm_chunkwise(lp["mlstm"], hn, cfg.num_heads,
                                      cfg.resolved_head_dim)
    return o, {"C": C, "n": n, "m": m}


def _mlstm_prefill_chunk(lp: Params, state: Dict[str, jax.Array],
                         hn: jax.Array, *, cfg: ModelConfig,
                         pos: jax.Array, length: jax.Array,
                         valid_tok: jax.Array, **_):
    fresh = (pos == 0) & (length > 0)
    st = (
        _lane_select(jnp.zeros_like(state["C"]), state["C"], fresh),
        _lane_select(jnp.zeros_like(state["n"]), state["n"], fresh),
        _lane_select(jnp.full_like(state["m"], -1e30), state["m"], fresh),
    )
    o, (C, n, m) = xl.mlstm_chunkwise(lp["mlstm"], hn, cfg.num_heads,
                                      cfg.resolved_head_dim, state=st,
                                      valid=valid_tok)
    act = length > 0
    return o, {"C": _lane_select(C, state["C"], act),
               "n": _lane_select(n, state["n"], act),
               "m": _lane_select(m, state["m"], act)}


def _mlstm_decode_step(lp: Params, state: Dict[str, jax.Array],
                       hn: jax.Array, *, cfg: ModelConfig,
                       pos: jax.Array, **_):
    o, (C, n, m) = xl.mlstm_decode_step(lp["mlstm"], hn,
                                        _mlstm_state_tuple(state),
                                        cfg.num_heads,
                                        cfg.resolved_head_dim)
    act = pos >= 0
    return o, {"C": _lane_select(C, state["C"], act),
               "n": _lane_select(n, state["n"], act),
               "m": _lane_select(m, state["m"], act)}


# ---------------------------------------------------------------------------
# sLSTM mixer
# ---------------------------------------------------------------------------

def _slstm_init_state(cfg: ModelConfig, seg: SegmentSpec, batch: int,
                      max_len: int, dtype, a3: bool) -> Dict[str, jax.Array]:
    L, d = seg.count, cfg.d_model
    # distinct buffers per leaf: the engine's donated dispatches would
    # otherwise donate one aliased buffer several times
    zeros = lambda: jnp.zeros((L, batch, d), jnp.float32)  # noqa: E731
    return {"c": zeros(), "n": zeros(),
            "m": jnp.full((L, batch, d), -1e30, jnp.float32), "h": zeros()}


def _slstm_state_tuple(state: Dict[str, jax.Array]):
    return (state["c"], state["n"], state["m"], state["h"])


def _slstm_forward(lp: Params, hn: jax.Array, *, cfg: ModelConfig, **_):
    return xl.slstm_apply_scan(lp["slstm"], hn, cfg.num_heads)[0]


def _slstm_prefill_full(lp: Params, hn: jax.Array, *, cfg: ModelConfig, **_):
    o, (c, n, m, h) = xl.slstm_apply_scan(lp["slstm"], hn, cfg.num_heads)
    return o, {"c": c, "n": n, "m": m, "h": h}


def _slstm_prefill_chunk(lp: Params, state: Dict[str, jax.Array],
                         hn: jax.Array, *, cfg: ModelConfig,
                         pos: jax.Array, length: jax.Array,
                         valid_tok: jax.Array, **_):
    fresh = (pos == 0) & (length > 0)
    st = (
        _lane_select(jnp.zeros_like(state["c"]), state["c"], fresh),
        _lane_select(jnp.zeros_like(state["n"]), state["n"], fresh),
        _lane_select(jnp.full_like(state["m"], -1e30), state["m"], fresh),
        _lane_select(jnp.zeros_like(state["h"]), state["h"], fresh),
    )
    # pad positions reselect the carried state inside the scan, so a
    # zero-length lane is bit-identical by construction
    o, (c, n, m, h) = xl.slstm_apply_scan(lp["slstm"], hn, cfg.num_heads,
                                          state=st, valid=valid_tok)
    act = length > 0
    return o, {"c": _lane_select(c, state["c"], act),
               "n": _lane_select(n, state["n"], act),
               "m": _lane_select(m, state["m"], act),
               "h": _lane_select(h, state["h"], act)}


def _slstm_decode_step(lp: Params, state: Dict[str, jax.Array],
                       hn: jax.Array, *, cfg: ModelConfig,
                       pos: jax.Array, **_):
    o, (c, n, m, h) = xl.slstm_decode_step(lp["slstm"], hn,
                                           _slstm_state_tuple(state),
                                           cfg.num_heads)
    act = pos >= 0
    return o, {"c": _lane_select(c, state["c"], act),
               "n": _lane_select(n, state["n"], act),
               "m": _lane_select(m, state["m"], act),
               "h": _lane_select(h, state["h"], act)}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def _no_pages(cfg: ModelConfig, seg: SegmentSpec, pages: int,
              page_size: int, dtype, a3: bool,
              kv_quant: str = "none") -> None:
    """Recurrent kinds keep no per-token pages: their decode state is a
    fixed-size carry, snapshotted per page boundary instead."""
    return None


def _carry_snapshot(state: Dict[str, jax.Array], si: jax.Array
                    ) -> Dict[str, jax.Array]:
    """Per-lane boundary snapshot: the chunked-prefill carry itself.
    Every recurrent state leaf is [L, B, ...]; slice lane ``si``."""
    return {k: jax.lax.dynamic_slice_in_dim(v, si, 1, axis=1)
            for k, v in state.items()}


def _carry_restore(state: Dict[str, jax.Array],
                   snap: Dict[str, jax.Array], si: jax.Array
                   ) -> Dict[str, jax.Array]:
    """Replay a boundary snapshot into lane ``si`` (warm admission)."""
    return {k: v.at[:, si].set(snap[k][:, 0]) for k, v in state.items()}


def _snapshot_dump(snap: Dict[str, jax.Array]) -> Dict[str, np.ndarray]:
    """Serialize a boundary snapshot to host numpy for the durable page
    store / engine checkpoint (dtype- and bit-exact: float leaves round-
    trip unchanged, so a promoted or restored carry replays the same
    tokens). Per-kind mixers with non-array snapshot state override
    this pair."""
    return {k: np.asarray(v) for k, v in snap.items()}


def _snapshot_load(host: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
    """Rehydrate a dumped snapshot to device arrays (L2 promotion /
    checkpoint restore)."""
    return {k: jnp.asarray(v) for k, v in host.items()}


@dataclasses.dataclass(frozen=True)
class SegmentMixer:
    """The per-kind mixer-state interface (see module docstring)."""
    init_state: Callable[..., Dict[str, jax.Array]]
    forward: Callable[..., jax.Array]
    prefill_full: Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]
    prefill_chunk: Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]
    decode_step: Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]
    # paged prefix-cache hooks (repro.serve.prefix_cache)
    init_pages: Callable[..., Optional[Dict[str, jax.Array]]] = _no_pages
    write_page: Optional[Callable[..., Dict[str, jax.Array]]] = None
    gather_pages: Optional[Callable[..., Dict[str, jax.Array]]] = None
    snapshot_state: Callable[..., Dict[str, jax.Array]] = _carry_snapshot
    restore_state: Callable[..., Dict[str, jax.Array]] = _carry_restore
    # durable-state hooks (repro.serve.page_store): snapshot <-> host
    # bytes for the L2 tier and the engine checkpoint
    dump_snapshot: Callable[..., Dict[str, np.ndarray]] = _snapshot_dump
    load_snapshot: Callable[..., Dict[str, jax.Array]] = _snapshot_load


MIXERS: Dict[BlockKind, SegmentMixer] = {
    BlockKind.ATTENTION: SegmentMixer(
        _attn_init_state, _attn_forward, _attn_prefill_full,
        _attn_prefill_chunk, _attn_decode_step,
        init_pages=_attn_init_pages, write_page=_attn_write_page,
        gather_pages=_attn_gather_pages, snapshot_state=_attn_snapshot,
        restore_state=_attn_restore),
    BlockKind.RGLRU: SegmentMixer(
        _rglru_init_state, _rglru_forward, _rglru_prefill_full,
        _rglru_prefill_chunk, _rglru_decode_step),
    BlockKind.MLSTM: SegmentMixer(
        _mlstm_init_state, _mlstm_forward, _mlstm_prefill_full,
        _mlstm_prefill_chunk, _mlstm_decode_step),
    BlockKind.SLSTM: SegmentMixer(
        _slstm_init_state, _slstm_forward, _slstm_prefill_full,
        _slstm_prefill_chunk, _slstm_decode_step),
}
