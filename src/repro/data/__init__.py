from repro.data.synthetic import SyntheticLM, make_lm_batch
from repro.data.babi import BabiTask, generate_babi

__all__ = ["SyntheticLM", "make_lm_batch", "BabiTask", "generate_babi"]
