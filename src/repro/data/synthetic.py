"""Synthetic LM data pipeline.

A deterministic, seekable token stream (Zipf-distributed unigrams mixed
with short learnable n-gram motifs so loss actually falls during the
example training runs), plus a host-side prefetching iterator that
mirrors a production input pipeline: the generator thread produces numpy
batches while the device works on the previous step.

``make_lm_batch`` is the pure stateless entry used by tests and the
dry-run; ``SyntheticLM`` is the stateful prefetching pipeline used by the
training loop (checkpointable via its ``state`` property).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


def _motif_table(vocab: int, n_motifs: int, motif_len: int,
                 seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(n_motifs, motif_len), dtype=np.int32)


def make_lm_batch(step: int, batch: int, seq_len: int, vocab: int,
                  seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic batch for ``step`` (restart-safe: same step -> same
    batch). tokens[t+1] is the label for tokens[t]."""
    rng = np.random.default_rng((seed, step))
    # Zipf base stream (clipped to vocab)
    base = rng.zipf(1.3, size=(batch, seq_len + 1)).astype(np.int64)
    base = np.minimum(base - 1, vocab - 1).astype(np.int32)
    # overwrite random spans with motifs => predictable structure
    motifs = _motif_table(vocab, 64, 8, seed)
    n_spans = max(1, seq_len // 64)
    for b in range(batch):
        starts = rng.integers(0, seq_len - 8, size=n_spans)
        ids = rng.integers(0, len(motifs), size=n_spans)
        for s, mid in zip(starts, ids):
            base[b, s:s + 8] = motifs[mid]
    return {"tokens": base[:, :-1], "labels": base[:, 1:]}


class SyntheticLM:
    """Host-prefetching synthetic LM pipeline.

    Double-buffered: a daemon thread keeps ``prefetch`` batches ready.
    ``state``/``restore`` give step-accurate restart (fault tolerance).
    """

    def __init__(self, batch: int, seq_len: int, vocab: int, seed: int = 0,
                 prefetch: int = 2, start_step: int = 0):
        self.batch, self.seq_len, self.vocab = batch, seq_len, vocab
        self.seed = seed
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            b = make_lm_batch(step, self.batch, self.seq_len, self.vocab,
                              self.seed)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, b = self._q.get()
        self._step = step + 1
        return b

    @property
    def state(self) -> Dict[str, int]:
        return {"step": self._step, "seed": self.seed}

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    @staticmethod
    def restore(state: Dict[str, int], batch: int, seq_len: int,
                vocab: int, **kw) -> "SyntheticLM":
        return SyntheticLM(batch, seq_len, vocab, seed=state["seed"],
                           start_step=state["step"], **kw)
