"""Synthetic bAbI-style QA generator (paper SSVI-A workload shape).

Task family mirrors bAbI task 1 ("single supporting fact"): a story of
"<actor> moved to <place>." statements followed by "Where is <actor>?".
The answer is the most recent place for that actor — exactly the
content-based retrieval the attention hop must learn, and the setting of
the paper's Figure 2 example.

Vocabulary layout: 0 = PAD, then actors, places, verbs, question words.
Everything is already tokenized (ints); no text processing needed.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import numpy as np


class BabiTask(NamedTuple):
    vocab_size: int
    num_actors: int
    num_places: int
    max_sentences: int
    max_words: int
    answer_offset: int          # token id of place 0 (answers are places)


def make_task(num_actors: int = 12, num_places: int = 12,
              max_sentences: int = 50, max_words: int = 8) -> BabiTask:
    # 0=PAD, 1..A actors, A+1..A+P places, then 4 verbs + 2 question words
    vocab = 1 + num_actors + num_places + 6
    return BabiTask(vocab, num_actors, num_places, max_sentences, max_words,
                    answer_offset=1 + num_actors)


def generate_babi(task: BabiTask, batch: int, num_statements: int,
                  seed: int = 0) -> Dict[str, np.ndarray]:
    """Returns sentences [B, n, J], question [B, J], answer [B] (token id).

    ``num_statements`` <= task.max_sentences controls n — the paper's
    search-set size knob.
    """
    assert num_statements <= task.max_sentences
    rng = np.random.default_rng(seed)
    A, P = task.num_actors, task.num_places
    verb0 = 1 + A + P                       # 4 verbs: moved/went/ran/walked
    q_who = verb0 + 4                       # "where"
    q_is = verb0 + 5                        # "is"

    sentences = np.zeros((batch, task.max_sentences, task.max_words),
                         np.int32)
    question = np.zeros((batch, task.max_words), np.int32)
    answer = np.zeros((batch,), np.int32)

    unique = A >= num_statements
    for b in range(batch):
        last_place = {}
        # unique actors (paper Fig. 2 setting: pure content lookup) when
        # the vocabulary allows; otherwise repeats (requires the temporal
        # encoding to resolve "most recent")
        if unique:
            actors = rng.choice(A, size=num_statements, replace=False)
        else:
            actors = rng.integers(0, A, size=num_statements)
        for s in range(num_statements):
            actor = int(actors[s])
            place = int(rng.integers(0, P))
            verb = int(rng.integers(0, 4))
            sentences[b, s, 0] = 1 + actor
            sentences[b, s, 1] = verb0 + verb
            sentences[b, s, 2] = task.answer_offset + place
            last_place[actor] = place
        # ask about an actor that appeared
        actor = int(rng.choice(list(last_place.keys())))
        question[b, 0] = q_who
        question[b, 1] = q_is
        question[b, 2] = 1 + actor
        answer[b] = task.answer_offset + last_place[actor]
    return {"sentences": sentences, "question": question, "answer": answer}
