import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory/cost/roofline from the compiled
artifact. No real buffers are allocated (ShapeDtypeStruct stand-ins).

The two lines above MUST stay first: jax locks the device count on first
initialization, and the production meshes need 512 placeholder devices.

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --arch all [--multipod] [--out results.json]
  python -m repro.launch.dryrun --list
"""
import argparse
import gc
import json
import sys
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (
    A3Config,
    A3Mode,
    ModelConfig,
    RunConfig,
    SHAPE_SUITE,
    ShapeConfig,
    ShapeKind,
    ShardingConfig,
    applicable_shapes,
    get_arch,
    list_archs,
)
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline
from repro.models import decoder
from repro.sharding.rules import batch_spec, cache_specs, param_specs, \
    shardings_for
from repro.train.step import init_train_state_shape, make_train_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, weak-type-correct, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs for one step of the given shape kind."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == ShapeKind.TRAIN:
        if cfg.frontend:
            return {
                "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    if shape.kind == ShapeKind.PREFILL:
        if cfg.frontend:
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    # DECODE: one new token against a cache of length s. ``pos`` is the
    # per-slot position vector [B] — the sharded path lowers the same
    # ragged continuous-batching dispatch the single-host engine runs,
    # not a scalar-position special case.
    if cfg.frontend:
        tok = {"embed": jax.ShapeDtypeStruct((b, cfg.d_model), dt)}
    else:
        tok = {"token": jax.ShapeDtypeStruct((b,), jnp.int32)}
    return {**tok, "pos": jax.ShapeDtypeStruct((b,), jnp.int32)}


def model_flops_for(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == ShapeKind.TRAIN:
        return 6.0 * n_active * shape.tokens_per_step
    return 2.0 * n_active * shape.tokens_per_step


# ---------------------------------------------------------------------------
# per-kind lowering
# ---------------------------------------------------------------------------

def lower_train(cfg: ModelConfig, shape: ShapeConfig, mesh,
                sharding_cfg: ShardingConfig):
    run = RunConfig(model=cfg, shape=shape, sharding=sharding_cfg)
    step = make_train_step(run, mesh, donate=False)
    state_shape = init_train_state_shape(run)
    return step.lower(state_shape, input_specs(cfg, shape))


def lower_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh,
                  sharding_cfg: ShardingConfig):
    from repro.models.common import activation_shardings
    from repro.sharding.rules import act_specs
    params_shape = decoder.init_params_shape(cfg)
    pspecs = shardings_for(param_specs(params_shape, sharding_cfg, mesh),
                           mesh)
    bs = batch_spec(shape, mesh, sharding_cfg)
    a_specs = act_specs(cfg, shape, mesh, sharding_cfg)
    spec = input_specs(cfg, shape)

    if cfg.frontend:
        bspec = NamedSharding(mesh, P(*bs, None))
        def fn(params, embeds):
            with activation_shardings(a_specs):
                return decoder.prefill(params, cfg, inputs_embeds=embeds)
        jf = jax.jit(fn, in_shardings=(pspecs, bspec))
        return jf.lower(params_shape, spec["embeds"])

    bspec = NamedSharding(mesh, bs)
    def fn(params, tokens):
        with activation_shardings(a_specs):
            return decoder.prefill(params, cfg, tokens)
    jf = jax.jit(fn, in_shardings=(pspecs, bspec))
    return jf.lower(params_shape, spec["tokens"])


def lower_decode(cfg: ModelConfig, shape: ShapeConfig, mesh,
                 sharding_cfg: ShardingConfig,
                 a3: A3Config = A3Config()):
    """Lower the ragged decode dispatch: per-slot pos vector [B] and a
    donated KV cache, exactly as the serving engine dispatches it."""
    from repro.models.common import activation_shardings
    from repro.sharding.rules import act_specs
    params_shape = decoder.init_params_shape(cfg)
    pspecs = shardings_for(param_specs(params_shape, sharding_cfg, mesh),
                           mesh)
    cache_shape = jax.eval_shape(
        lambda: decoder.init_cache(cfg, shape.global_batch, shape.seq_len,
                                   a3=a3.mode != A3Mode.OFF))
    cspecs = shardings_for(cache_specs(cache_shape, shape, mesh, sharding_cfg), mesh)
    a_specs = act_specs(cfg, shape, mesh, sharding_cfg)
    spec = input_specs(cfg, shape)
    rep = NamedSharding(mesh, P())

    if cfg.frontend:
        def fn(params, cache, embed, pos):
            with activation_shardings(a_specs):
                return decoder.decode_step(params, cfg, cache, None, pos,
                                           input_embed=embed, a3=a3)
        jf = jax.jit(fn, in_shardings=(pspecs, cspecs, rep, rep),
                     out_shardings=(None, cspecs), donate_argnums=(1,))
        return jf.lower(params_shape, cache_shape, spec["embed"],
                        spec["pos"])

    def fn(params, cache, token, pos):
        with activation_shardings(a_specs):
            return decoder.decode_step(params, cfg, cache, token, pos,
                                       a3=a3)
    jf = jax.jit(fn, in_shardings=(pspecs, cspecs, rep, rep),
                 out_shardings=(None, cspecs), donate_argnums=(1,))
    return jf.lower(params_shape, cache_shape, spec["token"], spec["pos"])


def lower_decode_block(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       sharding_cfg: ShardingConfig, *,
                       steps: int = 8, a3: A3Config = A3Config(),
                       resort_every: int = 64):
    """Lower the multi-step scanned decode dispatch: ``steps`` decode
    iterations per dispatch under one ``lax.scan`` with in-graph greedy
    sampling and (A^3) in-graph re-sort — the serving engine's blocked
    inner loop, with per-lane ``steps_left`` masking and a donated
    cache, on the production mesh. Returns the [B, steps] token ring,
    the [B] final-token carry (the device-resident value the pipelined
    engine feeds to the next block's dispatch), plus the updated
    cache."""
    from repro.models.common import activation_shardings
    from repro.sharding.rules import act_specs
    if cfg.frontend:
        raise ValueError(f"{cfg.name}: blocked decode feeds sampled token "
                         "ids back in-graph; frontend archs decode "
                         "single-step from precomputed embeddings")
    params_shape = decoder.init_params_shape(cfg)
    pspecs = shardings_for(param_specs(params_shape, sharding_cfg, mesh),
                           mesh)
    use_a3 = a3.mode != A3Mode.OFF
    cache_shape = jax.eval_shape(
        lambda: decoder.init_cache(cfg, shape.global_batch, shape.seq_len,
                                   a3=use_a3))
    cspecs = shardings_for(cache_specs(cache_shape, shape, mesh,
                                       sharding_cfg), mesh)
    a_specs = act_specs(cfg, shape, mesh, sharding_cfg)
    rep = NamedSharding(mesh, P())

    def fn(params, cache, token, pos, steps_left):
        with activation_shardings(a_specs):
            return decoder.decode_block(
                params, cfg, cache, token, pos, steps_left, steps=steps,
                a3=a3, resort_every=resort_every if use_a3 else 0)

    jf = jax.jit(fn, in_shardings=(pspecs, cspecs, rep, rep, rep),
                 out_shardings=(None, None, cspecs), donate_argnums=(1,))
    vec = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    return jf.lower(params_shape, cache_shape, vec, vec, vec)


def lower_prefill_chunk(cfg: ModelConfig, shape: ShapeConfig, mesh,
                        sharding_cfg: ShardingConfig, *,
                        chunk: int = 256, a3: A3Config = A3Config()):
    """Lower the ragged admission-prefill dispatch: a padded [B, chunk]
    token block extends the per-slot caches from per-slot start
    positions (pos [B], length [B]) — the third serving dispatch next to
    prefill/decode, sharded over the same cache specs."""
    from repro.models.common import activation_shardings
    from repro.sharding.rules import act_specs
    if cfg.frontend:
        raise ValueError(f"{cfg.name}: chunked admission prefill takes "
                         "token prompts; frontend archs admit whole-prompt")
    params_shape = decoder.init_params_shape(cfg)
    pspecs = shardings_for(param_specs(params_shape, sharding_cfg, mesh),
                           mesh)
    use_a3 = a3.mode != A3Mode.OFF
    cache_shape = jax.eval_shape(
        lambda: decoder.init_cache(cfg, shape.global_batch, shape.seq_len,
                                   a3=use_a3))
    cspecs = shardings_for(cache_specs(cache_shape, shape, mesh,
                                       sharding_cfg), mesh)
    a_specs = act_specs(cfg, shape, mesh, sharding_cfg)
    rep = NamedSharding(mesh, P())

    def fn(params, cache, tokens, pos, length):
        with activation_shardings(a_specs):
            return decoder.prefill_chunk(params, cfg, cache, tokens, pos,
                                         length, a3=use_a3)

    jf = jax.jit(fn, in_shardings=(pspecs, cspecs, rep, rep, rep),
                 out_shardings=(None, cspecs), donate_argnums=(1,))
    b = shape.global_batch
    tok = jax.ShapeDtypeStruct((b, chunk), jnp.int32)
    vec = jax.ShapeDtypeStruct((b,), jnp.int32)
    return jf.lower(params_shape, cache_shape, tok, vec, vec)


def lower_gather_pages(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       sharding_cfg: ShardingConfig, *,
                       page_size: int = 64, pages: int = 4096,
                       a3: A3Config = A3Config(),
                       kv_quant: str = "none"):
    """Lower the prefix-cache warm-admission *gather* dispatch — the
    ONE jitted copy a warm admission pays instead of re-prefilling the
    matched prefix — on the production mesh with the slot cache donated
    and the pool sharded like the rings. The graph is the engine's own
    ``serve.prefix_cache.gather_fn`` (shared, so the lowered cell can
    never drift from what serving dispatches); it is lowered on the
    no-donor path (``sk_snaps = {}``: A^3 sorted columns re-derived by
    the in-graph comprehension sort of the gathered ring)."""
    import functools
    from repro.config import BlockKind
    from repro.models.mixer import build_segments, cache_len_for
    from repro.serve.prefix_cache import gather_fn
    if cfg.frontend:
        raise ValueError(f"{cfg.name}: the prefix cache reuses token "
                         "prompts; frontend archs admit whole-prompt")
    use_a3 = a3.mode != A3Mode.OFF
    b, s = shape.global_batch, shape.seq_len
    segs = build_segments(cfg)
    cache_shape = jax.eval_shape(
        lambda: decoder.init_cache(cfg, b, s, a3=use_a3))
    pool_shape = jax.eval_shape(
        lambda: decoder.init_page_pool(cfg, pages, page_size, a3=use_a3,
                                       kv_quant=kv_quant))
    cspecs = shardings_for(cache_specs(cache_shape, shape, mesh,
                                       sharding_cfg), mesh)
    # pool leaves are [L, pages, Hkv, page_size, hd] — the same 5-dim
    # layout as the rings with the page axis in the batch position, so
    # the cache rules shard them (pages over dp, page rows over model);
    # int8 pools add fp32 scale leaves [L, pages, Hkv, 1, 1], still
    # 5-dim so the same rules apply (w=1 keeps them off the ring axis)
    pspecs = shardings_for(cache_specs(pool_shape, shape, mesh,
                                       sharding_cfg), mesh)
    rep = NamedSharding(mesh, P())

    idx_shape = {}
    snaps_shape = {}
    for i, seg in enumerate(segs):
        name = f"seg{i}"
        if seg.kind == BlockKind.ATTENTION:
            w = cache_len_for(seg, s)
            idx_shape[name] = {
                "page": jax.ShapeDtypeStruct((w,), jnp.int32),
                "off": jax.ShapeDtypeStruct((w,), jnp.int32),
                "valid": jax.ShapeDtypeStruct((w,), jnp.bool_),
            }
        else:
            snaps_shape[name] = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(
                    (l.shape[0], 1) + l.shape[2:], l.dtype),
                cache_shape[name])

    fn = functools.partial(gather_fn, segs, use_a3)
    jf = jax.jit(fn,
                 in_shardings=(cspecs, pspecs, rep, rep, rep, rep, rep),
                 out_shardings=cspecs, donate_argnums=(0,))
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    return jf.lower(cache_shape, pool_shape, scalar, scalar, idx_shape,
                    snaps_shape, {})


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             sharding_cfg: Optional[ShardingConfig] = None,
             a3: A3Config = A3Config(),
             prefill_chunk: Optional[int] = None,
             decode_block: Optional[int] = None,
             gather_pages: Optional[int] = None,
             page_size: int = 64,
             kv_quant: str = "none",
             verbose: bool = True,
             save_hlo_dir: Optional[str] = None) -> Dict[str, Any]:
    cfg = get_arch(arch)
    shape = SHAPE_SUITE[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.devices.size
    if sharding_cfg is None:
        sharding_cfg = ShardingConfig(
            remat="full" if shape.kind == ShapeKind.TRAIN else "none")

    t0 = time.time()
    with mesh:
        if shape.kind == ShapeKind.TRAIN:
            lowered = lower_train(cfg, shape, mesh, sharding_cfg)
        elif shape.kind == ShapeKind.PREFILL:
            # chunked admission covers every token arch (the mixer-state
            # interface carries recurrent mid-prompt state); frontend
            # archs admit whole-prompt from precomputed embeddings
            chunkable = bool(prefill_chunk) and not cfg.frontend
            if prefill_chunk and not chunkable and verbose:
                print(f"  {arch}: chunked admission takes token prompts; "
                      f"lowering whole-prompt (embeds) prefill")
            if gather_pages and not cfg.frontend:
                # the prefix-cache warm-admission copy dispatch
                lowered = lower_gather_pages(cfg, shape, mesh,
                                             sharding_cfg,
                                             page_size=page_size,
                                             pages=gather_pages, a3=a3,
                                             kv_quant=kv_quant)
            elif chunkable:
                lowered = lower_prefill_chunk(cfg, shape, mesh,
                                              sharding_cfg,
                                              chunk=prefill_chunk, a3=a3)
            else:
                lowered = lower_prefill(cfg, shape, mesh, sharding_cfg)
        else:
            blockable = bool(decode_block) and decode_block > 1 \
                and not cfg.frontend
            if decode_block and decode_block > 1 and cfg.frontend \
                    and verbose:
                print(f"  {arch}: blocked decode unsupported (frontend "
                      f"embeds); lowering single-step decode")
            if blockable:
                lowered = lower_decode_block(cfg, shape, mesh,
                                             sharding_cfg,
                                             steps=decode_block, a3=a3)
            else:
                lowered = lower_decode(cfg, shape, mesh, sharding_cfg, a3)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    if save_hlo_dir:
        import gzip
        import os as _os
        _os.makedirs(save_hlo_dir, exist_ok=True)
        fn = f"{arch}_{shape_name}_{mesh_name}"
        if a3.mode.value != "off":
            fn += f"_a3-{a3.mode.value}"
        with gzip.open(_os.path.join(save_hlo_dir, fn + ".hlo.gz"),
                       "wt") as f:
            f.write(hlo_text)
    r = roofline.analyze(arch, shape_name, mesh_name, chips, compiled,
                         model_flops_for(cfg, shape), hlo_text=hlo_text)
    rec = {
        **r.to_dict(),
        "a3_mode": a3.mode.value,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_device_bytes": (mem.argument_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  + mem.output_size_in_bytes
                                  - mem.alias_size_in_bytes),
        },
        "lower_s": t_lower,
        "compile_s": t_compile,
    }
    if verbose:
        print(f"[{mesh_name}] {arch} x {shape_name}: "
              f"compute={r.compute_s*1e3:.2f}ms memory={r.memory_s*1e3:.2f}ms "
              f"collective={r.collective_s*1e3:.2f}ms "
              f"bottleneck={r.bottleneck} "
              f"peak_dev={rec['memory']['peak_device_bytes']/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
        sys.stdout.flush()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--a3", default="off",
                    choices=["off", "conservative", "aggressive"])
    ap.add_argument("--select-shards", type=int, default=16,
                    help="A3 distributed-selection blocks (align with the "
                         "sharded ring: 16 = model axis, 256 = full grid)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="lower prefill cells as the chunked ragged "
                         "admission-prefill dispatch with this chunk "
                         "size (0 = whole-prompt prefill)")
    ap.add_argument("--decode-block", type=int, default=0,
                    help="lower decode cells as the multi-step scanned "
                         "decode dispatch with this many steps per block "
                         "(in-graph sampling + A^3 re-sort; 0/1 = "
                         "single-step decode)")
    ap.add_argument("--gather-pages", type=int, default=0,
                    help="lower prefill cells as the prefix-cache "
                         "warm-admission gather dispatch against a pool "
                         "of this many pages (0 = normal prefill cell)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="prefix-cache page size for --gather-pages")
    ap.add_argument("--kv-quant", default="none",
                    choices=["none", "int8"],
                    help="pool precision for --gather-pages: int8 "
                         "lowers the gather against an int8 page pool "
                         "with per-page fp32 scales (dequantize fused "
                         "into the copy dispatch)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--save-hlo", default=None,
                    help="directory for gzipped per-cell compiled HLO")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for a in list_archs():
            print(a, "->", ", ".join(applicable_shapes(a)))
        return

    import dataclasses as _dc
    a3 = {"off": A3Config(),
          "conservative": A3Config.conservative(),
          "aggressive": A3Config.aggressive()}[args.a3]
    if a3.mode != A3Mode.OFF:
        # distributed selection aligned with the sharded KV ring
        a3 = _dc.replace(a3, select_shards=args.select_shards)

    archs = list_archs() if args.arch == "all" else [args.arch]
    results = []
    meshes = [False, True] if args.both_meshes else [args.multipod]
    for arch in archs:
        shapes = (applicable_shapes(arch) if args.shape == "all"
                  else [args.shape])
        for shape_name in shapes:
            if shape_name not in applicable_shapes(arch):
                print(f"SKIP {arch} x {shape_name} (inapplicable; "
                      f"see DESIGN.md SS6)")
                continue
            for mp in meshes:
                try:
                    results.append(run_cell(
                        arch, shape_name, multi_pod=mp, a3=a3,
                        prefill_chunk=args.prefill_chunk or None,
                        decode_block=args.decode_block or None,
                        gather_pages=args.gather_pages or None,
                        page_size=args.page_size,
                        kv_quant=args.kv_quant,
                        save_hlo_dir=args.save_hlo))
                except Exception as e:   # noqa: BLE001
                    print(f"FAIL {arch} x {shape_name} "
                          f"({'2x16x16' if mp else '16x16'}): {e!r}")
                    results.append({"arch": arch, "shape": shape_name,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "error": repr(e)})
                gc.collect()

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {len(results)} records to {args.out}")
    n_fail = sum(1 for r in results if "error" in r)
    print(f"dry-run: {len(results) - n_fail}/{len(results)} cells OK")
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
