"""Training launcher.

Single-host example (the container): trains a reduced-config model on
the local device with the full production stack (config registry, data
pipeline, checkpointing, watchdog, restart supervisor).

On a real cluster every host runs this same entrypoint;
``jax.distributed.initialize`` picks up the coordinator from the
environment, the mesh comes from ``make_production_mesh``, and the
GSPMD program is identical — that is exactly what the dry-run compiles.

  python -m repro.launch.train --arch internlm2-1.8b --smoke \
      --steps 50 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import os
import time

import jax

from repro.config import (
    CheckpointConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    ShapeKind,
    ShardingConfig,
    get_arch,
    list_archs,
    smoke_variant,
)
from repro.train.loop import train_with_recovery


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--remat", default="none",
                    choices=["none", "dots", "full"])
    ap.add_argument("--distributed", action="store_true",
                    help="multi-host: jax.distributed.initialize()")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    shape = ShapeConfig("cli", ShapeKind.TRAIN, args.seq, args.batch)
    run = RunConfig(
        model=cfg, shape=shape,
        optimizer=OptimizerConfig(lr=args.lr, total_steps=args.steps,
                                  warmup_steps=max(1, args.steps // 10)),
        sharding=ShardingConfig(remat=args.remat),
        checkpoint=CheckpointConfig(directory=args.ckpt_dir,
                                    save_every=args.save_every),
    )

    t0 = time.time()
    out = train_with_recovery(run, num_steps=args.steps)
    dt = time.time() - t0
    losses = out["losses"]
    toks = shape.tokens_per_step * len(losses)
    print(f"arch={cfg.name} steps={len(losses)} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({toks / dt:.0f} tok/s, {dt:.1f}s, restarts={out['restarts']})")


if __name__ == "__main__":
    main()
