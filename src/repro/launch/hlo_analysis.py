"""Backend-independent HLO accounting for the dry-run roofline.

``compiled.cost_analysis()`` on the CPU backend under-counts dot FLOPs
(library-call dots report 0) and says nothing about collectives, so we
parse the compiled HLO text ourselves:

  * build the computation call graph (while bodies/conds, fusions,
    calls, conditionals) and propagate execution multipliers — a while
    whose condition compares the induction variable against
    ``constant(N)`` executes its body N times (the layer-stack scan);
  * count dot FLOPs as 2 x prod(result dims) x prod(contracting dims),
    scaled by the computation's multiplier;
  * sum collective operand bytes (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), same scaling.

Everything works on one per-device SPMD program: numbers are
*per-device* by construction.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
# header: "%name (args...) -> type {"  — args may contain nested parens
# (tuple types), so just grab the name and require "->" + trailing "{".
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype,
                        [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class HloStats:
    dot_flops: float
    collective_bytes: Dict[str, float]
    collective_wire_bytes: float
    collective_counts: Dict[str, int]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


class HloModule:
    """Parsed (textual) HLO module with execution-count propagation."""

    def __init__(self, text: str):
        self._fusion_cache: Dict[str, Optional[Tuple[float, float]]] = {}
        self.computations: Dict[str, List[str]] = {}
        cur, lines = None, []
        for line in text.splitlines():
            m = _COMP_HDR_RE.match(line)
            if (m and "->" in line and line.rstrip().endswith("{")
                    and "=" not in line.split("(")[0]):
                if cur is not None:
                    self.computations[cur] = lines
                cur, lines = m.group(1), []
            elif cur is not None:
                lines.append(line)
        if cur is not None:
            self.computations[cur] = lines

        # name -> result type string (for operand byte lookup)
        self.result_type: Dict[str, str] = {}
        # computations that are fusion bodies (excluded from byte walk)
        self.fusion_bodies: set = set()
        # call graph edges: (caller, callee, multiplier_per_call)
        edges: List[Tuple[str, str, float]] = []
        for comp, clines in self.computations.items():
            for line in clines:
                m = _DEF_RE.match(line)
                if not m:
                    continue
                name, rhs = m.group(1), m.group(2)
                self.result_type[name] = rhs.split("(")[0]
                if re.search(r"\bfusion\(", rhs):
                    for callee in re.findall(r"calls=%?([\w.\-]+)", rhs):
                        self.fusion_bodies.add(callee)
                if re.search(r"\bwhile\(", rhs):
                    cm = re.search(r"condition=%?([\w.\-]+)", rhs)
                    bm = re.search(r"body=%?([\w.\-]+)", rhs)
                    if cm and bm:
                        trip = self._trip_count(cm.group(1))
                        edges.append((comp, bm.group(1), float(trip)))
                        edges.append((comp, cm.group(1), float(trip + 1)))
                for attr in ("calls", "to_apply", "true_computation",
                             "false_computation", "branch_computations"):
                    for callee in re.findall(
                            attr + r"=\{?%?([\w.\-]+)", rhs):
                        edges.append((comp, callee, 1.0))

        # propagate multipliers from ENTRY (first computation w/ ENTRY or
        # assume any computation not referenced as callee is a root)
        callees = {c for _, c, _ in edges}
        roots = [c for c in self.computations if c not in callees]
        self.mult: Dict[str, float] = defaultdict(float)
        for r in roots:
            self.mult[r] = 1.0
        for _ in range(32):
            changed = False
            new = defaultdict(float)
            for r in roots:
                new[r] = 1.0
            for caller, callee, k in edges:
                new[callee] += self.mult[caller] * k
            if dict(new) != dict(self.mult):
                self.mult = new
                changed = True
            if not changed:
                break

    def _trip_count(self, cond: str) -> int:
        """Trip count of a while loop from its condition computation:
        resolve the scalar constant operand of the ROOT compare (the
        bound the induction variable is checked against). Falls back to
        the max scalar constant in the computation."""
        lines = self.computations.get(cond, ())
        consts: Dict[str, int] = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            cm = re.match(r"s32\[\]\s.*constant\((\d+)\)", rhs)
            if cm:
                consts[name] = int(cm.group(1))
        for line in lines:
            m = _DEF_RE.match(line)
            if not m or "compare(" not in m.group(2):
                continue
            rhs = m.group(2)
            inline = re.findall(r"constant\((\d+)\)", rhs)
            if inline:
                return max(int(c) for c in inline)
            args = rhs.split("compare(")[1].split(")")[0]
            ops = re.findall(r"%?([\w.\-]+)", args)
            vals = [consts[o] for o in ops if o in consts]
            if vals:
                return max(vals)
        return max(consts.values(), default=1)

    # -- dot flops -----------------------------------------------------------
    def dot_flops(self) -> float:
        total = 0.0
        for comp, clines in self.computations.items():
            k = self.mult.get(comp, 0.0)
            if k == 0.0:
                continue
            for line in clines:
                m = _DEF_RE.match(line)
                if not m:
                    continue
                rhs = m.group(2)
                dm = re.search(r"\bdot\(", rhs)
                if not dm:
                    continue
                shapes = _shape_dims(rhs.split("(")[0])
                if not shapes:
                    continue
                _, rdims = shapes[0]
                out_elems = 1
                for d in rdims:
                    out_elems *= d
                # contracting size from the lhs operand + dims attribute.
                # Newer HLO prints operand types inline
                # (``dot(f32[64,128]{1,0} %lhs, ...)``) — prefer those;
                # fall back to the named operand's recorded result type.
                args = rhs[dm.end():].split(")")[0]
                lhs_shapes = _shape_dims(args)
                if not lhs_shapes:
                    ops = re.findall(r"%?([\w.\-]+)", args)
                    lhs_t = self.result_type.get(ops[0], "") if ops else ""
                    lhs_shapes = _shape_dims(lhs_t)
                cdim = re.search(r"lhs_contracting_dims=\{([0-9,]+)\}", rhs)
                csize = 1
                if lhs_shapes and cdim:
                    _, ldims = lhs_shapes[0]
                    for ci in cdim.group(1).split(","):
                        ci = int(ci)
                        if ci < len(ldims):
                            csize *= ldims[ci]
                total += k * 2.0 * out_elems * csize
        return total

    # -- collective bytes ------------------------------------------------------
    def collectives(self, default_ring: int = 16
                    ) -> Tuple[Dict[str, float], Dict[str, int], float]:
        op_bytes: Dict[str, float] = defaultdict(float)
        op_counts: Dict[str, int] = defaultdict(int)
        wire = 0.0
        for comp, clines in self.computations.items():
            k = self.mult.get(comp, 0.0)
            if k == 0.0:
                continue
            for line in clines:
                m = _DEF_RE.match(line)
                if not m:
                    continue
                rhs = m.group(2)
                opm = re.search(
                    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
                    r"collective-permute)(?:-start)?\(", rhs)
                if not opm:
                    continue
                op = opm.group(1)
                args = rhs[opm.end():]
                operands = re.findall(r"%?([\w.\-]+)", args.split(")")[0])
                b = sum(_shape_bytes(self.result_type.get(o, ""))
                        for o in operands)
                if b == 0:
                    b = _shape_bytes(rhs.split("(")[0])
                rg = re.search(r"replica_groups=\{\{([0-9,]+)\}", rhs)
                n = len(rg.group(1).split(",")) if rg else default_ring
                op_bytes[op] += k * b
                op_counts[op] += int(k) if k >= 1 else 1
                if op == "all-reduce":
                    wire += k * b * 2 * (n - 1) / max(n, 1)
                elif op in ("all-gather", "reduce-scatter"):
                    wire += k * b * (n - 1) / max(n, 1)
                else:
                    wire += k * b
        return dict(op_bytes), dict(op_counts), wire

    # -- approximate HBM traffic -----------------------------------------------
    _SKIP_OPS = ("parameter", "constant", "tuple(", "get-tuple-element",
                 "bitcast", "after-all", "partition-id", "replica-id")

    def _dus_update_bytes(self, fusion_body: str) -> Optional[int]:
        """If the fusion's ROOT is a dynamic-update-slice, return the
        update operand's byte size (the in-place write), else None."""
        for line in self.computations.get(fusion_body, ()):
            if "ROOT" not in line or "dynamic-update-slice(" not in line:
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            args = rhs.split("dynamic-update-slice(")[1].split(")")[0]
            ops = re.findall(r"%?([\w.\-]+)", args)
            if len(ops) >= 2:
                # operand 1 is the update; resolve within the body first
                upd = ops[1]
                t = self.result_type.get(upd, "")
                return _shape_bytes(t) if t else None
        return None

    def _fusion_bytes(self, body: str) -> Optional[Tuple[float, float]]:
        """(read_bytes, write_bytes) of one fusion execution, resolved
        from its body: parameters consumed only through dynamic-slice /
        gather count at the slice-result size (the loop-body pattern:
        'slice one timestep from the big scanned array'); the write side
        is the update size when the ROOT is a dynamic-update-slice.
        Cached per body."""
        if body in self._fusion_cache:
            return self._fusion_cache[body]
        lines = self.computations.get(body)
        if lines is None:
            return None
        param_full: Dict[str, int] = {}
        sliced_only: Dict[str, int] = {}
        used_dense: set = set()
        root_write: Optional[int] = None
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            head = rhs.split("(")[0]
            if re.search(r"\bparameter\(", rhs):
                param_full[name] = _shape_bytes(head)
                continue
            args = rhs[len(head):].split(")")[0]
            ops = re.findall(r"%?([\w.\-]+)", args)
            if "dynamic-update-slice(" in rhs:
                # operand 0 (the buffer) is aliased in place — neither
                # read nor written beyond the update region
                if "ROOT" in line:
                    if len(ops) >= 2 and ops[1] in param_full:
                        root_write = param_full[ops[1]]
                    else:
                        t = self.result_type.get(ops[1], "") \
                            if len(ops) > 1 else ""
                        root_write = _shape_bytes(t) if t else None
                for o in ops[1:]:
                    if o in param_full:
                        used_dense.add(o)
                continue
            is_slice = re.search(r"\b(dynamic-slice|gather)\(", rhs)
            for i, o in enumerate(ops):
                if o not in param_full:
                    continue
                if is_slice and i == 0:
                    sliced_only[o] = sliced_only.get(o, 0) + \
                        _shape_bytes(head)
                else:
                    used_dense.add(o)
        reads = 0.0
        for p, full in param_full.items():
            if p in used_dense or p not in sliced_only:
                reads += full if p in used_dense else 0.0
            else:
                reads += sliced_only[p]
        out = (reads, float(root_write) if root_write is not None else -1.0)
        self._fusion_cache[body] = out
        return out

    def hbm_bytes(self) -> float:
        """Approximate HBM traffic: operand + result bytes of every
        top-level op (fusion internals excluded — a fusion reads its
        params and writes its result once), scaled by execution count."""
        total = 0.0
        for comp, clines in self.computations.items():
            if comp in self.fusion_bodies:
                continue
            k = self.mult.get(comp, 0.0)
            if k == 0.0:
                continue
            for line in clines:
                m = _DEF_RE.match(line)
                if not m:
                    continue
                rhs = m.group(2)
                head = rhs.split("(")[0]
                body = rhs[len(head):]
                if any(s in rhs for s in self._SKIP_OPS) and not \
                        re.search(r"\b(dot|fusion|convolution|custom-call|"
                                  r"scatter|gather|while|reduce)\b", rhs):
                    continue
                b = _shape_bytes(head)                  # result bytes
                if re.search(r"\b(gather|dynamic-slice)\(", rhs):
                    # a gather/slice physically reads ~result bytes (+
                    # indices), not its full operand
                    total += k * 2 * b
                    continue
                fm = re.search(r"\bfusion\(.*calls=%?([\w.\-]+)", rhs)
                if fm:
                    fb = self._fusion_bytes(fm.group(1))
                    if fb is not None:
                        reads, write = fb
                        total += k * (reads + (write if write >= 0 else b))
                        continue
                ops = re.findall(r"%?([\w.\-]+)", body.split(")")[0])
                for o in ops:
                    b += _shape_bytes(self.result_type.get(o, ""))
                total += k * b
        return total

    def stats(self) -> HloStats:
        ob, oc, wire = self.collectives()
        return HloStats(self.dot_flops(), ob, wire, oc)


def analyze_hlo(text: str) -> HloStats:
    return HloModule(text).stats()
