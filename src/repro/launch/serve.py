"""Serving launcher: load (or init) a model, run batched requests
through the slot engine, optionally with A^3 approximation.

  python -m repro.launch.serve --arch phi4-mini-3.8b --smoke \
      --requests 8 --prompt-len 64 --max-new 32 --a3 conservative
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import time

import jax
import numpy as np

from repro.config import A3Config, ServeConfig, get_arch, smoke_variant
from repro.models import decoder
from repro.serve.chaos import ChaosConfig, ChaosInjector
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="admission-prefill chunk size in tokens (every "
                         "arch, incl. recurrent/hybrid stacks — the "
                         "mixer-state interface carries mid-prompt "
                         "state); 0 = default chunk of "
                         "min(max_len, 512)")
    ap.add_argument("--prefill-chunk-min", type=int, default=0,
                    help="adaptive admission chunking floor: ticks with "
                         ">= 1 decoding slot shrink the effective chunk "
                         "to this many tokens (cold queues drain at the "
                         "full chunk); 0 = fixed chunk")
    ap.add_argument("--page-size", type=int, default=64,
                    help="prefix-cache page granularity in tokens (trie "
                         "edge length)")
    ap.add_argument("--cache-pages", type=int, default=0,
                    help="paged prefix-cache budget (pages of "
                         "--page-size tokens; shared prompt prefixes "
                         "admit via one gather dispatch instead of "
                         "re-prefilling); 0 = disabled")
    ap.add_argument("--kv-quant", default="none",
                    choices=["none", "int8"],
                    help="prefix-cache pool precision: int8 stores KV "
                         "pages (and A^3 sorted-key snapshots) with "
                         "per-page fp32 scales — ~2x cache residency at "
                         "equal HBM — dequantized inside the warm "
                         "gather; none = pool in serving dtype")
    ap.add_argument("--l2-bytes", type=int, default=0,
                    help="host-RAM L2 page-store budget in bytes: "
                         "prefix-cache evictions demote pages (KV + "
                         "int8 scales + mixer snapshots + A^3 sorted "
                         "keys) to checksummed host blobs instead of "
                         "freeing them, and later lookups promote "
                         "verified blobs back to the device pool; "
                         "0 = disabled (evictions free)")
    ap.add_argument("--checkpoint-dir", default="",
                    help="write a crash-consistent engine checkpoint "
                         "(slots, queue, device cache, prefix trie + "
                         "L2 tier) to this directory after the run; "
                         "empty = no checkpoint")
    ap.add_argument("--restore", action="store_true",
                    help="restore the engine from --checkpoint-dir "
                         "before serving (continues any in-flight "
                         "requests token-for-token); the directory "
                         "must hold a checkpoint")
    ap.add_argument("--decode-block", type=int, default=1,
                    help="decode steps per jitted dispatch (lax.scan with "
                         "in-graph sampling + A^3 re-sort; the host syncs "
                         "once per block)")
    ap.add_argument("--pipeline-depth", type=int, default=0,
                    help="decode-block harvests allowed to stay in "
                         "flight behind the tick loop: tick N's ring is "
                         "read back only after tick N+depth's dispatches "
                         "issue (the next block's tokens ride the "
                         "device-resident carry); 0 = synchronous "
                         "harvest (bit-identical historical behavior)")
    ap.add_argument("--stats-json", default="",
                    help="write a versioned engine-stats snapshot "
                         "(schema tag + config echo + counters + "
                         "metrics-registry dump when telemetry is on) "
                         "as JSON to this path after the run drains; "
                         "empty = no dump")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable the serving telemetry plane: metrics "
                         "registry (TTFT/TPOT/queue-sojourn "
                         "histograms), per-request span tracing, and "
                         "in-graph A^3 quality probes (candidate "
                         "count + captured-score-mass ratio, sampled "
                         "per --telemetry-every). Adds zero host "
                         "syncs; token streams are bit-identical")
    ap.add_argument("--telemetry-every", type=int, default=8,
                    help="sample the A^3 quality probe on every N-th "
                         "decode-block dispatch")
    ap.add_argument("--metrics-json", default="",
                    help="write the metrics-registry snapshot "
                         "(counters/gauges/histograms + the legacy "
                         "stats view) as JSON to this path after the "
                         "run; implies --telemetry")
    ap.add_argument("--trace-out", default="",
                    help="write the request-lifecycle event log as "
                         "Chrome-trace JSON (chrome://tracing / "
                         "Perfetto) to this path after the run; "
                         "implies --telemetry")
    ap.add_argument("--retain-results", type=int, default=0,
                    help="bound terminal per-request bookkeeping to "
                         "this many entries (FIFO eviction; results "
                         "pop on first read); 0 = unbounded")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route decode attention through the fused "
                         "single-pass Pallas kernel (TPU)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="in-graph sampling temperature; 0 = greedy argmax")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission: maximum queued requests "
                         "(overload beyond it is load-shed per "
                         "--shed-policy); 0 = unbounded")
    ap.add_argument("--shed-policy", default="reject-new",
                    choices=["reject-new", "evict-oldest-queued"],
                    help="which request a full queue sheds (shed "
                         "requests terminate REJECTED, submit never "
                         "raises for overload)")
    ap.add_argument("--deadline-ticks", type=int, default=0,
                    help="per-request deadline in engine ticks "
                         "(requests not finished in time terminate "
                         "EXPIRED); 0 = no deadline")
    ap.add_argument("--chaos-rate", type=float, default=0.0,
                    help="chaos injection: per-site per-tick fault "
                         "probability (corrupt a decoding lane, fail a "
                         "page gather, abort a tick mid-phase); 0 = "
                         "injection off")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the deterministic chaos schedule "
                         "(a run is exactly reproducible from "
                         "(seed, rate))")
    ap.add_argument("--a3", default="off",
                    choices=["off", "conservative", "aggressive"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    a3 = {"off": A3Config(), "conservative": A3Config.conservative(),
          "aggressive": A3Config.aggressive()}[args.a3]
    telemetry = bool(args.telemetry or args.metrics_json or args.trace_out)
    serve = ServeConfig(slots=args.slots, max_len=args.max_len,
                        prefill_chunk=args.prefill_chunk or None,
                        prefill_chunk_min=args.prefill_chunk_min or None,
                        decode_block=args.decode_block,
                        use_kernel=args.use_kernel,
                        temperature=args.temperature,
                        sample_seed=args.seed,
                        page_size=args.page_size,
                        cache_pages=args.cache_pages,
                        max_queue=args.max_queue,
                        shed_policy=args.shed_policy,
                        deadline_ticks=args.deadline_ticks or None,
                        kv_quant=args.kv_quant,
                        l2_bytes=args.l2_bytes,
                        pipeline_depth=args.pipeline_depth,
                        telemetry=telemetry,
                        telemetry_every=args.telemetry_every,
                        retain_results=args.retain_results)

    chaos = None
    if args.chaos_rate > 0.0:
        chaos = ChaosInjector(ChaosConfig(seed=args.chaos_seed,
                                          rate=args.chaos_rate))

    params = decoder.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.restore:
        if not args.checkpoint_dir:
            ap.error("--restore requires --checkpoint-dir")
        engine = ServeEngine.restore(args.checkpoint_dir, params, cfg,
                                     a3=a3, chaos=chaos)
        print(f"restored engine from {args.checkpoint_dir} "
              f"(in_flight={engine.in_flight})")
    else:
        engine = ServeEngine.from_config(params, cfg, serve, a3=a3,
                                         chaos=chaos)

    rng = np.random.default_rng(args.seed)
    uids = [engine.submit(
        rng.integers(0, cfg.vocab_size, size=args.prompt_len),
        max_new_tokens=args.max_new) for _ in range(args.requests)]

    t0 = time.time()
    engine.run_to_completion()
    dt = time.time() - t0
    done = sum(1 for u in uids if engine.result(u) is not None)
    total_new = sum(len(engine.result(u) or []) for u in uids)
    by_status = collections.Counter(engine.status(u) for u in uids)
    print(f"arch={cfg.name} a3={args.a3} requests={done}/{len(uids)} "
          f"new_tokens={total_new} ({total_new / dt:.1f} tok/s, "
          f"{dt:.1f}s) statuses={dict(by_status)} stats={engine.stats}")
    if chaos is not None:
        print(f"chaos: seed={args.chaos_seed} rate={args.chaos_rate} "
              f"events={chaos.events} victims={sorted(chaos.injected_uids)}")
    if args.stats_json:
        snapshot = {
            # versioned schema so bench/reanalyze tooling can diff
            # runs (the flat dict lives under "stats", unchanged)
            "schema": "a3-serve-stats/v2",
            "config": {"arch": cfg.name, "a3": args.a3,
                       "smoke": bool(args.smoke),
                       "requests": args.requests,
                       "prompt_len": args.prompt_len,
                       "max_new": args.max_new,
                       "seed": args.seed,
                       "serve": dataclasses.asdict(serve)},
            "stats": dict(engine.stats),
        }
        if engine.tm is not None:
            snapshot["metrics"] = engine.tm.metrics_snapshot()
        with open(args.stats_json, "w") as f:
            json.dump(snapshot, f, indent=2, sort_keys=True)
        print(f"wrote engine stats to {args.stats_json}")
    if args.metrics_json and engine.tm is not None:
        engine.tm.write_metrics(args.metrics_json)
        print(f"wrote metrics snapshot to {args.metrics_json}")
    if args.trace_out and engine.tm is not None:
        engine.tm.write_trace(args.trace_out)
        print(f"wrote chrome trace to {args.trace_out}")
    if args.checkpoint_dir:
        engine.checkpoint(args.checkpoint_dir)
        print(f"checkpointed engine to {args.checkpoint_dir}")


if __name__ == "__main__":
    main()
