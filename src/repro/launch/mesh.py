"""Production mesh definition.

Single pod: (data=16, model=16) — 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across a DCI.

A function, not a module constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS first; smoke tests
see 1 device).
"""
from __future__ import annotations

import jax

try:                                   # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                    # older jax: Auto is the only type
    AxisType = None


def _mesh(shape, axes) -> jax.sharding.Mesh:
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh with Auto axis types (tests, examples)."""
    return _mesh(shape, axes)
