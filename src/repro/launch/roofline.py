"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs / peak_FLOPs          (per-device program)
  memory     = HLO_bytes / HBM_bandwidth
  collective = collective_bytes / ICI_bandwidth

FLOPs/bytes/collective-bytes come from the call-graph-aware HLO walk in
``repro.launch.hlo_analysis`` (the CPU backend's ``cost_analysis()``
neither scales ``while``-body ops by trip count — i.e. the whole layer
scan — nor reports library dots), applied to ``compiled.as_text()``,
which is a per-device SPMD program: all numbers are per-device.

collective_bytes = sum of collective operand sizes (assignment
definition). ``wire_bytes`` additionally applies ring-algorithm factors
(all-reduce moves 2(n-1)/n x bytes) — used in the SSPerf analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.launch.hlo_analysis import HloModule

# ---- TPU v5e hardware constants (assignment) -------------------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    wire_bytes: float
    model_flops: float                 # 6 N_active D (2 N_active D inference)
    compute_s: float
    memory_s: float
    collective_s: float
    op_counts: Dict[str, int]

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / compiled FLOPs — catches remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful FLOPs / (chips x peak x max-term step time) — the MFU
        this program would achieve if it ran exactly at its dominant
        roofline term."""
        t = max(self.compute_s, self.memory_s, self.collective_s)
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS_BF16 * t)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["bottleneck"] = self.bottleneck
        d["useful_flop_ratio"] = self.useful_flop_ratio
        d["roofline_fraction"] = self.roofline_fraction
        return d


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            compiled, model_flops: float,
            hlo_text: Optional[str] = None) -> Roofline:
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    mod = HloModule(txt)
    flops = mod.dot_flops()
    bts = mod.hbm_bytes()
    ob, oc, wire = mod.collectives()
    coll_total = sum(ob.values())
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=bts,
        collective_bytes=coll_total, wire_bytes=wire,
        model_flops=model_flops,
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=bts / HBM_BW,
        collective_s=coll_total / ICI_BW,
        op_counts=oc,
    )
