"""Durable page store: the checksummed host-RAM L2 tier behind the
paged prefix cache, plus the blob (de)serialization the engine
checkpoint reuses.

HBM bounds how many shared prefixes the device pool (L1) can hold; a
host-RAM tier multiplies cache residency far past it (the butterfly
co-design observation: serving wins come from memory-layout
restructuring, not just kernel math). But a durable tier is only
trustworthy if a spilled page that comes back corrupt degrades to cold
prefill — never to wrong tokens — so every blob here is *verified on
every restore*:

* **Blob format** (``serialize_tree`` / ``deserialize_tree``): a nested
  dict of arrays flattens to a JSON manifest (key paths, dtypes,
  shapes) plus the concatenated raw bytes, prefixed with a magic tag
  and a ``zlib.crc32`` over manifest+payload. ``deserialize_tree``
  recomputes the checksum and raises :class:`IntegrityError` on any
  mismatch, truncation, or malformed header — a caller can *always*
  distinguish "bit rot" from "valid data". (xxhash would be faster but
  is not in the baked image; crc32 is stdlib and the blobs are cold.)
* **:class:`PageStore`** holds blobs keyed by the full token path of
  the evicted trie node, LRU-evicted under a byte budget
  (``l2_bytes``). ``get`` verifies lazily: a corrupt blob is dropped
  *at read time* and counted in ``stats["l2_integrity_drops"]`` — the
  prefix cache then falls back to cold prefill for that node only.
  Promotion ``pop``s the blob (a page lives in exactly one tier), so
  the store can never leak host memory for a node that moved back to
  the device pool.
* **:class:`Stager`** double-buffers ``jax.device_put`` uploads for
  promotion: it pins the last two staged trees so the host can
  serialize / overwrite the next promotion's buffers while the previous
  pool-insert dispatch is still consuming its staged arrays
  asynchronously — the upload overlaps the one-dispatch warm gather
  that follows it instead of serializing behind it.

The same ``serialize_tree`` blobs are the engine checkpoint's array
payload and the wire format the ROADMAP's multi-host disaggregation
item needs (a prefill host records pages, a decode host warm-admits
them): self-describing, integrity-checked, host-portable bytes.
"""
from __future__ import annotations

import collections
import json
import struct
import zlib
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["IntegrityError", "CheckpointError", "PageStore", "Stager",
           "serialize_tree", "deserialize_tree"]

_MAGIC = b"A3L2"
_HEADER = struct.Struct("<4sII")     # magic, crc32, manifest length


class IntegrityError(RuntimeError):
    """A serialized blob failed verification (checksum mismatch,
    truncation, or malformed header) — the caller must treat the data
    as lost, never as approximately right."""


class CheckpointError(RuntimeError):
    """An engine checkpoint directory failed verification or does not
    match the restoring configuration."""


def _flatten(tree: Any, prefix: str = "") -> List[Tuple[str, np.ndarray]]:
    if isinstance(tree, dict):
        out: List[Tuple[str, np.ndarray]] = []
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}{k}/"))
        return out
    if tree is None:
        return []
    return [(prefix[:-1], np.asarray(tree))]


def _dtype_tag(dt: np.dtype) -> str:
    # ml_dtypes extension dtypes (bfloat16, float8_*) stringify to an
    # opaque void typestr ("|V2") that np.dtype cannot reverse; their
    # registered name round-trips through _np_dtype below.
    return dt.name if dt.kind == "V" else dt.str


def _np_dtype(tag: str) -> np.dtype:
    try:
        dt = np.dtype(tag)
        if dt.kind == "V":      # a fresh-format manifest never carries
            raise TypeError     # a void typestr; fall through to name
        return dt
    except TypeError:
        import ml_dtypes
        try:
            return np.dtype(getattr(ml_dtypes, tag))
        except (AttributeError, TypeError):
            raise IntegrityError(
                f"unknown dtype {tag!r} in manifest") from None


def serialize_tree(tree: Any) -> bytes:
    """Nested dict of arrays -> self-describing checksummed bytes.
    Leaves may be numpy or jax arrays (device leaves transfer to host
    here); ``None`` leaves and empty dicts serialize to nothing and
    restore as absent keys."""
    leaves = _flatten(tree)
    manifest = []
    chunks = []
    for key, arr in leaves:
        arr = np.ascontiguousarray(arr)
        manifest.append({"key": key, "dtype": _dtype_tag(arr.dtype),
                         "shape": list(arr.shape)})
        chunks.append(arr.tobytes())
    mbytes = json.dumps(manifest, sort_keys=True).encode()
    payload = b"".join(chunks)
    crc = zlib.crc32(payload, zlib.crc32(mbytes))
    return _HEADER.pack(_MAGIC, crc, len(mbytes)) + mbytes + payload


def deserialize_tree(blob: bytes) -> Dict[str, Any]:
    """Verified inverse of :func:`serialize_tree` (host numpy leaves).
    Raises :class:`IntegrityError` unless the blob's checksum, header,
    and per-leaf byte counts all hold."""
    if len(blob) < _HEADER.size:
        raise IntegrityError(f"blob truncated: {len(blob)} bytes < "
                             f"{_HEADER.size}-byte header")
    magic, crc, mlen = _HEADER.unpack_from(blob)
    if magic != _MAGIC:
        raise IntegrityError(f"bad magic {magic!r}")
    body = blob[_HEADER.size:]
    if len(body) < mlen:
        raise IntegrityError("blob truncated inside manifest")
    if zlib.crc32(body) != crc:
        raise IntegrityError("checksum mismatch")
    try:
        manifest = json.loads(body[:mlen].decode())
    except ValueError as e:
        raise IntegrityError(f"malformed manifest: {e}") from None
    tree: Dict[str, Any] = {}
    off = mlen
    for entry in manifest:
        dtype = _np_dtype(entry["dtype"])
        shape = tuple(entry["shape"])
        n = dtype.itemsize * int(np.prod(shape, dtype=np.int64))
        if off + n > len(body):
            raise IntegrityError("blob truncated inside payload")
        arr = np.frombuffer(body[off:off + n], dtype=dtype).reshape(shape)
        off += n
        node = tree
        *parents, leaf = entry["key"].split("/")
        for p in parents:
            node = node.setdefault(p, {})
        node[leaf] = arr
    if off != len(body):
        raise IntegrityError(f"{len(body) - off} trailing bytes")
    return tree


class Stager:
    """Double-buffered ``jax.device_put`` staging for L2 promotion (see
    module docstring): rotating references keep the previous upload
    alive while its insert dispatch drains, so staging promotion N+1
    overlaps gathering promotion N."""

    def __init__(self):
        self._bufs: List[Any] = [None, None]
        self._i = 0

    def stage(self, tree: Any) -> Any:
        staged = jax.tree_util.tree_map(jax.device_put, tree)
        self._i ^= 1
        self._bufs[self._i] = staged
        return staged


_STAT_KEYS = ("l2_spills", "l2_hits", "l2_evictions",
              "l2_integrity_drops")


class PageStore:
    """Byte-budgeted LRU host store of checksummed blobs, keyed by the
    evicted node's full token path. ``stats`` may be externally owned
    (the prefix cache passes the engine's dict)."""

    def __init__(self, max_bytes: int,
                 stats: Optional[Dict[str, int]] = None):
        if max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1 for a PageStore, "
                             f"got {max_bytes} (use l2_bytes=0 to "
                             f"disable the L2 tier)")
        self.max_bytes = int(max_bytes)
        self._blobs: "collections.OrderedDict[Tuple[int, ...], bytes]" = \
            collections.OrderedDict()
        self._bytes = 0
        self.stats = stats if stats is not None else {}
        for k in _STAT_KEYS:
            self.stats.setdefault(k, 0)

    # -- capacity ------------------------------------------------------------
    @property
    def bytes_used(self) -> int:
        return self._bytes

    def _reserve(self, need: int) -> bool:
        """Evict LRU blobs until ``need`` bytes fit; False if ``need``
        alone exceeds the budget (the blob is dropped, not stored —
        losing an L2 entry only costs a cold prefill later)."""
        if need > self.max_bytes:
            return False
        while self._bytes + need > self.max_bytes:
            _, blob = self._blobs.popitem(last=False)
            self._bytes -= len(blob)
            self.stats["l2_evictions"] += 1
        return True

    # -- store / load --------------------------------------------------------
    def put(self, key: Tuple[int, ...], tree: Any) -> bool:
        """Serialize and store a demoted node's payload; True if it was
        admitted under the byte budget."""
        blob = serialize_tree(tree)
        self.discard(key)
        if not self._reserve(len(blob)):
            return False
        self._blobs[key] = blob
        self._bytes += len(blob)
        self.stats["l2_spills"] += 1
        return True

    def put_raw(self, key: Tuple[int, ...], blob: bytes) -> bool:
        """Re-admit an already-serialized blob (checkpoint restore);
        verification stays lazy — ``get`` checks the crc as usual."""
        self.discard(key)
        if not self._reserve(len(blob)):
            return False
        self._blobs[key] = bytes(blob)
        self._bytes += len(blob)
        return True

    def get(self, key: Tuple[int, ...]) -> Optional[Dict[str, Any]]:
        """Verified load. None on miss; a blob failing verification is
        dropped here (graceful degradation: the caller cold-prefills)
        and counted in ``stats["l2_integrity_drops"]``."""
        blob = self._blobs.get(key)
        if blob is None:
            return None
        self._blobs.move_to_end(key)
        try:
            tree = deserialize_tree(blob)
        except IntegrityError:
            self.discard(key)
            self.stats["l2_integrity_drops"] += 1
            return None
        self.stats["l2_hits"] += 1
        return tree

    def pop(self, key: Tuple[int, ...]) -> None:
        """Remove a promoted blob (a page lives in exactly one tier)."""
        self.discard(key)

    def discard(self, key: Tuple[int, ...]) -> None:
        blob = self._blobs.pop(key, None)
        if blob is not None:
            self._bytes -= len(blob)

    def clear(self) -> None:
        self._blobs.clear()
        self._bytes = 0

    # -- introspection / fault injection -------------------------------------
    def __contains__(self, key: Tuple[int, ...]) -> bool:
        return key in self._blobs

    def __len__(self) -> int:
        return len(self._blobs)

    def keys(self) -> Iterable[Tuple[int, ...]]:
        return self._blobs.keys()

    def raw_items(self) -> Iterable[Tuple[Tuple[int, ...], bytes]]:
        """(key, blob) pairs for the engine checkpoint (blobs are
        written as-is: they carry their own checksums)."""
        return self._blobs.items()

    def corrupt(self, key: Tuple[int, ...]) -> bool:
        """Deterministically flip one payload byte of a stored blob
        (the chaos ``restore_corrupt`` site and the conformance tests'
        bit-rot model). Returns True if the key was present."""
        blob = self._blobs.get(key)
        if blob is None:
            return False
        flipped = blob[:-1] + bytes([blob[-1] ^ 0xFF])
        self._blobs[key] = flipped
        return True
