"""Paged prefix cache: shared-prefix reuse across every mixer kind.

Serving wastes the same work twice: A^3's premise is that attention
recomputes scores for keys that never matter, and an engine without
prefix reuse re-*prefills* identical prompt prefixes — shared system
prompts, few-shot headers, multi-turn histories — for every request.
This module makes admitted prompts reusable by carving each slot's
per-segment decode cache into fixed-size **pages** with a host-side
block table, and indexing admitted token prefixes with a **radix trie**
whose nodes own immutable page runs plus per-``BlockKind`` mixer-state
snapshots taken at page boundaries:

::

    root ──[tok 0..ps)──> node(page 0, snap@ps)
                            ├─[tok ps..2ps)──> node(page 1, snap@2ps)
                            │                    └─ ...
                            └─[tok' ps..2ps)─> node(page 7, snap@2ps)
                                                 (divergent sibling: COW)

* **Pages** live in a device-resident pool (``decoder.init_page_pool``):
  a *logical* page spans ``page_size`` token positions across every
  segment at once — attention segments store those positions' K/V ring
  rows per page; recurrent segments (RG-LRU, mLSTM, sLSTM) store
  nothing per token, because their decode state is a fixed-size carry.
* **Snapshots** are the PR-4 chunked-prefill carry itself: the engine
  clamps a recorded prompt's chunks to end on page boundaries, so after
  the chunk dispatch the slot's mixer state *is* the boundary state —
  one ``snapshot_state`` slice per new trie node captures it
  (recurrent carries; attention's per-token state is already paged).
  A^3 sorted-key state is a whole-ring property: it is snapshotted once
  per recorded prompt at the trie leaf and *sliced* to any interior
  page boundary at restore time
  (:func:`repro.core.candidate_selection.slice_sorted_keys`).
* **Warm admission** walks the trie over the prompt's pages, then
  gathers every matched page into the slot's cache with ONE jitted copy
  dispatch (``gather``): ring rows come back from pages, recurrent
  carries from the matched node's snapshot, and the A^3 sorted columns
  + ``sorted_upto`` watermark are restored at the boundary — so no
  re-sort is triggered and only the unmatched suffix is chunk-prefilled.
  A full-prefix hit is capped one page short of the prompt end: at least
  one suffix token always prefills, which is what produces the
  next-token logits (and, on the final chunk, re-folds the full-ring
  A^3 sort exactly as a cold admission would).
* **Copy-on-write** is structural: pool pages are immutable and
  refcounted via the trie; a request that diverges mid-page matches
  only up to the last shared boundary, prefills its divergent suffix
  into its own slot cache, and records *new* pages for it — the first
  divergent page becomes a sibling edge, never a mutation.
* **Eviction** is LRU over childless, unreferenced trie nodes under the
  ``ServeConfig.cache_pages`` budget (each node = one logical page; a
  leaf's sorted-key snapshot rides along and is freed with it). Nodes
  pinned by an in-flight admission or an actively recording slot are
  never evicted.
* **Host-RAM L2 tier** (``l2_bytes > 0``): eviction *demotes* instead
  of freeing — the node's pool page (KV rows + int8 scales), recurrent
  carry snapshot, and A^3 sorted-key leaf snapshot serialize to one
  checksummed blob in a :class:`~repro.serve.page_store.PageStore`.
  ``lookup`` extends a stalled trie walk through L2: each continuing
  page found there is *promoted* back — blob verified, device page
  allocated (may itself demote an LRU victim), arrays staged via a
  double-buffered ``jax.device_put`` overlapping the warm gather that
  follows, and the trie node re-created in place. Degradation is
  graceful and node-local: a checksum mismatch, missing blob, or failed
  host->device copy drops *that node only* back to cold prefill
  (``stats["l2_integrity_drops"]``) — a corrupted L2 entry can shorten
  the reused prefix but never change emitted tokens.
* **Batched warm admission**: ``gather_into`` admits N matched slots in
  ONE jitted copy dispatch (the flash-crowd case — one viral system
  prompt, N concurrent hits), applying THE per-slot gather graph
  (``gather_fn``) N times inside a single program;
  ``stats["gather_dispatches"]`` counts dispatches, not slots.
"""
from __future__ import annotations

import collections
import functools
import heapq
import itertools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import BlockKind, ModelConfig
from repro.models import decoder
from repro.models.mixer import FULL_WINDOW, MIXERS, build_segments, \
    cache_len_for
from repro.serve.page_store import PageStore, Stager

_STAT_KEYS = ("prefix_hits", "prefix_tokens_reused", "gather_dispatches",
              "pages_recorded", "pages_evicted", "l2_spills", "l2_hits",
              "l2_evictions", "l2_integrity_drops")


def gather_fn(segs, a3, cache, pool, si, t, idx, snaps, sk_snaps):
    """THE warm-admission copy graph: matched pages -> slot ring,
    boundary snapshot -> recurrent carries, sorted-key slice (or
    comprehension sort of the gathered ring) + watermark ``t`` -> A^3
    state. Module-level so ``launch.dryrun.lower_gather_pages`` lowers
    the *same* graph the engine dispatches (partial-bind ``segs``/``a3``
    and jit with the cache donated)."""
    new_cache = {}
    for i, seg in enumerate(segs):
        name = f"seg{i}"
        mixer = MIXERS[seg.kind]
        if seg.kind == BlockKind.ATTENTION:
            ids = idx[name]
            new_cache[name] = mixer.gather_pages(
                cache[name], pool[name], si, t, ids["page"], ids["off"],
                ids["valid"], a3=a3, sk_snap=sk_snaps.get(name))
        else:
            new_cache[name] = mixer.restore_state(cache[name],
                                                  snaps[name], si)
    return new_cache


def gather_many_fn(segs, a3, cache, pool, packed):
    """Stacked multi-slot warm admission: apply THE gather graph
    (:func:`gather_fn`) once per matched slot inside a single jitted
    dispatch, threading the cache through — a flash crowd of N
    same-prefix hits costs ONE copy dispatch instead of N."""
    for e in packed:
        cache = gather_fn(segs, a3, cache, pool, e["si"], e["t"],
                          e["idx"], e["snaps"], e["sk"])
    return cache


def insert_page_fn(pool, pid, page):
    """L2-promotion pool insert: write one staged host page back into
    logical page ``pid`` across every pool leaf (KV rows + int8
    scales). Module-level so the sharded lowering test compiles the
    same graph the cache dispatches."""
    return jax.tree_util.tree_map(
        lambda leaf, pg: leaf.at[:, pid].set(pg), pool, page)


class _TrieNode:
    """One page run: ``tokens`` (the edge label, exactly ``page_size``
    token ids), the owned logical ``page_id``, and the mixer-state
    snapshot at ``end`` (the boundary this node's pages reach)."""

    __slots__ = ("parent", "tokens", "end", "children", "page_id",
                 "snap", "snap_valid", "sk_snap", "sk_pages", "refs",
                 "last_used")

    def __init__(self, parent: Optional["_TrieNode"],
                 tokens: Tuple[int, ...], end: int):
        self.parent = parent
        self.tokens = tokens
        self.end = end
        self.children: Dict[Tuple[int, ...], "_TrieNode"] = {}
        self.page_id = -1
        self.snap: Any = {}
        # whether this node can terminate a match: chunks may span
        # several pages, and interior pages of a multi-page chunk are
        # recorded (their K/V rows are real and restorable) without a
        # boundary state — no recurrent carry (it exists only at the
        # chunk END), and sliding rings captured post-chunk may already
        # have dropped rows an interior-boundary restore would need.
        # Global-attention-only stacks match at any page; everything
        # else stops at chunk-end (snap_valid) nodes.
        self.snap_valid = False
        self.sk_snap: Optional[Dict[str, Any]] = None
        self.sk_pages: List[int] = []   # budget pages charged for sk_snap
        self.refs = 0
        self.last_used = 0


class PrefixCache:
    """Host-side block table + device page pool + radix trie.

    Built by ``ServeEngine`` when ``cache_pages > 0``; usable standalone
    against any ``decoder.init_cache`` pytree (the unit tests drive it
    without an engine). ``stats`` may be an externally owned dict (the
    engine passes its own) — the cache increments ``prefix_hits``,
    ``prefix_tokens_reused``, ``gather_dispatches``, ``pages_recorded``
    and ``pages_evicted`` in place.
    """

    def __init__(self, cfg: ModelConfig, *, max_len: int, page_size: int,
                 cache_pages: int, a3: bool = False, dtype=None,
                 kv_quant: str = "none", l2_bytes: int = 0,
                 stats: Optional[Dict[str, int]] = None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if cache_pages < 1:
            raise ValueError(
                f"cache_pages must be >= 1 for a PrefixCache, got "
                f"{cache_pages} (use ServeConfig.cache_pages=0 to disable)")
        if kv_quant not in ("none", "int8"):
            raise ValueError(
                f"kv_quant must be 'none' or 'int8', got {kv_quant!r}")
        self.cfg = cfg
        self.max_len = int(max_len)
        self.page_size = int(page_size)
        self.capacity = int(cache_pages)
        self.a3 = bool(a3)
        self.kv_quant = kv_quant
        self.segs = build_segments(cfg)
        # per-attention-segment ring widths (the pool mirrors only these)
        self._widths = {
            f"seg{i}": cache_len_for(seg, max_len)
            for i, seg in enumerate(self.segs)
            if seg.kind == BlockKind.ATTENTION
        }
        self._sk_widths = {
            name: w for name, w in self._widths.items()
            if self.a3 and self.segs[int(name[3:])].window >= FULL_WINDOW
        }
        # a leaf sorted-key snapshot holds 2 whole-ring arrays per sk
        # segment (vals + rows ~ a page's k + v per row), so it is
        # charged sum(w)/page_size budget pages — the cache_pages budget
        # bounds TOTAL device memory held by the trie, not just pages
        self._sk_cost = (-(-sum(self._sk_widths.values())
                           // self.page_size) if self._sk_widths else 0)
        self._has_rec = any(s.kind != BlockKind.ATTENTION for s in self.segs)
        # Page-granularity match terminals are safe only when every
        # attention ring spans max_len (global windows): a sliding ring
        # is captured post-chunk, so an interior page's rows in
        # (t - w, chunk_end - w) would have been overwritten already —
        # matches on such stacks (and on recurrent stacks, which need
        # the carry) must stop at chunk-END boundaries (snap_valid).
        self._page_terminals = (not self._has_rec and all(
            w >= self.max_len for w in self._widths.values()))
        self.pool = decoder.init_page_pool(cfg, cache_pages, page_size,
                                           dtype=dtype, a3=a3,
                                           kv_quant=kv_quant)
        self.root = _TrieNode(None, (), 0)
        self._free: List[int] = list(range(cache_pages))
        self._nodes: set = set()
        self._clock = 0
        # lazy-deletion LRU heap over (last_used, seq, node): pushed on
        # every touch and on every becomes-evictable transition (refs
        # hit 0, last child removed); stale / non-evictable entries are
        # discarded at pop, so victim selection is O(log n) instead of
        # a full node scan per allocation
        self._heap: List[Tuple[int, int, _TrieNode]] = []
        self._seq = itertools.count()
        self.stats = stats if stats is not None else {}
        for k in _STAT_KEYS:
            self.stats.setdefault(k, 0)
        # host-RAM L2 tier: eviction demotes checksummed blobs here
        # instead of freeing (0 = historical free-on-evict)
        if int(l2_bytes) < 0:
            raise ValueError(f"l2_bytes must be >= 0, got {l2_bytes} "
                             f"(0 disables the L2 tier)")
        self.l2: Optional[PageStore] = (
            PageStore(int(l2_bytes), stats=self.stats)
            if int(l2_bytes) > 0 else None)
        self._stager = Stager()
        # chaos hook: called with the blob key before each L2 restore;
        # returning True corrupts the blob first (restore_corrupt site)
        self.l2_fault_hook: Optional[Any] = None
        # telemetry bundle (serve.telemetry.Telemetry), set by the
        # engine when tracing is on: cache events (hits, evictions,
        # COW dedupes, L2 demote/promote) land on the shared timeline
        self.tm: Optional[Any] = None
        self._jit_record = jax.jit(self._record_fn, donate_argnums=(0,))
        self._jit_gather_many = jax.jit(
            functools.partial(gather_many_fn, self.segs, self.a3),
            donate_argnums=(0,))
        self._jit_insert = jax.jit(insert_page_fn, donate_argnums=(0,))
        self._jit_snapshot = jax.jit(self._snapshot_fn)
        self._jit_sk_snapshot = jax.jit(self._sk_snapshot_fn)

    # -- jitted copy dispatches ---------------------------------------------
    def _record_fn(self, pool, cache, si, page_id, rows, valid):
        """Copy one page of slot ``si``'s ring rows into the pool."""
        new_pool = {}
        for i, seg in enumerate(self.segs):
            name = f"seg{i}"
            if name not in pool:
                continue
            new_pool[name] = MIXERS[seg.kind].write_page(
                pool[name], cache[name], si, page_id, rows[name],
                valid[name])
        return new_pool

    def _snapshot_fn(self, cache, si):
        """Boundary snapshot = the chunked-prefill carry of lane ``si``
        for every non-paged (recurrent) segment."""
        return {f"seg{i}": MIXERS[seg.kind].snapshot_state(
                    cache[f"seg{i}"], si)
                for i, seg in enumerate(self.segs)
                if seg.kind != BlockKind.ATTENTION}

    def _sk_snapshot_fn(self, cache, si):
        """Leaf snapshot of the A^3 sorted columns (whole-ring state:
        captured once per recorded prompt, sliced at restore).

        With ``kv_quant="int8"`` the sorted values are stored int8 with
        one fp32 scale per sorted column (axis ``w`` of [L, H, w, d]) —
        round-to-nearest is monotone, so the quantized columns remain
        validly ascending for the greedy candidate walk; the gather hook
        dequantizes before the boundary slice."""
        if self.kv_quant == "int8":
            from repro.core.quantization import quantize_int8_block
            out = {}
            for name in self._sk_widths:
                q, scale = quantize_int8_block(
                    cache[name]["sk_vals"][:, si], axes=(2,))
                out[name] = {"vals": q, "scale": scale,
                             "rows": cache[name]["sk_rows"][:, si]}
            return out
        return {name: {"vals": cache[name]["sk_vals"][:, si],
                       "rows": cache[name]["sk_rows"][:, si]}
                for name in self._sk_widths}

    # -- trie ----------------------------------------------------------------
    def _push(self, node: _TrieNode) -> None:
        if node is self.root:
            return
        heapq.heappush(self._heap,
                       (node.last_used, next(self._seq), node))
        # Bound the lazy heap: under-budget steady traffic never drains
        # it via _alloc_page (the free list stays nonempty), so stale
        # touch entries would otherwise accumulate forever. Compact to
        # one fresh entry per live node once it outgrows a small
        # multiple of the node population.
        if len(self._heap) > 4 * (len(self._nodes) + 16):
            fresh = {id(n): (lu, seq, n) for lu, seq, n in self._heap
                     if n.page_id >= 0 and lu == n.last_used}
            self._heap = sorted(fresh.values())

    def _touch(self, node: _TrieNode) -> None:
        self._clock += 1
        node.last_used = self._clock
        self._push(node)

    def lookup(self, prompt: np.ndarray) -> Tuple[int, _TrieNode]:
        """Longest *restorable* page-aligned cached prefix of
        ``prompt``: the deepest matched node that can terminate a match
        — any page on global-attention-only stacks, else the deepest
        chunk-end (``snap_valid``) node, which holds the recurrent
        carry and bounds sliding-ring capture staleness.

        Capped one token short of the prompt end: the admission path
        must always chunk-prefill >= 1 suffix token (it produces the
        next-token logits and re-folds the final A^3 sort)."""
        prompt = np.asarray(prompt)
        node, t, ps = self.root, 0, self.page_size
        best_t, best_node = 0, self.root
        while t + ps < len(prompt):
            child = node.children.get(
                tuple(int(x) for x in prompt[t:t + ps]))
            if child is None:
                break
            node = child
            t += ps
            self._touch(node)
            if node.snap_valid or self._page_terminals:
                best_t, best_node = t, node
        if self.l2 is not None:
            # the trie walk stalled: its demoted continuation (if any)
            # lives in L2 — promote page by page until a miss, an
            # integrity drop, or an unallocatable device page ends the
            # match (eviction only ever demotes childless nodes, so
            # once the chain leaves L1 it never re-enters it)
            while t + ps < len(prompt):
                edge = tuple(int(x) for x in prompt[t:t + ps])
                child = self._promote(node, edge)
                if child is None:
                    break
                node = child
                t += ps
                if node.snap_valid or self._page_terminals:
                    best_t, best_node = t, node
        return best_t, best_node

    def ref(self, node: Optional[_TrieNode]) -> None:
        if node is not None and node is not self.root:
            node.refs += 1

    def unref(self, node: Optional[_TrieNode]) -> None:
        if node is not None and node is not self.root:
            node.refs -= 1
            if node.refs == 0:
                self._push(node)    # may have become evictable

    def _find_sk_donor(self, node: _TrieNode) -> Optional[_TrieNode]:
        """Any leaf snapshot at-or-below ``node`` covers every boundary
        <= node.end with identical ring layout (captured only for
        unwrapped prompts), so a BFS finds a valid donor."""
        queue = collections.deque([node])
        while queue:
            n = queue.popleft()
            if n.sk_snap is not None:
                return n
            queue.extend(n.children.values())
        return None

    # -- eviction ------------------------------------------------------------
    def _alloc_page(self) -> Optional[int]:
        if self._free:
            return self._free.pop()
        while self._heap:
            lu, _, node = heapq.heappop(self._heap)
            if node.page_id < 0 or node.children or node.refs > 0 \
                    or lu != node.last_used:
                continue        # evicted / not a leaf / pinned / stale
            self._evict(node)
            return self._free.pop()
        return None

    def _evict(self, node: _TrieNode) -> None:
        if self.l2 is not None:
            self._demote(node)          # spill, don't lose
        node.parent.children.pop(node.tokens, None)
        self._nodes.discard(node)
        self._free.append(node.page_id)
        self._free.extend(node.sk_pages)    # sk-snapshot budget charge
        node.sk_pages = []
        node.page_id = -1       # marks heap entries for this node stale
        node.snap = {}
        node.sk_snap = None
        if not node.parent.children:
            self._push(node.parent)     # parent may now be evictable
        self.stats["pages_evicted"] += 1
        if self.tm is not None:
            self.tm.event("page_evict", track="cache",
                          demoted=self.l2 is not None, end=node.end)

    def spill(self, n: int) -> int:
        """Force-evict up to ``n`` LRU evictable nodes (the chaos
        ``spill`` site / external memory pressure): demotes to L2 when
        enabled, frees otherwise. Returns the number evicted."""
        done = 0
        while done < n:
            victim = None
            while self._heap:
                lu, _, node = heapq.heappop(self._heap)
                if node.page_id < 0 or node.children or node.refs > 0 \
                        or lu != node.last_used:
                    continue
                victim = node
                break
            if victim is None:
                break
            self._evict(victim)
            done += 1
        return done

    # -- L2 tier (host-RAM page store) ----------------------------------------
    def _seg_kind(self, name: str) -> BlockKind:
        return self.segs[int(name[3:])].kind

    def _path_of(self, node: _TrieNode) -> Tuple[int, ...]:
        """Full token path from the root — the node's L2 blob key."""
        parts: List[Tuple[int, ...]] = []
        n = node
        while n is not self.root:
            parts.append(n.tokens)
            n = n.parent
        out: List[int] = []
        for tk in reversed(parts):
            out.extend(tk)
        return tuple(out)

    def _demote(self, node: _TrieNode) -> None:
        """Serialize an evicted node's durable payload — pool page
        rows (+ int8 scales), recurrent carry snapshot, A^3 sorted-key
        leaf snapshot — into one checksummed L2 blob. Off the decode
        hot path (runs only under eviction pressure), so the one
        blocking device read per demotion is acceptable."""
        page = {}
        if self.pool:
            page = jax.device_get(jax.tree_util.tree_map(
                lambda a: a[:, node.page_id], self.pool))
        snap = {name: MIXERS[self._seg_kind(name)].dump_snapshot(s)
                for name, s in node.snap.items()}
        sk = {}
        if node.sk_snap is not None:
            sk = {name: {k: np.asarray(v) for k, v in h.items()}
                  for name, h in node.sk_snap.items()}
        self.l2.put(self._path_of(node),
                    {"page": page, "snap": snap, "sk": sk,
                     "meta": {"snap_valid": np.uint8(node.snap_valid)}})
        if self.tm is not None:
            self.tm.event("l2_demote", track="cache", end=node.end)

    def _promote(self, parent: _TrieNode, edge: Tuple[int, ...]
                 ) -> Optional[_TrieNode]:
        """Move one demoted page L2 -> L1: verify the blob, allocate a
        device page (may itself demote an LRU victim), stage the host
        arrays through the double-buffered ``jax.device_put`` buffer,
        insert into the pool, and re-create the trie node. Returns None
        on a miss or on *graceful degradation* — a checksum mismatch,
        missing blob, or failed host->device copy drops this node (and
        only it) back to cold prefill, counted in
        ``stats["l2_integrity_drops"]``."""
        ps = self.page_size
        key = self._path_of(parent) + edge
        if self.l2_fault_hook is not None and self.l2_fault_hook(key):
            self.l2.corrupt(key)        # chaos restore_corrupt site
        tree = self.l2.get(key)     # verified; None on miss or bit rot
        if tree is None:
            return None
        # pin the attach point: _alloc_page's eviction scan must not
        # demote the very node we are extending
        self.ref(parent)
        pid = None
        try:
            pid = self._alloc_page()
            if pid is None:
                return None     # budget fully pinned; blob stays put
            if self.pool:
                staged = self._stager.stage(tree["page"])
                self.pool = self._jit_insert(
                    self.pool, jnp.asarray(pid, jnp.int32), staged)
            snap = {}
            if self._has_rec:
                snap = {name: MIXERS[self._seg_kind(name)]
                        .load_snapshot(h)
                        for name, h in tree.get("snap", {}).items()}
            snap_valid = bool(int(np.asarray(
                tree["meta"]["snap_valid"]).ravel()[0]))
        except Exception:
            # failed copy / malformed payload: degrade this node only
            if pid is not None:
                self._free.append(pid)
            self.l2.discard(key)
            self.stats["l2_integrity_drops"] += 1
            if self.tm is not None:
                self.tm.event("l2_integrity_drop", track="cache",
                              tokens=len(key))
            return None
        finally:
            self.unref(parent)
        self.l2.pop(key)        # a page lives in exactly one tier
        child = _TrieNode(parent, edge, parent.end + ps)
        child.page_id = pid
        child.snap = snap
        child.snap_valid = snap_valid
        parent.children[edge] = child
        self._nodes.add(child)
        self._touch(child)
        sk_host = tree.get("sk")
        if sk_host and self._sk_widths:
            # re-charge the leaf snapshot's budget pages; dropping it
            # is not an error (the warm gather re-derives the sort)
            self.ref(child)     # pin against the charge's own evictions
            charged: List[int] = []
            for _ in range(self._sk_cost):
                p = self._alloc_page()
                if p is None:
                    self._free.extend(charged)
                    charged = []
                    break
                charged.append(p)
            self.unref(child)
            if len(charged) == self._sk_cost:
                child.sk_pages = charged
                child.sk_snap = {
                    name: {k: jnp.asarray(v) for k, v in h.items()}
                    for name, h in sk_host.items()}
        if self.tm is not None:
            self.tm.event("l2_promote", track="cache", end=child.end)
        return child

    # -- admission -----------------------------------------------------------
    def admit(self, cache: Dict[str, Any], si: int, prompt: np.ndarray,
              fail_hook: Optional[Any] = None
              ) -> Tuple[Dict[str, Any], int, _TrieNode]:
        """Walk the trie, gather every matched page into slot ``si``
        with one jitted copy dispatch, and return (cache, matched_len,
        matched_node). The caller should ``ref`` the node as the slot's
        recording anchor and ``unref`` it at prefill end.

        ``fail_hook(matched_len)``, when given, is called for warm
        admissions *before* the gather dispatch; it may raise (chaos
        injection: a failed page gather) — the device cache is then
        untouched, no stats are counted, and no refs are held, so the
        caller can fail the request without unwinding anything."""
        t, node = self.lookup(prompt)
        if t == 0:
            return cache, 0, node
        if fail_hook is not None:
            fail_hook(t)
        cache = self.gather_into(cache, [(si, t, node)])
        return cache, t, node

    def gather_into(self, cache: Dict[str, Any],
                    entries: List[Tuple[int, int, _TrieNode]]
                    ) -> Dict[str, Any]:
        """Warm-admit every matched ``(si, t, node)`` with ONE jitted
        stacked copy dispatch — the flash-crowd path: N same-prefix
        slots cost one ``gather_dispatches`` increment, not N. Entries
        must be ref-pinned by the caller before this runs (an L2
        promotion inside a *later* lookup could otherwise evict an
        earlier entry's matched chain between lookup and gather);
        page ids are resolved here, at dispatch time."""
        ps = self.page_size
        packed = []
        for si, t, node in entries:
            # host-side block table walk: pool page id per page index
            chain: List[int] = []
            n = node
            while n is not self.root:
                chain.append(n.page_id)
                n = n.parent
            pid_of = np.asarray(chain[::-1], np.int32)
            idx = {}
            for name, w in self._widths.items():
                r = np.arange(w)
                q = (t - 1) - ((t - 1 - r) % w)  # position in ring row r
                valid = q >= 0
                qc = np.where(valid, q, 0)
                idx[name] = {
                    "page": jnp.asarray(pid_of[qc // ps], jnp.int32),
                    "off": jnp.asarray(qc % ps, jnp.int32),
                    "valid": jnp.asarray(valid)}
            snaps = node.snap if self._has_rec else {}
            sk_snaps: Dict[str, Any] = {}
            if self._sk_widths:
                donor = self._find_sk_donor(node)
                if donor is not None:
                    sk_snaps = donor.sk_snap
            packed.append({"si": jnp.asarray(si, jnp.int32),
                           "t": jnp.asarray(t, jnp.int32),
                           "idx": idx, "snaps": snaps, "sk": sk_snaps})
        cache = self._jit_gather_many(cache, self.pool, packed)
        self.stats["prefix_hits"] += len(entries)
        self.stats["prefix_tokens_reused"] += sum(t for _, t, _ in entries)
        self.stats["gather_dispatches"] += 1
        if self.tm is not None:
            self.tm.event("prefix_hit", track="cache", hits=len(entries),
                          tokens=sum(t for _, t, _ in entries))
        return cache

    # -- recording -----------------------------------------------------------
    def record_boundary(self, cache: Dict[str, Any], si: int,
                        prompt: np.ndarray, boundary: int,
                        parent: _TrieNode, carry: bool = True
                        ) -> Optional[_TrieNode]:
        """Called by the engine for every page boundary a prefill chunk
        crossed: dedupe against an existing child, else allocate a page
        (evicting LRU if the budget is full) and copy the ring rows
        pool-ward. ``carry`` marks the chunk-END boundary, where the
        slot's mixer state *is* the boundary state — only there is the
        recurrent carry snapshotted (interior pages of a multi-page
        chunk are recorded carry-less; an existing carry-less node is
        upgraded when a later chunk ends on it). Returns the child node,
        or None when no page could be allocated (the lane stops
        recording; its prefix so far stays reusable)."""
        ps = self.page_size
        key = tuple(int(x) for x in np.asarray(prompt)[boundary - ps:
                                                       boundary])
        child = parent.children.get(key)
        if child is not None:
            # copy-on-write dedupe: the page already exists, so this
            # lane shares it instead of recording a duplicate
            self._touch(child)
            if carry and not child.snap_valid:
                if self._has_rec:
                    child.snap = self._jit_snapshot(
                        cache, jnp.asarray(si, jnp.int32))
                child.snap_valid = True
            if self.tm is not None:
                self.tm.event("page_dedupe", track="cache",
                              end=child.end)
            return child
        page_id = self._alloc_page()
        if page_id is None:
            return None
        if self.pool:
            rows, valid = {}, {}
            for name, w in self._widths.items():
                p = np.arange(boundary - ps, boundary)
                rows[name] = jnp.asarray(p % w, jnp.int32)
                valid[name] = jnp.asarray(p >= boundary - w)
            self.pool = self._jit_record(self.pool, cache,
                                         jnp.asarray(si, jnp.int32),
                                         jnp.asarray(page_id, jnp.int32),
                                         rows, valid)
        child = _TrieNode(parent, key, boundary)
        child.page_id = page_id
        child.snap_valid = carry
        if carry and self._has_rec:
            child.snap = self._jit_snapshot(cache,
                                            jnp.asarray(si, jnp.int32))
        parent.children[key] = child
        self._nodes.add(child)
        self._touch(child)
        self.stats["pages_recorded"] += 1
        return child

    def record_final(self, cache: Dict[str, Any], si: int,
                     node: _TrieNode, prompt_len: int) -> None:
        """Leaf capture of the A^3 sorted columns after a recorded
        prompt's final chunk folded the full-ring sort. Skipped when the
        prompt wrapped any sorted ring (row != position would break the
        page-boundary slice), a snapshot already exists, or the
        ``sum(w)/page_size`` budget pages it costs cannot be allocated —
        the cache_pages budget bounds the trie's total device memory,
        and a warm admission without a donor snapshot just re-derives
        the sort in the gather dispatch."""
        if node is self.root or node.sk_snap is not None \
                or not self._sk_widths:
            return
        if any(prompt_len > w for w in self._sk_widths.values()):
            return
        charged: List[int] = []
        for _ in range(self._sk_cost):
            pid = self._alloc_page()
            if pid is None:
                self._free.extend(charged)
                return
            charged.append(pid)
        node.sk_pages = charged
        node.sk_snap = self._jit_sk_snapshot(cache,
                                             jnp.asarray(si, jnp.int32))

    # -- checkpoint -----------------------------------------------------------
    def dump_state(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """``(host_meta, arrays)`` snapshot for the engine checkpoint:
        the trie structure in parent-before-child order plus the device
        pool and per-node snapshots (host-transferred through the mixer
        ``dump_snapshot`` hooks). L2 blobs are not here — they are
        already serialized bytes (``l2.raw_items()``)."""
        nodes: List[_TrieNode] = []
        index = {id(self.root): -1}
        queue = collections.deque([self.root])
        while queue:
            n = queue.popleft()
            for child in n.children.values():
                index[id(child)] = len(nodes)
                nodes.append(child)
                queue.append(child)
        meta = {"nodes": [{"parent": index[id(n.parent)],
                           "tokens": list(n.tokens), "end": n.end,
                           "page_id": n.page_id,
                           "snap_valid": bool(n.snap_valid),
                           "sk_pages": list(n.sk_pages),
                           "last_used": n.last_used} for n in nodes],
                "free": list(self._free), "clock": self._clock}
        arrays = {
            "pool": self.pool,
            "snaps": {str(i): {name:
                               MIXERS[self._seg_kind(name)]
                               .dump_snapshot(s)
                               for name, s in n.snap.items()}
                      for i, n in enumerate(nodes) if n.snap},
            "sks": {str(i): n.sk_snap for i, n in enumerate(nodes)
                    if n.sk_snap is not None}}
        return meta, arrays

    def load_state(self, meta: Dict[str, Any],
                   arrays: Dict[str, Any]) -> None:
        """Rebuild the trie + pool on a freshly constructed cache from
        a checkpoint snapshot. Refcounts restore to 0 — the engine
        re-pins recording anchors from its restored slots. LRU clocks
        come back too, so post-restore eviction order matches the
        uninterrupted run."""
        nodes: List[_TrieNode] = []
        for rec in meta["nodes"]:
            parent = (self.root if rec["parent"] < 0
                      else nodes[rec["parent"]])
            node = _TrieNode(parent,
                             tuple(int(x) for x in rec["tokens"]),
                             int(rec["end"]))
            node.page_id = int(rec["page_id"])
            node.snap_valid = bool(rec["snap_valid"])
            node.sk_pages = [int(p) for p in rec["sk_pages"]]
            node.last_used = int(rec["last_used"])
            parent.children[node.tokens] = node
            self._nodes.add(node)
            nodes.append(node)
        for i, n in enumerate(nodes):
            snap = arrays.get("snaps", {}).get(str(i))
            if snap:
                n.snap = {name: MIXERS[self._seg_kind(name)]
                          .load_snapshot(h) for name, h in snap.items()}
            sk = arrays.get("sks", {}).get(str(i))
            if sk is not None:
                n.sk_snap = {name: {k: jnp.asarray(v)
                                    for k, v in h.items()}
                             for name, h in sk.items()}
        if self.pool:
            self.pool = jax.tree_util.tree_map(jnp.asarray,
                                               arrays["pool"])
        self._free = [int(p) for p in meta["free"]]
        self._clock = int(meta["clock"])
        self._heap = []
        for n in nodes:
            self._push(n)

    # -- introspection --------------------------------------------------------
    @property
    def record_span(self) -> int:
        """Max tokens a recording chunk may advance per dispatch: page
        capture reads the slot's rings once at chunk end, so every
        crossed page's positions must still be ring-resident then —
        bounded by the narrowest attention ring (sliding windows).
        Global-attention / recurrent-only stacks are unbounded (their
        rings span max_len / keep no pages)."""
        if not self._widths:
            return 1 << 30
        return max(self.page_size, min(self._widths.values()))

    @property
    def pages_in_use(self) -> int:
        return self.capacity - len(self._free)

    @property
    def referenced_nodes(self) -> int:
        """Trie nodes with a live refcount. Refs exist only while a
        slot prefills (the recording-anchor pin), so between ticks with
        no PREFILLING slot this must be 0 — the lifecycle audit checks
        it returns to baseline after any mix of finish / cancel /
        expire / fail (a leaked ref would pin pages against eviction
        forever)."""
        return sum(1 for n in self._nodes if n.refs > 0)

    def __len__(self) -> int:
        return len(self._nodes)
