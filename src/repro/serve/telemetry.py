"""Serving telemetry plane: metrics registry, per-request tracing,
Chrome-trace export, and A^3 approximation-quality probe aggregation.

Three pillars, all host-side and allocation-free on the hot path:

* ``MetricsRegistry`` — named counters, gauges, and fixed-bucket
  histograms.  Histograms use log-spaced nanosecond buckets whose
  bounds are precomputed at construction; ``observe`` is a single
  ``searchsorted`` into a preallocated int64 bucket array (no dict
  churn, no list append).  The engine's legacy ``stats`` dict is
  exported through a compatibility view at exposition time, so the
  dict itself stays a plain dict (checkpointing and the PrefixCache
  shared-reference contract are untouched).

* ``Tracer`` — a ring buffer (``deque(maxlen=...)``) of structured
  span/instant events keyed by request uid and slot, exportable as
  Chrome-trace JSON (``chrome://tracing`` / Perfetto).  Decode-block
  spans run dispatch→harvest, so a deferred-harvest pipeline stall is
  a visible gap on the slot's timeline rather than a bare counter.

* A^3 probe aggregation — the engine hands over per-dispatch probe
  rows (samples, mean candidate count, captured-score-mass ratio)
  that were computed in-graph and harvested on the already-landing
  ring read; this module only accumulates and exposes them.

Everything here is plain Python + numpy: no jax imports, so the
module is importable from analysis tooling without pulling in a
device runtime.
"""
from __future__ import annotations

import bisect
import collections
import json
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

# Log-spaced latency buckets: powers of two from 1us to ~1100s.  30
# buckets + overflow covers everything from a sub-tick host op to a
# stalled multi-minute drain without per-histogram tuning.
_NS_BUCKET_BOUNDS: Tuple[int, ...] = tuple(1 << s for s in range(10, 41))

# Dimensionless buckets for count-like histograms (candidate counts,
# token counts): powers of two from 1 to 2^20.
_COUNT_BUCKET_BOUNDS: Tuple[int, ...] = tuple(1 << s for s in range(0, 21))

# Unit-interval buckets for ratio histograms (captured score mass):
# dense near 1.0 where a healthy A^3 config lives.
_RATIO_BUCKET_BOUNDS: Tuple[float, ...] = (
    0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999, 1.0)

SCHEMA = "a3-serve-metrics/v1"


class Counter:
    """Monotone counter. ``inc`` is one float add."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-write-wins gauge."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram with zero-allocation recording.

    ``bounds`` are upper-inclusive bucket edges; one extra overflow
    bucket catches values above the last edge.  ``observe`` does a
    binary search over the precomputed edge list and a single int64
    increment into a preallocated numpy array — no allocation, no
    resizing, on the hot path.
    """

    __slots__ = ("name", "help", "bounds", "counts", "total", "sum")

    def __init__(self, name: str, bounds: Tuple[float, ...], help: str = "") \
            -> None:
        self.name = name
        self.help = help
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = np.zeros(len(self.bounds) + 1, dtype=np.int64)
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.total += 1
        self.sum += v

    # -- exposition / checkpoint -------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        return {"bounds": list(self.bounds),
                "counts": [int(c) for c in self.counts],
                "total": int(self.total), "sum": float(self.sum)}

    def load(self, snap: Dict[str, Any]) -> None:
        if list(snap.get("bounds", [])) != list(self.bounds):
            return  # bucket layout changed across versions: start fresh
        self.counts[:] = np.asarray(snap["counts"], dtype=np.int64)
        self.total = int(snap["total"])
        self.sum = float(snap["sum"])

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper edge of the q-bucket)."""
        if self.total == 0:
            return 0.0
        target = q * self.total
        run = 0
        for i, c in enumerate(self.counts):
            run += int(c)
            if run >= target:
                return self.bounds[i] if i < len(self.bounds) \
                    else float("inf")
        return float("inf")


class MetricsRegistry:
    """Named instruments plus a compatibility view over legacy stats.

    ``attach_stats`` registers a live reference to the engine's plain
    ``stats`` dict; exposition renders each entry as a counter named
    ``serve_<key>``.  The dict is read, never copied, at exposition
    time — the hot path never touches the registry for those.
    """

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._stats_views: List[Tuple[str, Dict[str, int]]] = []

    # -- instrument construction (idempotent by name) ----------------
    def counter(self, name: str, help: str = "") -> Counter:
        if name not in self._counters:
            self._counters[name] = Counter(name, help)
        return self._counters[name]

    def gauge(self, name: str, help: str = "") -> Gauge:
        if name not in self._gauges:
            self._gauges[name] = Gauge(name, help)
        return self._gauges[name]

    def histogram(self, name: str,
                  bounds: Tuple[float, ...] = _NS_BUCKET_BOUNDS,
                  help: str = "") -> Histogram:
        if name not in self._histograms:
            self._histograms[name] = Histogram(name, bounds, help)
        return self._histograms[name]

    def attach_stats(self, prefix: str, stats: Dict[str, int]) -> None:
        self._stats_views.append((prefix, stats))

    # -- exposition --------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "schema": SCHEMA,
            "counters": {n: c.value for n, c in sorted(
                self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.snapshot() for n, h in sorted(
                self._histograms.items())},
        }
        for prefix, stats in self._stats_views:
            for k in sorted(stats):
                out["counters"][f"{prefix}{k}"] = float(stats[k])
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (untyped stats render as counters)."""
        lines: List[str] = []
        snap = self.snapshot()
        for name, v in snap["counters"].items():
            base, labels = _split_labels(name)
            lines.append(f"# TYPE {base} counter")
            lines.append(f"{base}{labels} {_fmt(v)}")
        for name, v in snap["gauges"].items():
            base, labels = _split_labels(name)
            lines.append(f"# TYPE {base} gauge")
            lines.append(f"{base}{labels} {_fmt(v)}")
        for name, h in snap["histograms"].items():
            base, labels = _split_labels(name)
            lines.append(f"# TYPE {base} histogram")
            run = 0
            for bound, c in zip(h["bounds"], h["counts"]):
                run += c
                le = _merge_labels(labels, f'le="{_fmt(bound)}"')
                lines.append(f"{base}_bucket{le} {run}")
            le = _merge_labels(labels, 'le="+Inf"')
            lines.append(f"{base}_bucket{le} {h['total']}")
            lines.append(f"{base}_sum{labels} {_fmt(h['sum'])}")
            lines.append(f"{base}_count{labels} {h['total']}")
        return "\n".join(lines) + "\n"

    # -- checkpoint --------------------------------------------------
    def dump_state(self) -> Dict[str, Any]:
        return {"counters": {n: c.value for n, c in self._counters.items()},
                "gauges": {n: g.value for n, g in self._gauges.items()},
                "histograms": {n: h.snapshot()
                               for n, h in self._histograms.items()}}

    def load_state(self, state: Dict[str, Any]) -> None:
        for n, v in state.get("counters", {}).items():
            self.counter(n).value = float(v)
        for n, v in state.get("gauges", {}).items():
            self.gauge(n).value = float(v)
        for n, snap in state.get("histograms", {}).items():
            bounds = tuple(snap.get("bounds", _NS_BUCKET_BOUNDS))
            self.histogram(n, bounds).load(snap)


def _split_labels(name: str) -> Tuple[str, str]:
    """``ttft_ns{terminal=finished}`` -> (``ttft_ns``,
    ``{terminal="finished"}``) — label values are quoted on the way
    out so registry keys stay terse but the exposition is valid
    Prometheus text format."""
    if "{" not in name:
        return name, ""
    base, rest = name.split("{", 1)
    pairs = []
    for part in rest.rstrip("}").split(","):
        k, _, v = part.partition("=")
        v = v.strip()
        if not v.startswith('"'):
            v = f'"{v}"'
        pairs.append(f"{k.strip()}={v}")
    return base, "{" + ",".join(pairs) + "}"


def _merge_labels(labels: str, extra: str) -> str:
    if not labels:
        return "{" + extra + "}"
    return labels[:-1] + "," + extra + "}"


def _fmt(v: float) -> str:
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


# ---------------------------------------------------------------------------
# Tracing


class Tracer:
    """Ring-buffered structured event log with Chrome-trace export.

    Events are tuples ``(ts_ns, kind, name, uid, track, dur_ns, args)``
    where ``kind`` is ``"X"`` (complete span) or ``"i"`` (instant) in
    Chrome-trace phase terms, and ``track`` maps to a ``tid`` in the
    export (slot index, or a named lane like ``"queue"``/``"engine"``).
    Appending to a bounded deque is O(1) and drops the oldest event —
    the log is a flight recorder, not an archive.
    """

    def __init__(self, max_events: int = 4096) -> None:
        self.events: collections.deque = collections.deque(
            maxlen=max(1, int(max_events)))
        self.dropped = 0
        self._t0_ns = time.monotonic_ns()

    def now_ns(self) -> int:
        return time.monotonic_ns()

    def span(self, name: str, *, ts_ns: int, dur_ns: int,
             uid: int = -1, track: Any = "engine",
             args: Optional[Dict[str, Any]] = None) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append((ts_ns, "X", name, uid, track, max(0, dur_ns),
                            args))

    def instant(self, name: str, *, uid: int = -1, track: Any = "engine",
                ts_ns: Optional[int] = None,
                args: Optional[Dict[str, Any]] = None) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append((ts_ns if ts_ns is not None
                            else time.monotonic_ns(),
                            "i", name, uid, track, 0, args))

    # -- export ------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """chrome://tracing JSON object (``ts``/``dur`` in microseconds)."""
        t0 = self._t0_ns
        out: List[Dict[str, Any]] = []
        for ts, ph, name, uid, track, dur, args in self.events:
            ev: Dict[str, Any] = {
                "name": name, "ph": ph, "pid": 0,
                "tid": track if isinstance(track, int) else str(track),
                "ts": (ts - t0) / 1e3,
            }
            if ph == "X":
                ev["dur"] = dur / 1e3
            if ph == "i":
                ev["s"] = "t"
            a = dict(args) if args else {}
            if uid >= 0:
                a["uid"] = uid
            if a:
                ev["args"] = a
            out.append(ev)
        return {"traceEvents": out,
                "displayTimeUnit": "ms",
                "otherData": {"schema": "a3-serve-trace/v1",
                              "dropped_events": self.dropped}}


# ---------------------------------------------------------------------------
# Per-request lifecycle tracking


class _ReqTrack:
    __slots__ = ("submit_ns", "admit_ns", "first_tok_ns", "slot",
                 "decode_steps")

    def __init__(self, submit_ns: int) -> None:
        self.submit_ns = submit_ns
        self.admit_ns = -1
        self.first_tok_ns = -1
        self.slot = -1
        self.decode_steps = 0


class Telemetry:
    """Bundle the engine owns when telemetry is enabled.

    One instance per engine; every hook is a plain method call so the
    engine's guard is a single ``is not None`` check and the off-path
    stays byte-for-byte the pre-telemetry code.
    """

    def __init__(self, *, trace_events: int = 4096,
                 telemetry_every: int = 8) -> None:
        self.registry = MetricsRegistry()
        self.tracer = Tracer(trace_events)
        self.telemetry_every = max(1, int(telemetry_every))
        r = self.registry
        self._h_ttft: Dict[str, Histogram] = {}
        self._h_sojourn: Dict[str, Histogram] = {}
        self.h_tpot = r.histogram(
            "serve_tpot_ns",
            help="per-token decode latency (finished requests; "
                 "decode wall time / decoded tokens)")
        self.h_decode_block = r.histogram(
            "serve_decode_block_ns",
            help="decode-block dispatch->harvest wall time")
        self.h_prefill_chunk = r.histogram(
            "serve_prefill_chunk_ns",
            help="prefill chunk dispatch wall time")
        self.h_a3_cand = r.histogram(
            "serve_a3_candidates", _COUNT_BUCKET_BOUNDS,
            help="A^3 mean candidate count per probed decode step")
        self.h_a3_mass = r.histogram(
            "serve_a3_captured_mass", _RATIO_BUCKET_BOUNDS,
            help="A^3 captured score mass: selected softmax mass / "
                 "full softmax mass, per probed decode step")
        self.c_probe_dispatches = r.counter(
            "serve_a3_probe_dispatches",
            help="decode dispatches that carried the in-graph probe")
        self.c_probe_samples = r.counter(
            "serve_a3_probe_samples",
            help="probed (slot, step) samples harvested")
        self.c_trace_dropped = r.counter(
            "serve_trace_events_dropped",
            help="ring-buffer evictions in the trace log")
        self._reqs: Dict[int, _ReqTrack] = {}

    # -- lazy labeled histograms -------------------------------------
    def _ttft(self, terminal: str) -> Histogram:
        h = self._h_ttft.get(terminal)
        if h is None:
            h = self.registry.histogram(
                "serve_ttft_ns{terminal=%s}" % terminal,
                help="submit -> first emitted token")
            self._h_ttft[terminal] = h
        return h

    def _sojourn(self, terminal: str) -> Histogram:
        h = self._h_sojourn.get(terminal)
        if h is None:
            h = self.registry.histogram(
                "serve_queue_sojourn_ns{terminal=%s}" % terminal,
                help="submit -> slot admission")
            self._h_sojourn[terminal] = h
        return h

    # -- request lifecycle hooks -------------------------------------
    def on_submit(self, uid: int) -> None:
        now = self.tracer.now_ns()
        self._reqs[uid] = _ReqTrack(now)
        self.tracer.instant("submit", uid=uid, track="queue", ts_ns=now)

    def on_admit(self, uid: int, slot: int, *, reused_tokens: int = 0) \
            -> None:
        t = self._reqs.get(uid)
        now = self.tracer.now_ns()
        if t is not None:
            t.admit_ns = now
            t.slot = slot
            self.tracer.span("queued", ts_ns=t.submit_ns,
                             dur_ns=now - t.submit_ns, uid=uid,
                             track="queue")
        args = {"slot": slot}
        if reused_tokens:
            args["prefix_tokens_reused"] = reused_tokens
        self.tracer.instant("admit", uid=uid, track=slot, args=args)

    def on_prefill_chunk(self, uid: int, slot: int, *, ts_ns: int,
                         dur_ns: int, pos: int, chunk: int) -> None:
        self.h_prefill_chunk.observe(dur_ns)
        self.tracer.span("prefill", ts_ns=ts_ns, dur_ns=dur_ns, uid=uid,
                         track=slot, args={"pos": pos, "chunk": chunk})

    def on_first_token(self, uid: int) -> None:
        t = self._reqs.get(uid)
        if t is not None and t.first_tok_ns < 0:
            t.first_tok_ns = self.tracer.now_ns()
            self.tracer.instant("first_token", uid=uid,
                                track=t.slot if t.slot >= 0 else "engine")

    def on_decode_steps(self, uid: int, steps: int) -> None:
        t = self._reqs.get(uid)
        if t is not None:
            t.decode_steps += steps

    def on_decode_block(self, slot_uids: List[Tuple[int, int]], *,
                        ts_ns: int, dur_ns: int, steps: int,
                        deferred: bool) -> None:
        self.h_decode_block.observe(dur_ns)
        for slot, uid in slot_uids:
            self.tracer.span("decode_block", ts_ns=ts_ns, dur_ns=dur_ns,
                             uid=uid, track=slot,
                             args={"steps": steps,
                                   "deferred": bool(deferred)})

    def on_terminal(self, uid: int, terminal: str) -> None:
        t = self._reqs.pop(uid, None)
        now = self.tracer.now_ns()
        if t is None:
            return
        if t.admit_ns >= 0:
            self._sojourn(terminal).observe(t.admit_ns - t.submit_ns)
        if t.first_tok_ns >= 0:
            self._ttft(terminal).observe(t.first_tok_ns - t.submit_ns)
            if terminal == "finished" and t.decode_steps > 0:
                self.h_tpot.observe(
                    (now - t.first_tok_ns) / t.decode_steps)
        self.tracer.instant("terminal", uid=uid,
                            track=t.slot if t.slot >= 0 else "queue",
                            args={"state": terminal})

    # -- subsystem events --------------------------------------------
    def event(self, name: str, *, uid: int = -1, track: Any = "engine",
              **args: Any) -> None:
        self.tracer.instant(name, uid=uid, track=track,
                            args=args or None)

    def span(self, name: str, *, ts_ns: int, dur_ns: int, uid: int = -1,
             track: Any = "engine", **args: Any) -> None:
        self.tracer.span(name, ts_ns=ts_ns, dur_ns=dur_ns, uid=uid,
                         track=track, args=args or None)

    # -- A^3 probe ----------------------------------------------------
    def on_a3_probe(self, probe: np.ndarray) -> None:
        """``probe`` is ``[B, 3]`` float32: per-lane (samples,
        sum(candidates), sum(captured-mass ratio)) accumulated over the
        dispatched block's advanced steps."""
        self.c_probe_dispatches.inc()
        samples = probe[:, 0]
        live = samples > 0
        n = float(samples[live].sum())
        if n <= 0:
            return
        self.c_probe_samples.inc(n)
        for cand, mass in zip(probe[live, 1] / samples[live],
                              probe[live, 2] / samples[live]):
            self.h_a3_cand.observe(float(cand))
            self.h_a3_mass.observe(float(mass))

    # -- exposition / checkpoint -------------------------------------
    def metrics_snapshot(self) -> Dict[str, Any]:
        self.c_trace_dropped.value = float(self.tracer.dropped)
        return self.registry.snapshot()

    def dump_state(self) -> Dict[str, Any]:
        self.c_trace_dropped.value = float(self.tracer.dropped)
        return {"registry": self.registry.dump_state()}

    def load_state(self, state: Dict[str, Any]) -> None:
        self.registry.load_state(state.get("registry", {}))
        # Re-resolve labeled handles that load_state may have created.
        for name, h in self.registry._histograms.items():
            if name.startswith("serve_ttft_ns{terminal="):
                self._h_ttft[name.split("=")[1].rstrip("}")] = h
            elif name.startswith("serve_queue_sojourn_ns{terminal="):
                self._h_sojourn[name.split("=")[1].rstrip("}")] = h

    def write_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.tracer.chrome_trace(), f)

    def write_metrics(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.metrics_snapshot(), f, indent=2, sort_keys=True)
