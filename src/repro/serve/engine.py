"""Serving engine: paged admission with shared-prefix reuse, chunked +
ragged admission prefill for EVERY architecture, and multi-step
*scanned* decode with slot-based continuous batching, plus the A^3
approximate decode path.

The engine holds a fixed number of request *slots*. Every engine tick
runs the admission state machine::

    admit -----------> chunked prefill ------> blocked decode
    (trie walk +       (suffix only;           (T x [in-graph resort
     paged gather)      + in-graph handoff)        -> step -> sample])

* **Admit — trie walk + paged gather.** Queued requests claim free
  slots. With the paged prefix cache enabled (``cache_pages > 0``), a
  submit first walks the radix trie over the prompt's ``page_size``-
  token pages (:mod:`repro.serve.prefix_cache`); every matched page is
  gathered into the slot's per-segment cache with ONE jitted copy
  dispatch — attention ring rows from pool pages, recurrent carries
  from the matched node's boundary snapshot (the chunked-prefill carry
  *is* the snapshot), and the A^3 sorted columns + ``sorted_upto``
  watermark restored at the boundary, so reuse triggers no re-sort.
  The slot's prompt cursor starts at the matched length and only the
  unmatched *suffix* chunk-prefills (always >= 1 token: a full hit is
  capped one page short, so the final chunk still produces the
  next-token logits and re-folds the A^3 sort exactly like a cold
  admission). ``stats["prefix_hits"]`` / ``stats["prefix_tokens_reused"]``
  count the reuse; ``prefill_tokens`` counts only suffix tokens, so a
  cold run's ``prefill_tokens`` equals a warm run's ``prefill_tokens +
  prefix_tokens_reused`` on the same workload. On a miss (or with the
  cache disabled) admission is unchanged: no cache work at admit time —
  the slot's first chunk dispatch resets its mixer state in-graph.
  Admitted prompts are *recorded* as they prefill: chunks clamp to page
  boundaries, each boundary copies one page pool-ward and snapshots the
  recurrent carry into a new trie node (refcounted; LRU-evicted under
  the ``cache_pages`` budget), and divergent requests copy-on-write by
  recording sibling pages — pool pages are never mutated.
* **Chunked ragged prefill — one dispatch per tick, every arch.** All
  PREFILLING slots advance by at most ``prefill_chunk`` prompt tokens
  in a *single* jitted ``prefill_chunk`` dispatch: a padded
  ``[slots, chunk]`` token block with per-slot start positions and
  lengths (lanes not prefilling ride along with length 0 and their
  cache rows pass through untouched). The per-segment mixer-state
  interface (``repro.models.mixer``) carries mid-prompt state for
  recurrent segments across chunk boundaries, so hybrid RG-LRU / xLSTM
  stacks admit through the same bounded-tick path as attention-only
  ones — there is no whole-prompt fallback. Long prompts therefore
  never stall decoding slots for more than one chunk, and multiple
  queued prompts prefill together. ``stats["prefill_dispatches"]``
  counts these dispatches; it is at most ``stats["ticks"]`` by
  construction. ``prefill_chunk=None`` uses a default chunk of
  ``min(max_len, 512)`` — same dispatch, bounded working set; short
  prompts still admit in a single dispatch. With
  ``prefill_chunk_min`` set, the effective chunk *adapts*: ticks where
  >= 1 slot is actively decoding shrink it to the floor (bounding the
  stall those decoders see), while a cold queue drains at the full
  chunk (``stats["adaptive_shrink_ticks"]`` counts shrunk prefill
  ticks). Chunking — fixed or adaptive — never changes outputs.
* **Device-resident prefill -> decode handoff.** The prefill dispatch
  samples each finishing lane's first token in-graph and returns it as
  a device array; the same tick's decode block consumes it directly
  (``jnp.where`` over the token lane vector) and the host learns it
  from the *decode* harvest — prefill ticks do not block. Only when a
  prompt finishes with no decode dispatch to ride (budget exhausted by
  its first token, or the prompt already at ``max_len``) does the
  engine read the first-token array directly; ``stats["handoff_syncs"]``
  counts those rare reads.
* **Blocked decode — T steps per dispatch, fully device-resident.**
  ``decoder.decode_block`` runs ``decode_block`` = T decode steps under
  one jitted ``lax.scan``: each step samples its successor token from
  its own on-device logits (greedy argmax; temperature hook behind
  ``ServeConfig``), re-sorts due lanes' A^3 key columns in-graph, and
  appends to an on-device ``[slots, T]`` token ring. The host syncs
  *once per block* to harvest the ring (prepended with the block's
  input tokens, which carries any prefill-handoff first tokens along
  for free) and run the finish/admit state machine. Lanes that exhaust
  their budget or hit ``max_len`` mid-block ride along at ``pos = -1``
  with dropped ring writes and bit-identical (masked) recurrent state.
  ``stats["decode_steps"]`` counts executed scan iterations
  (``decode_block x decode_dispatches``);
  ``stats["decode_steps_advanced"]`` counts the subset that advanced
  at least one lane — the gap is partial-block padding, and dispatch
  efficiency obeys the falsifiable bound ``decode_dispatches <=
  ceil(decode_steps_advanced / T) + prefill_dispatches`` (a partial
  block means every active lane finished, which can only follow a
  prefill dispatch that flipped its cohort). ``stats["host_syncs"]``
  counts blocking device reads — one ring harvest per decode dispatch
  plus the rare direct handoff reads, so ``host_syncs <=
  decode_dispatches + handoff_syncs``.
* **Pipelined tick loop — device-resident carry + deferred harvest.**
  ``decode_block`` also returns each lane's *last* scan token as a
  device array (the cross-block token carry): the next block's input
  token vector is that carry, so back-to-back decode dispatches chain
  entirely on device with no host readback in between. With
  ``pipeline_depth = d > 0`` the ring harvest itself is *deferred* —
  each dispatch's ``[slots, 1+T]`` harvest array is queued, and BEFORE
  each tick's dispatch the loop force-lands only the over-``d`` oldest
  rings (dispatched ``d+1`` ticks ago, so the device has normally long
  finished them) plus any newer rings that already completed. Up to
  ``d`` blocks therefore stay in flight behind the device at all
  times: the pipe stays primed, the device never drains dry waiting on
  host bookkeeping, and the blocking host reads mostly find their data
  ready (``host_sync_stalls`` counts the ones that did not). Host
  bookkeeping
  acts on the one-tick-delayed view: slot ``pos``/``budget`` advance
  optimistically at dispatch time (the advance is deterministic in the
  control words), while finish/poison/A^3-resort accounting runs at
  harvest, guarded by per-row ``uid`` checks and a per-slot ``pending``
  count so stale rows from released slots are dropped and a slot is
  only FINISHED once its rings have all landed. ``pipeline_depth = 0``
  harvests synchronously and is bit-identical to the historical
  engine. Timeline at ``d = 1`` (H(n) = deferred harvest of block n,
  issued before that tick's dispatch; block n is always fully behind
  the device by the time its forced read issues)::

      tick:      1          2          3          4          5
      device:  [block 1]  [block 2]  [block 3]  [block 4]  [block 5]
      host:     dispatch   dispatch   H(1)       H(2)       H(3)
                                      dispatch   dispatch   dispatch

  Checkpoints drain all pending harvests first, so snapshots stay
  host-consistent and ``pending`` never serializes. On hosts where
  XLA compute timeshares the tick loop's cores (single-core CI) the
  overlap cannot move wall clock; the
  ``virtual_device_latency_s`` constructor knob emulates an
  accelerator's completion latency per decode block (a GIL-releasing
  readiness floor on each queued harvest) so benches and tests can
  observe the pipeline hiding device time that a synchronous loop
  serializes on. Token streams are never affected by the knob.
* **Packed control-block uploads.** All per-tick host->device control
  scalars (prefill start/len/sort/sample columns; decode pos/budget/
  sample ids/handoff mask) ride ONE packed int32 ``[slots, CTRL_COLS]``
  array per tick; both the prefill and decode jits slice their columns
  in-graph, so a tick issues a single small upload plus the token
  block instead of ~9 scattered transfers. Per-phase wall time lands
  in ``stats["tick_ns_prefill"] / tick_ns_decode / tick_ns_harvest /
  tick_ns_host``, and ``stats["host_sync_stalls"]`` counts harvests
  that actually blocked on an unfinished device computation
  (``is_ready()`` false at drain time).
* **Cache donation.** Both the prefill-chunk and decode-block jits
  donate the cache argument, so ring buffers and recurrent states
  update in place instead of being copied each tick.
* **In-graph A^3 re-sort — zero host watermark reads.** The
  ``sorted_upto`` watermark check lives inside the decode dispatch
  (``decoder.resort_sorted_keys``): per segment, a ``lax.cond`` folds a
  due lane's fresh tail into its sorted key columns when
  ``pos - sorted_upto >= resort_every``. The host mirrors the watermark
  arithmetic (it is deterministic in ``pos``) to keep the
  ``stats["resorts"]`` counter without any device read.

A^3 state at serve time: the paper's "comprehension-time" preprocessing
maps to prefill — the prompt's keys are column-sorted per slot and
reused across all decode steps (amortization argument of SSIV-C). With
chunked prefill the sort stays once-per-prompt: the dispatch of a
prompt's *final* chunk folds the completed ring into the per-column
sorted matrices and advances the ``sorted_upto`` watermark (a
``lax.cond`` skips the sort on every other tick — nothing reads a
PREFILLING slot's sort). Tokens generated after prefill form the
*fresh tail*, always treated as candidates (exact attention) until an
in-graph re-sort folds them in.

``make_serve_step`` / ``make_decode_block_step`` /
``make_prefill_chunk_step`` build the jitted dispatches used by both
the engine and the multi-pod dry-run (they are what the ``decode_*`` /
chunked-prefill shapes lower).

Request lifecycle
-----------------

Every submitted request moves through the state machine below; the
terminal states are exactly {FINISHED, REJECTED, CANCELLED, EXPIRED,
FAILED} and a request reaches exactly one of them::

    submit() ──────────────> REJECTED   (queue full w/ reject-new,
       │                                 or engine draining)
       v
    QUEUED ────────────────> REJECTED   (shed by evict-oldest-queued)
       │        ├──────────> CANCELLED  (cancel(uid) / drain())
       │        └──────────> EXPIRED    (deadline_ticks elapsed)
       v  admit (slot free; prefix-cache gather may chaos-FAIL)
    PREFILLING ────────────> CANCELLED | EXPIRED | FAILED
       v  prompt exhausted (first token sampled in-graph)
    DECODING ──────────────> CANCELLED | EXPIRED
       │        └──────────> FAILED     (non-finite logits: the lane
       │                                 emits the POISON sentinel on
       v                                 the harvested ring)
    FINISHED    (budget exhausted or max_len reached)

    ── durability (orthogonal to the per-request lifecycle) ──────────
    any state ──checkpoint()──> <directory>     (atomic rename commit;
       │                                         every QUEUED /
       │                                         PREFILLING / DECODING
       │                                         request snapshots
       │                                         mid-flight)
       X  crash (EngineCrash / process death: partial tick discarded)
       │
    ServeEngine.restore() ──> same states as at checkpoint() — ticking
    on yields token-for-token the uninterrupted run's outputs for
    every in-flight request (greedy argmax and the (seed, uid, pos)-
    keyed sampler are both replay-deterministic; the device cache,
    prefix trie, pool pages, and L2 blobs round-trip bit-exactly)

Releasing a slot from ANY in-flight state reclaims it the same tick
(cancel/expire/poison never strand a lane) and drops the request's
prefix-cache recording pin, so trie refcounts return to baseline — no
leaked pages. The stats counters obey the conservation identity
checked by the lifecycle tests::

    submitted == finished + rejected + cancelled + expired + failed
                 + in_flight            (in_flight = queued + on-slot)

Overload policy: ``max_queue == 0`` keeps the historical unbounded
deque; ``max_queue > 0`` bounds it, and ``shed_policy`` picks the
victim — ``reject-new`` sheds the arriving request, ``evict-oldest-
queued`` sheds the head of the queue (freshest-first service under
overload). ``drain()`` enters graceful shutdown: queued work is
cancelled, in-flight work finishes, new submits are rejected.

Telemetry span lifecycle
------------------------

With ``telemetry=True`` every request leaves a timeline in the ring-
buffered trace (:mod:`repro.serve.telemetry`; Chrome-trace export).
Spans and instants per request, in lifecycle order — tracks (``tid``)
are the queue lane or the slot index the request occupies::

    track "queue":  submit ▸──────[ queued ]──────▸ admit
                      │   (queue-sojourn histogram, by terminal)
    track slot i:         admit ▸ [prefill c0][prefill c1]...[prefill cN]
                                  (one span per chunk dispatch, shared
                                   ragged-dispatch wall time)
                          ▸ first_token        (TTFT histogram keyed by
                                                terminal state; sampled
                                                in-graph, stamped when
                                                the harvest lands)
                          ▸ [decode_block][decode_block]...
                                  (span = dispatch -> harvest: a
                                   deferred-harvest stall is a visible
                                   gap; TPOT histogram at terminal)
                          ▸ terminal {finished|cancelled|expired|failed}
    track "engine": host_sync_stall / checkpoint / restore /
                    chaos_{delay,corrupt,spill,abort,gather_fail} /
                    prefix-cache + L2 events (hit/evict/demote/promote)

Metrics land in the registry (``serve_ttft_ns{terminal=...}``,
``serve_tpot_ns``, ``serve_queue_sojourn_ns{...}``, A^3
``serve_a3_captured_mass`` / ``serve_a3_candidates`` probe histograms
sampled every ``telemetry_every`` decode dispatches), with the legacy
``stats`` dict exported as ``serve_*`` counters through a zero-cost
compatibility view. Telemetry off is bit-identical to the
untelemetered engine; telemetry on adds **zero host syncs** — probes
ride the deferred ring drain the host already performs.

Chaos injection: constructed with a ``serve.chaos.ChaosInjector`` the
engine consults the injector at tick phase boundaries (delay / abort),
before decode dispatches (corrupt one decoding lane's mixer state so
its logits go non-finite), and inside warm prefix-cache admissions
(fail the page gather). Faults are quarantined per request; the chaos
conformance tests assert every un-injected request's token stream is
bit-identical to a chaos-free run and that ``host_syncs`` does not
grow (poison detection rides the existing per-block ring harvest).
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import shutil
import time
import zlib
from typing import Any, Callable, Deque, Dict, List, NamedTuple, Optional, \
    Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import A3Config, A3Mode, ModelConfig, ServeConfig
from repro.models import decoder
from repro.serve.chaos import ChaosError, ChaosInjector, EngineCrash, \
    corrupt_cache_lane
from repro.serve.page_store import CheckpointError, IntegrityError, \
    deserialize_tree, serialize_tree
from repro.serve.prefix_cache import PrefixCache
from repro.serve.telemetry import Telemetry


def make_serve_step(
    cfg: ModelConfig,
    a3: A3Config = A3Config(),
    *,
    use_kernel: bool = False,
) -> Callable:
    """Returns step(params, cache, token [B], pos scalar or [B]) ->
    (logits [B, Vp], new_cache)."""

    def step(params, cache, token, pos):
        return decoder.decode_step(params, cfg, cache, token, pos, a3=a3,
                                   use_kernel=use_kernel)

    return step


# Packed control-word layout: the per-tick scatter of small host int
# vectors (prefill pos/length/sort/sample columns, decode pos/budget/
# uid/handoff columns) collapses into ONE [slots, CTRL_COLS] int32
# upload shared by the prefill and decode dispatches — each jit slices
# the columns it needs in-graph, so a steady-state decode tick uploads
# exactly one small array (the token vector rides the device-resident
# carry and never leaves the device at all).
CTRL_P_POS = 0        # prefill: per-lane chunk start position
CTRL_P_LEN = 1        # prefill: per-lane chunk length (0 = ride-along)
CTRL_P_SORT = 2       # prefill: 1 = final chunk (fold the A^3 sort)
CTRL_P_SPOS = 3       # prefill: sampling position for the handoff draw
CTRL_P_SIDS = 4       # prefill: sampling uid for the handoff draw
CTRL_D_POS = 5        # decode: per-lane next position (-1 = ride-along)
CTRL_D_STEPS = 6      # decode: per-lane steps_left budget for the block
CTRL_D_IDS = 7        # decode: per-request sampling uid
CTRL_D_HMASK = 8      # decode: 1 = take the handoff first-token lane
CTRL_COLS = 9


def make_decode_block_step(
    cfg: ModelConfig,
    a3: A3Config = A3Config(),
    *,
    steps: int = 1,
    use_kernel: bool = False,
    resort_every: int = 0,
    temperature: float = 0.0,
    probe: bool = False,
) -> Callable:
    """Returns the blocked-decode dispatch: step(params, cache,
    token [B], first_tok [B], ctrl [B, CTRL_COLS][, rng]) ->
    (harvest [B, 1+steps], carry [B], new_cache). ``steps`` decode
    iterations run device-resident under one ``lax.scan`` — in-graph
    sampling feeds each step's token from the previous step's logits,
    and ``resort_every > 0`` folds due lanes' A^3 fresh tails into the
    sorted key columns in-graph (no host watermark read).

    All small per-lane scalars (pos / steps_left / sample uid / the
    handoff mask) arrive packed in the ``ctrl`` int32 block and are
    sliced in-graph (``CTRL_D_*`` columns), so one upload feeds the
    whole dispatch. The prefill->decode handoff select also happens
    in-graph: lanes with ``ctrl[:, CTRL_D_HMASK]`` set take their input
    token from ``first_tok`` (the prefill dispatch's device-resident
    output). The returned ``harvest`` prepends the effective input
    token column to the ring — it is the ONE array a host ever reads
    back, and the read is deferrable: ``carry`` is the scan's final
    per-lane token, feeding the next block's ``token`` argument
    directly so chained blocks never wait on a harvest. The ``rng``
    argument exists only when ``temperature > 0`` (greedy dispatches
    keep the production signature the dry-run lowers).

    ``probe=True`` builds the A^3 telemetry variant: the dispatch
    returns ``(harvest, probe [B, 3], carry, new_cache)`` where the
    probe accumulates in-graph (samples, candidate-count sum,
    captured-score-mass-ratio sum) per lane over the block's advanced
    steps — harvested alongside the ring at the same deferred read, so
    sampling it adds zero host syncs. The token path runs identical
    ops (see :func:`repro.models.decoder.decode_block`)."""

    def _run(params, cache, token, first_tok, ctrl, rng=None):
        token = jnp.where(ctrl[:, CTRL_D_HMASK] > 0, first_tok, token)
        out = decoder.decode_block(
            params, cfg, cache, token, ctrl[:, CTRL_D_POS],
            ctrl[:, CTRL_D_STEPS], steps=steps, a3=a3,
            use_kernel=use_kernel, resort_every=resort_every,
            temperature=temperature, rng=rng,
            sample_ids=ctrl[:, CTRL_D_IDS], probe=probe)
        if probe:
            ring, carry, cache, pr = out
            harvest = jnp.concatenate([token[:, None], ring], axis=1)
            return harvest, pr, carry, cache
        ring, carry, cache = out
        harvest = jnp.concatenate([token[:, None], ring], axis=1)
        return harvest, carry, cache

    if temperature > 0.0:
        def step(params, cache, token, first_tok, ctrl, rng):
            return _run(params, cache, token, first_tok, ctrl, rng)
    else:
        def step(params, cache, token, first_tok, ctrl):
            return _run(params, cache, token, first_tok, ctrl)

    return step


def make_prefill_chunk_step(cfg: ModelConfig, *, a3: bool = False,
                            update_sort: bool = True,
                            temperature: float = 0.0) -> Callable:
    """Returns step(params, cache, tokens [B, C], ctrl [B, CTRL_COLS]
    [, rng]) -> (first_tok [B], new_cache) — the ragged chunked-prefill
    dispatch with the device-resident prefill->decode handoff: each
    lane's next-token draw from its last valid position's logits
    happens in-graph, so finishing lanes hand their first generated
    token straight to the same tick's decode block without a blocking
    read (non-finishing lanes' entries are meaningless and ignored).
    The per-lane scalars ride the shared packed ``ctrl`` upload
    (``CTRL_P_*`` columns): chunk start ``pos``, chunk ``length``,
    ``sort_lanes`` marking lanes on their final chunk (A^3: fold the
    completed prompt into the column sort), and the handoff draw's
    sampling position / uid. ``update_sort=False`` builds the cheaper
    specialization that treats the sorted-key leaves as read-only
    (dispatched on ticks where no lane finishes its prompt). The
    ``rng`` argument exists only when ``temperature > 0`` (greedy
    dispatches keep the production signature)."""

    def _mark_poison(tok, logits):
        # poison quarantine rides the handoff: a finishing lane whose
        # prompt logits are non-finite hands POISON to the decode block
        # (or the direct read) instead of a garbage token — healthy
        # lanes take the identical select, bit-for-bit
        finite = jnp.all(jnp.isfinite(logits), axis=-1)
        return jnp.where(finite, tok, decoder.POISON)

    if temperature > 0.0:
        def step(params, cache, tokens, ctrl, rng):
            logits, cache = decoder.prefill_chunk(
                params, cfg, cache, tokens, ctrl[:, CTRL_P_POS],
                ctrl[:, CTRL_P_LEN], a3=a3,
                sort_lanes=ctrl[:, CTRL_P_SORT] > 0,
                update_sort=update_sort)
            tok = decoder.sample_logits(logits, temperature=temperature,
                                        rng=rng,
                                        pos=ctrl[:, CTRL_P_SPOS],
                                        ids=ctrl[:, CTRL_P_SIDS])
            return _mark_poison(tok, logits), cache
    else:
        def step(params, cache, tokens, ctrl):
            logits, cache = decoder.prefill_chunk(
                params, cfg, cache, tokens, ctrl[:, CTRL_P_POS],
                ctrl[:, CTRL_P_LEN], a3=a3,
                sort_lanes=ctrl[:, CTRL_P_SORT] > 0,
                update_sort=update_sort)
            return _mark_poison(decoder.sample_logits(logits),
                                logits), cache

    return step


class Request(NamedTuple):
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int
    deadline: Optional[int] = None   # absolute tick, None = no deadline


# slot phases (doubling as the in-flight request statuses)
IDLE = "idle"
PREFILLING = "prefilling"
DECODING = "decoding"

# request lifecycle statuses (see the module docstring's state diagram)
QUEUED = "queued"
FINISHED = "finished"
REJECTED = "rejected"
CANCELLED = "cancelled"
EXPIRED = "expired"
FAILED = "failed"

# terminal status -> stats counter (the conservation identity's terms)
_TERMINAL = {FINISHED: "finished", REJECTED: "rejected",
             CANCELLED: "cancelled", EXPIRED: "expired", FAILED: "failed"}

SHED_POLICIES = ("reject-new", "evict-oldest-queued")

# admission chunk when ServeConfig.prefill_chunk is None: bounds the
# chunk dispatch's per-layer score/scan working set independent of
# max_len (prompts <= 512 still admit in a single dispatch)
_DEFAULT_ADMIT_CHUNK = 512


@dataclasses.dataclass
class SlotState:
    uid: int = -1
    pos: int = 0                  # next position to write
    generated: List[int] = dataclasses.field(default_factory=list)
    budget: int = 0
    phase: str = IDLE
    prompt: Optional[np.ndarray] = None
    cursor: int = 0               # prompt tokens prefilled so far
    # host-side mirror of the in-graph A^3 ``sorted_upto`` watermark
    # (deterministic in pos; keeps stats["resorts"] without device reads)
    sorted_upto: int = 0
    # prefix-cache recording anchor: the trie node whose boundary the
    # cursor last crossed (ref-pinned against eviction while the slot
    # prefills); None = not recording (cache disabled / budget exhausted)
    rec_node: Any = None
    # absolute tick by which the request must finish (None = never):
    # enforced at tick boundaries by the engine's expiry sweep
    deadline: Optional[int] = None
    # number of in-flight (unharvested) ring blocks referencing this
    # lane: ``pos``/``budget`` advance optimistically at dispatch, but
    # the lane may not FINISH until every referencing harvest has
    # landed (its tokens live only on the device until then)
    pending: int = 0

    @property
    def active(self) -> bool:
        """Occupied (prefilling or decoding)."""
        return self.phase != IDLE

    @property
    def decoding(self) -> bool:
        return self.phase == DECODING


@dataclasses.dataclass
class _PendingHarvest:
    """One dispatched decode block whose ring is still device-side.

    ``full`` is the dispatch's harvest output ``[slots, 1+T]`` (input
    token column + ring). The host bookkeeping needed to land it is
    frozen at dispatch time: ``handoff`` lanes take their first token
    from column 0, ``lanes`` carry (slot, uid, steps-this-block,
    position-before-block) for the generated/extend + A^3 watermark
    mirror, and ``refs`` maps every referenced slot to the uid it held
    at dispatch — a lane released (cancel / expire / poison) while its
    harvest was in flight fails the uid guard and its rows are
    dropped, never misattributed to a successor request."""
    full: Any
    handoff: List[Tuple[int, int]]
    lanes: List[Tuple[int, int, int, int]]
    refs: Dict[int, int]
    # virtual-device emulation: earliest monotonic time this block is
    # allowed to be read (0.0 = no emulation, real readiness governs)
    ready_at: float = 0.0
    # telemetry: the A^3 quality-probe array ([slots, 3], present only
    # on sampled dispatches — it rides the same drain as ``full``, so
    # reading it adds no host sync event) and the dispatch timestamp
    # (monotonic ns) anchoring the block's trace span
    probe: Any = None
    t_dispatch: int = 0


class ServeEngine:
    """Slot-based batched serving. Single-host reference implementation —
    the sharded path reuses make_serve_step / make_prefill_chunk_step
    under a mesh (launch.serve)."""

    def __init__(self, params: Any, cfg: ModelConfig, *, slots: int = 4,
                 max_len: int = 2048, a3: A3Config = A3Config(),
                 resort_every: int = 64,
                 prefill_chunk: Optional[int] = None,
                 prefill_chunk_min: Optional[int] = None,
                 decode_block: int = 1, use_kernel: bool = False,
                 temperature: float = 0.0, sample_seed: int = 0,
                 page_size: int = 64, cache_pages: int = 0,
                 max_queue: int = 0, shed_policy: str = "reject-new",
                 deadline_ticks: Optional[int] = None,
                 kv_quant: str = "none", l2_bytes: int = 0,
                 pipeline_depth: int = 0,
                 virtual_device_latency_s: float = 0.0,
                 telemetry: bool = False, telemetry_every: int = 8,
                 trace_events: int = 4096, retain_results: int = 0,
                 chaos: Optional[ChaosInjector] = None):
        if cfg.frontend:
            # the engine admits token prompts; frontend archs (audio /
            # vision) need precomputed embeddings the submit() API cannot
            # carry — raise instead of silently serving garbage tokens
            raise ValueError(
                f"{cfg.name}: frontend archs serve from precomputed "
                f"embeddings; the token-prompt ServeEngine does not "
                f"support them")
        self.params, self.cfg, self.a3 = params, cfg, a3
        self.max_len = max_len
        self._use_a3 = a3.mode != A3Mode.OFF
        # clamp to >= 1: the in-graph dispatch treats resort_every <= 0
        # as "resort disabled", while the historical host-side meaning
        # of 0 was "resort whenever any fresh tail exists" — which is
        # what 1 expresses (0 would only add no-op sorts at pos == upto)
        self.resort_every = max(1, int(resort_every))
        # every arch admits through the chunked path (the mixer-state
        # interface carries recurrent mid-prompt state across chunks);
        # None = a default admission chunk of min(max_len, 512) — the
        # chunk dispatch materializes O(C x (ring + C)) attention
        # scores and O(C) recurrent-scan intermediates per layer, so an
        # uncapped max_len-sized chunk would blow peak memory at large
        # max_len for no latency benefit
        if prefill_chunk is not None and int(prefill_chunk) <= 0:
            raise ValueError(f"prefill_chunk must be positive, got "
                             f"{prefill_chunk} (use None for the "
                             f"default)")
        self.prefill_chunk = prefill_chunk
        self._chunk = (int(prefill_chunk) if prefill_chunk is not None
                       else min(int(max_len), _DEFAULT_ADMIT_CHUNK))
        # adaptive admission chunking: shrink to the floor on ticks
        # where >= 1 slot is decoding (bound the stall decoders see),
        # drain a cold queue at the full chunk
        if prefill_chunk_min is not None:
            if int(prefill_chunk_min) <= 0:
                raise ValueError(f"prefill_chunk_min must be positive, "
                                 f"got {prefill_chunk_min} (use None to "
                                 f"disable the adaptive policy)")
            if int(prefill_chunk_min) > self._chunk:
                raise ValueError(f"prefill_chunk_min "
                                 f"({prefill_chunk_min}) must not exceed "
                                 f"the effective prefill chunk "
                                 f"({self._chunk})")
        self._chunk_min = (int(prefill_chunk_min)
                           if prefill_chunk_min is not None else None)
        if int(page_size) < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if int(cache_pages) < 0:
            raise ValueError(f"cache_pages must be >= 0, got "
                             f"{cache_pages} (0 disables the prefix "
                             f"cache)")
        self.page_size = int(page_size)
        self.cache_pages = int(cache_pages)
        if kv_quant not in ("none", "int8"):
            raise ValueError(f"kv_quant must be 'none' or 'int8', got "
                             f"{kv_quant!r}")
        self.kv_quant = kv_quant
        if int(l2_bytes) < 0:
            raise ValueError(f"l2_bytes must be >= 0, got {l2_bytes} "
                             f"(0 disables the host-RAM L2 tier)")
        self.l2_bytes = int(l2_bytes)
        # bounded admission + load shedding (max_queue == 0 keeps the
        # historical unbounded deque)
        if int(max_queue) < 0:
            raise ValueError(f"max_queue must be >= 0, got {max_queue} "
                             f"(0 = unbounded queue)")
        if shed_policy not in SHED_POLICIES:
            raise ValueError(f"shed_policy must be one of "
                             f"{SHED_POLICIES}, got {shed_policy!r}")
        if deadline_ticks is not None and int(deadline_ticks) < 1:
            raise ValueError(f"deadline_ticks must be >= 1, got "
                             f"{deadline_ticks} (use None for no "
                             f"deadline)")
        self.max_queue = int(max_queue)
        self.shed_policy = shed_policy
        self.deadline_ticks = (int(deadline_ticks)
                               if deadline_ticks is not None else None)
        self._chaos = chaos
        self._draining = False
        if int(pipeline_depth) < 0:
            raise ValueError(f"pipeline_depth must be >= 0, got "
                             f"{pipeline_depth} (0 = synchronous "
                             f"harvest)")
        self.pipeline_depth = int(pipeline_depth)
        # virtual-device emulation: each decode block's ring becomes
        # readable no earlier than dispatch + this latency, modelling
        # an accelerator whose completion the host must wait out. On a
        # host where XLA compute timeshares the same cores as the tick
        # loop (single-core CI), this is the only way to observe the
        # host/device overlap the pipelined drain buys: the wait is a
        # GIL-releasing sleep, so the synchronous engine serializes on
        # it while a primed pipeline hides it behind tick work.
        # Token streams are unaffected — only readiness timing shifts.
        if float(virtual_device_latency_s) < 0.0:
            raise ValueError(f"virtual_device_latency_s must be >= 0, "
                             f"got {virtual_device_latency_s}")
        self.virtual_device_latency_s = float(virtual_device_latency_s)
        # telemetry plane: metrics registry + request tracing + A^3
        # quality probes. OFF is the default and keeps every hot path
        # byte-identical to the untelemetered engine (each hook sits
        # behind one ``self._tm is not None`` check); ON adds host-side
        # bookkeeping only — probe arrays ride the existing deferred
        # ring drain, so ``stats["host_syncs"]`` is pinned either way.
        if int(telemetry_every) < 1:
            raise ValueError(f"telemetry_every must be >= 1, got "
                             f"{telemetry_every}")
        if int(trace_events) < 1:
            raise ValueError(f"trace_events must be >= 1, got "
                             f"{trace_events}")
        if int(retain_results) < 0:
            raise ValueError(f"retain_results must be >= 0, got "
                             f"{retain_results} (0 = unbounded "
                             f"retention)")
        self.telemetry = bool(telemetry)
        self.telemetry_every = int(telemetry_every)
        self.trace_events = int(trace_events)
        self.retain_results = int(retain_results)
        self._tm: Optional[Telemetry] = None
        if self.telemetry:
            self._tm = Telemetry(trace_events=self.trace_events,
                                 telemetry_every=self.telemetry_every)
        self.decode_block = max(1, int(decode_block))
        self.use_kernel = use_kernel
        # temperature > 0 is THE sampling switch: 0 pins greedy argmax
        self.temperature = max(0.0, temperature)
        # the seed is the whole sampling state: the key is never
        # mutated (draws fold (uid, pos) per request), so a restored
        # engine reconstructs identical sampling from this int alone
        self.sample_seed = int(sample_seed)
        self._sample_rng = (jax.random.PRNGKey(self.sample_seed)
                            if self.temperature > 0.0 else None)
        self.slots = [SlotState() for _ in range(slots)]
        self.cache = decoder.init_cache(cfg, slots, max_len,
                                        a3=self._use_a3)
        # host-side mirror input for stats["resorts"]: number of
        # global-attention segments carrying sorted-key state (dict-key
        # inspection only — no device read).
        self._n_a3_segs = sum(1 for sc in self.cache.values()
                              if isinstance(sc, dict) and "sk_vals" in sc)
        # donate the cache argument: ring buffers update in place (no
        # full-cache copy per tick; the jit aliases input to output).
        self._decode_block = jax.jit(
            make_decode_block_step(
                cfg, a3, steps=self.decode_block, use_kernel=use_kernel,
                resort_every=self.resort_every if self._use_a3 else 0,
                temperature=self.temperature),
            donate_argnums=(1,))
        # A^3 quality-probe variant: identical token/cache ops plus the
        # in-graph (candidate count, captured-score-mass) accumulator.
        # Built only when telemetry is on AND sorted-key state exists;
        # dispatched every ``telemetry_every``-th decode block.
        self._decode_block_probe = None
        if self._tm is not None and self._use_a3 and self._n_a3_segs > 0:
            self._decode_block_probe = jax.jit(
                make_decode_block_step(
                    cfg, a3, steps=self.decode_block,
                    use_kernel=use_kernel,
                    resort_every=self.resort_every,
                    temperature=self.temperature, probe=True),
                donate_argnums=(1,))
        self._prefill = jax.jit(
            make_prefill_chunk_step(cfg, a3=self._use_a3,
                                    temperature=self.temperature),
            donate_argnums=(1,))
        self._prefill_nosort = None
        if self._use_a3:
            # ticks where no lane finishes its prompt skip the sort
            # AND the per-layer sorted-key passthrough copy
            self._prefill_nosort = jax.jit(
                make_prefill_chunk_step(cfg, a3=True, update_sort=False,
                                        temperature=self.temperature),
                donate_argnums=(1,))
        # device-resident prefill->decode handoff: slots that finished
        # their prompt this tick, whose first sampled token lives only
        # in ``_first_tok`` (the prefill dispatch output) until the next
        # decode harvest (or a direct read if no decode block runs)
        self._handoff: set = set()
        self._first_tok = None
        # pipelined harvest state: dispatched-but-unharvested decode
        # blocks (at most pipeline_depth stay in flight across ticks;
        # depth 0 drains every block the tick that dispatched it —
        # the synchronous engine, bit-identical), plus the device-
        # resident cross-block token carry: the previous block's final
        # per-lane token, consumed as the next block's input without
        # ever rebuilding the lane vector from host state
        self._pending: Deque[_PendingHarvest] = collections.deque()
        self._token_carry = None
        self._carry_ok = np.zeros((slots,), bool)
        # cached constant device buffers (built once, reused every
        # tick): the zero first-token vector fed to decode dispatches
        # on ticks with no prefill handoff (constant shape/value — no
        # per-tick upload)
        self._zero_tok = jnp.zeros((slots,), jnp.int32)
        self._queue: Deque[Request] = collections.deque()
        self._done: Dict[int, List[int]] = {}
        # request lifecycle: uid -> status (QUEUED / PREFILLING /
        # DECODING / one of the _TERMINAL states)
        self._status: Dict[int, str] = {}
        self._uid = 0
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "decode_steps_advanced": 0,
                      "decode_dispatches": 0, "decode_blocks": 0,
                      "prefill_dispatches": 0, "host_syncs": 0,
                      "handoff_syncs": 0, "ticks": 0, "resorts": 0,
                      "prefix_hits": 0, "prefix_tokens_reused": 0,
                      "gather_dispatches": 0, "pages_recorded": 0,
                      "pages_evicted": 0, "adaptive_shrink_ticks": 0,
                      # lifecycle counters: conservation identity
                      # submitted == finished + rejected + cancelled
                      #              + expired + failed + in_flight
                      "submitted": 0, "finished": 0, "rejected": 0,
                      "cancelled": 0, "expired": 0, "failed": 0,
                      # robustness bookkeeping
                      "chaos_aborted_ticks": 0, "max_ticks_exhausted": 0,
                      "chaos_delayed_ticks": 0,
                      # durable-state bookkeeping (host-RAM L2 tier +
                      # engine checkpoint/restore)
                      "l2_spills": 0, "l2_hits": 0, "l2_evictions": 0,
                      "l2_integrity_drops": 0, "checkpoints": 0,
                      "restores": 0,
                      # per-phase tick timing (monotonic-clock ns;
                      # chaos delays are virtual so they add no wall
                      # time) + harvest reads that actually blocked on
                      # an unfinished device block
                      "tick_ns_prefill": 0, "tick_ns_decode": 0,
                      "tick_ns_harvest": 0, "tick_ns_host": 0,
                      "host_sync_stalls": 0}
        if self._tm is not None:
            # compatibility view: the legacy stats dict is exported by
            # the registry at exposition time (read by reference — the
            # dict stays a plain dict, so checkpointing and the
            # PrefixCache shared-stats contract are untouched)
            self._tm.registry.attach_stats("serve_", self.stats)
        # bounded retention of terminal bookkeeping (uid -> status /
        # result): FIFO order of terminal transition; 0 = historical
        # unbounded maps
        self._terminal_order: Deque[int] = collections.deque()
        # paged prefix cache: shared-prefix reuse across all mixer kinds
        # (cache_pages == 0 disables it — admission is byte-identical to
        # the cache-less engine, and no pool memory is allocated)
        self._pc: Optional[PrefixCache] = None
        if self.cache_pages > 0:
            self._pc = PrefixCache(cfg, max_len=max_len,
                                   page_size=self.page_size,
                                   cache_pages=self.cache_pages,
                                   a3=self._use_a3,
                                   kv_quant=self.kv_quant,
                                   l2_bytes=self.l2_bytes,
                                   stats=self.stats)
            self._pc.tm = self._tm
            if self._pc.l2 is not None and chaos is not None:
                # restore_corrupt site: flip a blob byte right before
                # its verified L2 restore (checksum must catch it)
                self._pc.l2_fault_hook = (
                    lambda key: self._chaos.l2_restore_corrupt(
                        self.stats["ticks"], key))

    @classmethod
    def from_config(cls, params: Any, cfg: ModelConfig, serve: ServeConfig,
                    a3: A3Config = A3Config(),
                    chaos: Optional[ChaosInjector] = None) -> "ServeEngine":
        return cls(params, cfg, slots=serve.slots, max_len=serve.max_len,
                   a3=a3, resort_every=serve.resort_every,
                   prefill_chunk=serve.prefill_chunk,
                   prefill_chunk_min=serve.prefill_chunk_min,
                   decode_block=serve.decode_block,
                   use_kernel=serve.use_kernel,
                   temperature=serve.temperature,
                   sample_seed=serve.sample_seed,
                   page_size=serve.page_size,
                   cache_pages=serve.cache_pages,
                   max_queue=serve.max_queue,
                   shed_policy=serve.shed_policy,
                   deadline_ticks=serve.deadline_ticks,
                   kv_quant=serve.kv_quant,
                   l2_bytes=serve.l2_bytes,
                   pipeline_depth=serve.pipeline_depth,
                   telemetry=serve.telemetry,
                   telemetry_every=serve.telemetry_every,
                   trace_events=serve.trace_events,
                   retain_results=serve.retain_results,
                   chaos=chaos)

    # -- public API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               deadline_ticks: Optional[int] = None) -> int:
        """Submit a prompt; returns the request uid.

        Invalid *inputs* raise (TypeError / ValueError) without
        consuming a uid; overload *shedding* does not raise — the uid
        comes back with ``status(uid) == "rejected"`` so callers can
        distinguish "you sent garbage" from "the server is full".

        Validation: the prompt must be a non-empty 1-D integer array
        with token ids in ``[0, vocab_size)`` and length <= ``max_len``
        (a prompt of length *exactly* ``max_len`` is admitted and
        finishes with just its prefill-sampled token — there is no
        room to decode past it; longer prompts are an error, not a
        silent truncation). ``max_new_tokens`` must be >= 1.
        ``deadline_ticks`` (default: the engine-wide setting) expires
        the request if it has not FINISHED within that many ticks of
        submission."""
        arr = np.asarray(prompt)
        if arr.ndim != 1:
            raise ValueError(f"prompt must be 1-D, got shape {arr.shape}")
        if arr.size == 0:
            # neither admission path supports empty prompts (chunked
            # would fold a reused slot's stale ring into the A^3 sort;
            # whole-prompt prefill has no last position to unembed)
            raise ValueError("empty prompt")
        if not np.issubdtype(arr.dtype, np.integer):
            raise TypeError(f"prompt must be an integer token array, "
                            f"got dtype {arr.dtype}")
        if arr.size > self.max_len:
            raise ValueError(
                f"prompt length {arr.size} exceeds max_len "
                f"{self.max_len}: the slot cache cannot hold it "
                f"(submit a shorter prompt or raise max_len)")
        if (arr < 0).any() or (arr >= self.cfg.vocab_size).any():
            raise ValueError(
                f"prompt token ids must lie in [0, "
                f"{self.cfg.vocab_size}); got range "
                f"[{int(arr.min())}, {int(arr.max())}]")
        max_new_tokens = int(max_new_tokens)
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}")
        if deadline_ticks is None:
            deadline_ticks = self.deadline_ticks
        deadline = None
        if deadline_ticks is not None:
            if int(deadline_ticks) < 1:
                raise ValueError(f"deadline_ticks must be >= 1, got "
                                 f"{deadline_ticks}")
            deadline = self.stats["ticks"] + int(deadline_ticks)
        uid = self._uid
        self._uid += 1
        self.stats["submitted"] += 1
        if self._tm is not None:
            self._tm.on_submit(uid)
        if self._draining:
            self._terminal(uid, REJECTED)
            return uid
        if self.max_queue and len(self._queue) >= self.max_queue:
            if self.shed_policy == "evict-oldest-queued":
                victim = self._queue.popleft()
                self._terminal(victim.uid, REJECTED)
            else:                      # reject-new
                self._terminal(uid, REJECTED)
                return uid
        self._status[uid] = QUEUED
        self._queue.append(
            Request(uid, arr.astype(np.int32), max_new_tokens, deadline))
        return uid

    def result(self, uid: int) -> Optional[List[int]]:
        """Generated tokens for a FINISHED request, else None (still in
        flight, or terminated rejected/cancelled/expired/failed).

        With bounded retention (``retain_results > 0``) a fetched
        result is popped — the first read returns the tokens and
        releases the engine's copy (later reads return None), so a
        long-running engine's result map holds only unread results,
        and at most ``retain_results`` of those."""
        if self.retain_results > 0:
            return self._done.pop(uid, None)
        return self._done.get(uid)

    def status(self, uid: int) -> str:
        """Lifecycle status of a submitted uid (see module docstring)."""
        try:
            return self._status[uid]
        except KeyError:
            raise KeyError(f"unknown request uid {uid}") from None

    def cancel(self, uid: int) -> bool:
        """Cancel a request in any non-terminal state. Queued requests
        leave the queue; on-slot requests are reclaimed immediately —
        mid-prefill or mid-decode — and their prefix-cache recording
        pin is dropped (refcounts return to baseline). Returns True if
        the request was cancelled, False if already terminal (or
        unknown)."""
        st = self._status.get(uid)
        if st == QUEUED:
            self._queue = collections.deque(
                r for r in self._queue if r.uid != uid)
            self._terminal(uid, CANCELLED)
            return True
        if st in (PREFILLING, DECODING):
            for si, s in enumerate(self.slots):
                if s.active and s.uid == uid:
                    self._release_slot(si, CANCELLED)
                    return True
        return False

    def drain(self):
        """Graceful shutdown: cancel all queued work, keep ticking
        in-flight slots to completion, reject every new submit.
        Idempotent; ``run_to_completion`` after ``drain`` finishes the
        slots and returns."""
        self._draining = True
        while self._queue:
            req = self._queue.popleft()
            self._terminal(req.uid, CANCELLED)

    @property
    def draining(self) -> bool:
        return self._draining

    @property
    def tm(self) -> Optional[Telemetry]:
        """The telemetry bundle (None unless ``telemetry=True``)."""
        return self._tm

    @property
    def in_flight(self) -> int:
        """Requests not yet terminal: queued plus on-slot."""
        return len(self._queue) + sum(1 for s in self.slots if s.active)

    def step(self):
        """One engine tick: expire -> admit -> plan + pack -> chunked
        prefill -> blocked decode (the A^3 re-sort runs *inside* the
        decode dispatch) -> deferred harvest. Both dispatch phases are
        *planned* first against the post-admission slot table, their
        per-lane scalars packed into one ``[slots, CTRL_COLS]`` int32
        upload, and the prefill + decode dispatches issued
        back-to-back before any host sync; the ring harvest at the
        tail lands every block older than ``pipeline_depth``. With a
        chaos injector attached the injector is consulted at each
        phase boundary and may abort the tick with
        :class:`~repro.serve.chaos.ChaosError` — every phase leaves the
        engine consistent, so the next tick simply resumes (the
        caller counts the abort; ``run_to_completion`` does)."""
        self.stats["ticks"] += 1
        tick = self.stats["ticks"]
        t0 = time.monotonic_ns()
        h0 = self.stats["tick_ns_harvest"]
        p_ns = d_ns = 0
        ch = self._chaos
        if ch is not None:
            ch.phase(tick, "tick_start")
            if ch.consume_delay():
                # virtual stall: the whole tick does no work (the
                # wall-clock-free replacement for the old time.sleep
                # delay — deterministic, and deadlines still elapse)
                self.stats["chaos_delayed_ticks"] += 1
                if self._tm is not None:
                    self._tm.event("chaos_delay", tick=tick)
                self.stats["tick_ns_host"] += time.monotonic_ns() - t0
                return
            spill = ch.pick_spill(tick)
            if spill and self._pc is not None:
                if self._tm is not None:
                    self._tm.event("chaos_spill", tick=tick, pages=spill)
                self._pc.spill(spill)
        self._expire_tick()
        self._admit()
        if ch is not None:
            ch.phase(tick, "pre_prefill")
        if any(s.phase == PREFILLING for s in self.slots):
            # an aborted tick (injected mid-tick raise) can leave
            # handoff first tokens unharvested; resolve them with a
            # direct read BEFORE the prefill dispatch overwrites
            # ``_first_tok`` (and before planning reads slot state)
            self._flush_stale_handoff()
        # plan both dispatch phases, pack their control words into ONE
        # transfer (the decode plan simulates the prefill plan's slot
        # transitions, so it needs no sync in between)
        ctrl = np.zeros((len(self.slots), CTRL_COLS), np.int32)
        ctrl[:, CTRL_D_POS] = -1
        plan_p = self._plan_prefill(ctrl)
        plan_d = self._plan_decode(plan_p, ctrl)
        ctrl_dev = (jnp.asarray(ctrl)
                    if plan_p is not None or plan_d is not None else None)
        tp = time.monotonic_ns()
        self._prefill_tick(plan_p, ctrl_dev)
        p_ns = time.monotonic_ns() - tp
        if ch is not None:
            ch.phase(tick, "pre_advance")
        self._corrupt_tick()
        hd = self.stats["tick_ns_harvest"]
        td = time.monotonic_ns()
        self._advance(plan_d, ctrl_dev)
        d_ns = max(0, time.monotonic_ns() - td
                   - (self.stats["tick_ns_harvest"] - hd))
        self.stats["tick_ns_prefill"] += p_ns
        self.stats["tick_ns_decode"] += d_ns
        self.stats["tick_ns_host"] += max(
            0, time.monotonic_ns() - t0 - p_ns - d_ns
            - (self.stats["tick_ns_harvest"] - h0))

    def run_to_completion(self, max_ticks: int = 10_000):
        """Tick until no work remains. Injected tick aborts
        (:class:`ChaosError`) are absorbed and counted in
        ``stats["chaos_aborted_ticks"]``. Hitting ``max_ticks`` with
        work still pending raises RuntimeError (and bumps
        ``stats["max_ticks_exhausted"]``) instead of returning
        silently with requests stranded in flight."""
        ticks = 0
        while self.in_flight and ticks < max_ticks:
            try:
                self.step()
            except EngineCrash:
                # injected process death: NOT absorbed — the caller's
                # recovery path is restore() from the last checkpoint
                raise
            except ChaosError:
                self.stats["chaos_aborted_ticks"] += 1
                if self._tm is not None:
                    self._tm.event("chaos_abort",
                                   tick=self.stats["ticks"])
            ticks += 1
        if self.in_flight:
            self.stats["max_ticks_exhausted"] += 1
            queued = [r.uid for r in self._queue]
            on_slot = [s.uid for s in self.slots if s.active]
            raise RuntimeError(
                f"run_to_completion exhausted max_ticks={max_ticks} "
                f"with {self.in_flight} requests still in flight "
                f"(queued uids {queued}, on-slot uids {on_slot}) — "
                f"raise max_ticks or investigate a stalled lane")

    # -- crash-consistent checkpoint / restore --------------------------------
    def _ckpt_kwargs(self) -> Dict[str, Any]:
        """The JSON-serializable constructor kwargs a restore rebuilds
        the engine from (params / cfg / a3 / chaos come from the
        caller and are validated against the saved echo)."""
        return {"slots": len(self.slots), "max_len": self.max_len,
                "resort_every": self.resort_every,
                "prefill_chunk": self.prefill_chunk,
                "prefill_chunk_min": self._chunk_min,
                "decode_block": self.decode_block,
                "use_kernel": bool(self.use_kernel),
                "temperature": self.temperature,
                "sample_seed": self.sample_seed,
                "page_size": self.page_size,
                "cache_pages": self.cache_pages,
                "max_queue": self.max_queue,
                "shed_policy": self.shed_policy,
                "deadline_ticks": self.deadline_ticks,
                "kv_quant": self.kv_quant,
                "l2_bytes": self.l2_bytes,
                "pipeline_depth": self.pipeline_depth,
                "virtual_device_latency_s":
                    self.virtual_device_latency_s,
                "telemetry": self.telemetry,
                "telemetry_every": self.telemetry_every,
                "trace_events": self.trace_events,
                "retain_results": self.retain_results}

    def checkpoint(self, path: str) -> None:
        """Snapshot the complete serving state to directory ``path``
        with an atomic rename commit: slots (mid-prefill cursors,
        generated tokens, budgets), queue, per-request status map and
        results, sampling state (the seed — the key is never mutated),
        stats, the device cache, and the prefix trie + pool + L2 blob
        store. A crash at ANY point leaves either the previous complete
        checkpoint or the new one — never a torn mix: everything is
        written into ``path + ".tmp"`` first and a single
        ``os.rename`` is the commit point (an interrupted commit
        leaves ``path + ".old"``, which :meth:`restore` falls back
        to). ``state.json`` carries a crc32 and the array payload is a
        self-checksummed :func:`~repro.serve.page_store.serialize_tree`
        blob, so a torn or bit-rotted checkpoint fails restore loudly
        (:class:`~repro.serve.page_store.CheckpointError`) instead of
        resuming with silently wrong state."""
        # land every in-flight ring harvest and resolve any pending
        # device-resident handoff tokens first: the snapshot must be
        # host-consistent at a tick boundary (a crash between a
        # dispatch and its deferred harvest loses only post-checkpoint
        # work — the restored engine re-decodes those tokens
        # bit-identically)
        t_ck = time.monotonic_ns()
        self._drain_harvests()
        self._flush_stale_handoff()
        self._finish_done_slots()
        slots_meta = []
        for s in self.slots:
            rec = None
            if s.rec_node is not None and self._pc is not None:
                rec = [int(x) for x in self._pc._path_of(s.rec_node)]
            slots_meta.append({
                "uid": s.uid, "pos": s.pos,
                "generated": [int(x) for x in s.generated],
                "budget": s.budget, "phase": s.phase,
                "prompt": (None if s.prompt is None
                           else [int(x) for x in s.prompt]),
                "cursor": s.cursor, "sorted_upto": s.sorted_upto,
                "rec": rec, "has_rec": s.rec_node is not None,
                "deadline": s.deadline})
        state: Dict[str, Any] = {
            "version": 1, "cfg_name": self.cfg.name,
            "a3_mode": self.a3.mode.value,
            "engine": self._ckpt_kwargs(),
            "uid": self._uid, "draining": self._draining,
            "stats": dict(self.stats),
            "status": {str(k): v for k, v in self._status.items()},
            "done": {str(k): [int(t) for t in v]
                     for k, v in self._done.items()},
            "queue": [{"uid": r.uid,
                       "prompt": [int(x) for x in r.prompt],
                       "max_new": r.max_new_tokens,
                       "deadline": r.deadline} for r in self._queue],
            "slots": slots_meta}
        if self._tm is not None:
            # histogram/counter state round-trips so a restored
            # engine's latency distributions continue instead of
            # resetting (optional key: older checkpoints lack it)
            state["telemetry"] = self._tm.dump_state()
        arrays: Dict[str, Any] = {"cache": self.cache}
        l2_blobs: List[bytes] = []
        if self._pc is not None:
            pc_meta, pc_arrays = self._pc.dump_state()
            state["pc"] = pc_meta
            arrays["pc"] = pc_arrays
            if self._pc.l2 is not None:
                index, off = [], 0
                for key, blob in self._pc.l2.raw_items():
                    index.append({"key": list(key), "off": off,
                                  "len": len(blob)})
                    l2_blobs.append(blob)
                    off += len(blob)
                state["l2_index"] = index
        tmp = path + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        payload = json.dumps(state, sort_keys=True).encode()
        with open(os.path.join(tmp, "state.json"), "wb") as f:
            f.write(b"%d\n" % zlib.crc32(payload) + payload)
        with open(os.path.join(tmp, "arrays.bin"), "wb") as f:
            f.write(serialize_tree(arrays))
        with open(os.path.join(tmp, "l2.bin"), "wb") as f:
            f.write(b"".join(l2_blobs))
        # atomic commit: the rename below is the durability point
        old = path + ".old"
        shutil.rmtree(old, ignore_errors=True)
        if os.path.isdir(path):
            os.rename(path, old)
        os.rename(tmp, path)
        shutil.rmtree(old, ignore_errors=True)
        self.stats["checkpoints"] += 1
        if self._tm is not None:
            now = time.monotonic_ns()
            self._tm.span("checkpoint", ts_ns=t_ck, dur_ns=now - t_ck,
                          path=path)

    @classmethod
    def restore(cls, path: str, params: Any, cfg: ModelConfig,
                a3: A3Config = A3Config(),
                chaos: Optional[ChaosInjector] = None) -> "ServeEngine":
        """Rebuild an engine from a :meth:`checkpoint` directory and
        resume exactly where it left off: ticking the restored engine
        yields token-for-token the outputs the uninterrupted run would
        have produced, for every queued / prefilling / decoding
        request (see the module docstring's durability diagram). The
        caller supplies what a checkpoint cannot durably own — params,
        the model config, the A^3 config, and optionally a fresh chaos
        injector — and the saved echo (cfg name, A^3 mode) is
        validated against them. Raises
        :class:`~repro.serve.page_store.CheckpointError` on any
        verification failure."""
        if not os.path.isdir(path) and os.path.isdir(path + ".old"):
            # a crash between the commit renames leaves only .old:
            # the previous complete checkpoint is still durable
            path = path + ".old"
        try:
            with open(os.path.join(path, "state.json"), "rb") as f:
                raw = f.read()
            crc_s, payload = raw.split(b"\n", 1)
            if zlib.crc32(payload) != int(crc_s):
                raise CheckpointError(
                    f"{path}: state.json checksum mismatch")
            state = json.loads(payload.decode())
            with open(os.path.join(path, "arrays.bin"), "rb") as f:
                arrays = deserialize_tree(f.read())
            with open(os.path.join(path, "l2.bin"), "rb") as f:
                l2_raw = f.read()
        except CheckpointError:
            raise
        except (OSError, ValueError, IntegrityError) as e:
            raise CheckpointError(
                f"unreadable checkpoint {path}: {e}") from None
        if state.get("version") != 1:
            raise CheckpointError(
                f"unsupported checkpoint version "
                f"{state.get('version')!r}")
        if state["cfg_name"] != cfg.name:
            raise CheckpointError(
                f"checkpoint was taken with model "
                f"{state['cfg_name']!r}; restoring with {cfg.name!r}")
        if state["a3_mode"] != a3.mode.value:
            raise CheckpointError(
                f"checkpoint A^3 mode {state['a3_mode']!r} does not "
                f"match {a3.mode.value!r}")
        eng = cls(params, cfg, a3=a3, chaos=chaos, **state["engine"])
        # stats is SHARED with the prefix cache: update in place
        eng.stats.update({k: int(v) for k, v in state["stats"].items()})
        eng._uid = int(state["uid"])
        eng._draining = bool(state["draining"])
        eng._status = {int(k): v for k, v in state["status"].items()}
        eng._done = {int(k): [int(t) for t in v]
                     for k, v in state["done"].items()}
        eng._queue = collections.deque(
            Request(int(q["uid"]), np.asarray(q["prompt"], np.int32),
                    int(q["max_new"]),
                    None if q["deadline"] is None else int(q["deadline"]))
            for q in state["queue"])
        eng.cache = jax.tree_util.tree_map(jnp.asarray, arrays["cache"])
        if eng._pc is not None and "pc" in state:
            eng._pc.load_state(state["pc"], arrays.get("pc", {}))
            if eng._pc.l2 is not None:
                for entry in state.get("l2_index", []):
                    off, n = int(entry["off"]), int(entry["len"])
                    eng._pc.l2.put_raw(
                        tuple(int(x) for x in entry["key"]),
                        l2_raw[off:off + n])
        for si, sm in enumerate(state["slots"]):
            s = SlotState(
                uid=int(sm["uid"]), pos=int(sm["pos"]),
                generated=[int(x) for x in sm["generated"]],
                budget=int(sm["budget"]), phase=sm["phase"],
                prompt=(None if sm["prompt"] is None
                        else np.asarray(sm["prompt"], np.int32)),
                cursor=int(sm["cursor"]),
                sorted_upto=int(sm["sorted_upto"]),
                deadline=(None if sm["deadline"] is None
                          else int(sm["deadline"])))
            if sm["has_rec"] and eng._pc is not None:
                # re-derive the recording-anchor pin from the node's
                # token path (refs are not serialized — they restore
                # exactly from the slots that hold them)
                node: Any = eng._pc.root
                toks = [int(x) for x in sm["rec"]]
                ps = eng.page_size
                for b in range(0, len(toks), ps):
                    node = node.children.get(tuple(toks[b:b + ps]))
                    if node is None:
                        break
                if node is not None:
                    s.rec_node = node
                    eng._pc.ref(node)
            eng.slots[si] = s
        eng.stats["restores"] += 1
        if eng._tm is not None:
            if "telemetry" in state:
                eng._tm.load_state(state["telemetry"])
            eng._tm.event("restore", tick=int(eng.stats["ticks"]))
        return eng

    # -- internals ------------------------------------------------------------
    def _terminal(self, uid: int, status: str):
        """Move a request to a terminal status exactly once and bump
        the matching conservation counter. With ``retain_results > 0``
        the oldest terminal entries beyond the bound are dropped from
        the status/result maps (the conservation counters above are
        the durable record; the maps are a serving-window view)."""
        self._status[uid] = status
        self.stats[_TERMINAL[status]] += 1
        if self._tm is not None:
            self._tm.on_terminal(uid, status)
        if self.retain_results > 0:
            self._terminal_order.append(uid)
            while len(self._terminal_order) > self.retain_results:
                old = self._terminal_order.popleft()
                self._status.pop(old, None)
                self._done.pop(old, None)

    def _release_slot(self, si: int, status: str):
        """Reclaim a slot from ANY in-flight phase (cancel / expire /
        poison-fail): drop the prefix-cache recording pin so trie
        refcounts return to baseline, forget any pending device-
        resident handoff token, and free the lane — the slot admits new
        work on the next tick. No device cleanup is needed: a fresh
        admission resets the lane's mixer state in-graph at pos == 0."""
        s = self.slots[si]
        if s.rec_node is not None and self._pc is not None:
            self._pc.unref(s.rec_node)
        self._handoff.discard(si)
        self._carry_ok[si] = False
        self._terminal(s.uid, status)
        self.slots[si] = SlotState()

    def _expire_tick(self):
        """Enforce per-request deadlines at the tick boundary: a
        request submitted at tick T with deadline_ticks d expires at
        the start of tick T + d + 1 if not yet FINISHED — it gets d
        full ticks of service, queued or on-slot alike."""
        now = self.stats["ticks"]
        if self._queue and any(r.deadline is not None
                               for r in self._queue):
            kept: Deque[Request] = collections.deque()
            for req in self._queue:
                if req.deadline is not None and now > req.deadline:
                    self._terminal(req.uid, EXPIRED)
                else:
                    kept.append(req)
            self._queue = kept
        for si, s in enumerate(self.slots):
            if s.active and s.deadline is not None and now > s.deadline:
                self._release_slot(si, EXPIRED)

    def _corrupt_tick(self):
        """Chaos site: overwrite one decoding lane's mixer state with
        NaN (victim picked deterministically by the injector). The
        lane's next logits go non-finite and the decode dispatch emits
        POISON on the harvested ring — detection costs no extra sync."""
        if self._chaos is None:
            return
        decoding = {s.uid: si for si, s in enumerate(self.slots)
                    if s.decoding}
        if not decoding:
            return
        victim = self._chaos.pick_corrupt_victim(
            self.stats["ticks"], sorted(decoding))
        if victim is None:
            return
        if self._tm is not None:
            self._tm.event("chaos_corrupt", uid=victim,
                           track=decoding[victim])
        self.cache = corrupt_cache_lane(self.cache, decoding[victim])

    def _admit(self):
        # Phase 1 — assignment: queued requests claim free slots. The
        # warm path walks the prefix trie (extending through the L2
        # tier: demoted pages promote back with verified restores) —
        # the cursor starts past the matched prefix and only the
        # suffix chunk-prefills. Cold path (miss / cache disabled): no
        # host-side cache work at admit; the slot's first chunk
        # dispatch resets its mixer state in-graph (pos == 0), so
        # chunked prefill reproduces the whole-prompt cache state.
        assigned: List[Tuple[int, Request, int, Any]] = []
        for si, slot in enumerate(self.slots):
            if slot.active:
                continue
            while self._queue:
                req = self._queue.popleft()
                t, node = 0, None
                if self._pc is not None:
                    t, node = self._pc.lookup(req.prompt)
                    if t > 0 and self._chaos is not None:
                        try:
                            self._chaos.gather_fail(self.stats["ticks"],
                                                    req.uid, t)
                        except ChaosError:
                            # injected page-gather failure BEFORE the
                            # copy dispatch: the device cache is
                            # untouched and no trie ref was taken —
                            # fail the request, keep the slot free for
                            # the next one
                            if self._tm is not None:
                                self._tm.event("chaos_gather_fail",
                                               uid=req.uid)
                            self._terminal(req.uid, FAILED)
                            continue
                    # pin the matched chain NOW: a later assignment's
                    # L2 promotion could otherwise evict it between
                    # this lookup and the batched gather below
                    self._pc.ref(node)       # recording anchor pin
                assigned.append((si, req, t, node))
                break
        # Phase 2 — one stacked gather dispatch warm-admits EVERY
        # matched slot (ring rows from pool pages, recurrent carries
        # from boundary snapshots, A^3 sorted state + watermark
        # restored — no re-sort): a flash crowd of N same-prefix hits
        # costs one gather_dispatches increment, not N.
        warm = [(si, t, node) for si, req, t, node in assigned if t > 0]
        if warm:
            self.cache = self._pc.gather_into(self.cache, warm)
        for si, req, t, node in assigned:
            self.slots[si] = SlotState(uid=req.uid, pos=t,
                                       generated=[],
                                       budget=req.max_new_tokens,
                                       phase=PREFILLING,
                                       prompt=req.prompt, cursor=t,
                                       sorted_upto=t, rec_node=node,
                                       deadline=req.deadline)
            self._status[req.uid] = PREFILLING
            if self._tm is not None:
                self._tm.on_admit(req.uid, si, reused_tokens=t)

    def _plan_prefill(self, ctrl: np.ndarray) -> Optional[Dict[str, Any]]:
        """Plan this tick's chunked-prefill dispatch against the
        post-admission slot table WITHOUT touching any state: compute
        each PREFILLING lane's chunk ``take`` (page-boundary clamping
        included) and write the ``CTRL_P_*`` columns of the shared
        packed control block. Returns None when no lane prefills. The
        decode plan consumes the result to simulate the prefill's
        slot transitions, so both dispatches issue back-to-back off
        one upload with no sync between them."""
        pre = [si for si, s in enumerate(self.slots)
               if s.phase == PREFILLING]
        if not pre:
            return None
        n, c = len(self.slots), self._chunk
        # adaptive chunking: decoders active -> shrink the admission
        # stall to the floor; cold queue -> drain at the full chunk
        if self._chunk_min is not None \
                and any(s.decoding for s in self.slots):
            c = self._chunk_min
            self.stats["adaptive_shrink_ticks"] += 1
        ps = self.page_size
        tokens = np.zeros((n, c), np.int32)
        sort_any = False
        takes = {}
        for si in pre:
            s = self.slots[si]
            take = min(c, len(s.prompt) - s.cursor)
            if s.rec_node is not None:
                # Recorded prompts bound EVERY chunk by record_span and
                # land EVERY boundary-crossing chunk exactly on its last
                # page boundary (an unaligned tail < page_size follows
                # in the next dispatch, crossing nothing). Page capture
                # reads the rings once at chunk end, so together these
                # guarantee each recorded page's unmasked positions are
                # still ring-resident at capture — a wider or unaligned
                # chunk would record rows the chunk itself had already
                # overwritten in a sliding ring, stale pages a later
                # dedupe could upgrade into a match terminal. The
                # post-chunk mixer carry at the END boundary IS the trie
                # node's snapshot, so no replay dispatch is ever needed.
                take = min(take, self._pc.record_span)
                if s.cursor % ps:
                    # unaligned start (adaptive floor / sub-page
                    # chunks): realign at the FIRST boundary — crossing
                    # several boundaries from an unaligned start can
                    # outrun a sliding ring's capture residency even
                    # within record_span
                    take = min(take, ps - s.cursor % ps)
                else:
                    aligned = ((s.cursor + take) // ps) * ps
                    if aligned > s.cursor:
                        take = aligned - s.cursor
            tokens[si, :take] = s.prompt[s.cursor:s.cursor + take]
            ctrl[si, CTRL_P_POS] = s.cursor
            ctrl[si, CTRL_P_LEN] = take
            takes[si] = take
            # A^3 sort amortization: fold into the column sort only on
            # the prompt's final chunk (one sort per admitted prompt).
            if s.cursor + take >= len(s.prompt):
                ctrl[si, CTRL_P_SORT] = 1
                sort_any = True
            # sampling key for the in-graph first-token draw, keyed at
            # the producing position len(prompt)-1 (== cursor+take-1 on
            # the final chunk; meaningless and unused for other lanes)
            ctrl[si, CTRL_P_SPOS] = s.cursor + take - 1
            ctrl[si, CTRL_P_SIDS] = s.uid
        return {"pre": pre, "takes": takes, "tokens": tokens,
                "sort_any": sort_any}

    def _prefill_tick(self, plan: Optional[Dict[str, Any]],
                      ctrl_dev) -> None:
        """Advance every PREFILLING slot by one prompt chunk in a single
        ragged padded dispatch (planned by :meth:`_plan_prefill`);
        finishing lanes' first tokens are sampled in-graph and stay on
        device for the decode handoff."""
        if plan is None:
            return
        pre, takes = plan["pre"], plan["takes"]
        ps = self.page_size
        fn = self._prefill
        if self._prefill_nosort is not None and not plan["sort_any"]:
            fn = self._prefill_nosort
        args = (self.params, self.cache, jnp.asarray(plan["tokens"]),
                ctrl_dev)
        t_disp = time.monotonic_ns() if self._tm is not None else 0
        if self._sample_rng is not None:
            first_tok, self.cache = fn(*args, self._sample_rng)
        else:
            first_tok, self.cache = fn(*args)
        self.stats["prefill_dispatches"] += 1
        if self._tm is not None:
            # one ragged dispatch serves every prefilling lane; each
            # lane gets a span of the shared dispatch wall time
            dur = time.monotonic_ns() - t_disp
            for si in pre:
                s = self.slots[si]
                self._tm.on_prefill_chunk(s.uid, si, ts_ns=t_disp,
                                          dur_ns=dur, pos=s.cursor,
                                          chunk=takes[si])
        for si in pre:
            s = self.slots[si]
            s.cursor += takes[si]
            s.pos = s.cursor
            self.stats["prefill_tokens"] += takes[si]
            if s.rec_node is not None and s.cursor > s.rec_node.end:
                # record every page boundary the chunk crossed: each
                # copies one page pool-ward (deduped against concurrent
                # recorders); only the chunk-END boundary carries the
                # recurrent snapshot (the slot's carry is at end-state
                # only there). A None return means the page budget is
                # exhausted with nothing evictable — stop recording,
                # keep the prefix recorded so far
                prev = s.cursor - takes[si]
                for b in range((prev // ps + 1) * ps, s.cursor + 1, ps):
                    child = self._pc.record_boundary(
                        self.cache, si, s.prompt, b, s.rec_node,
                        carry=(b == s.cursor))
                    self._pc.unref(s.rec_node)
                    self._pc.ref(child)
                    s.rec_node = child
                    if child is None:
                        break
            if s.cursor >= len(s.prompt):
                # device-resident handoff: the first token exists only
                # in ``first_tok`` until the decode harvest resolves it
                s.phase = DECODING
                self._status[s.uid] = DECODING
                s.generated = []
                s.budget -= 1
                s.sorted_upto = len(s.prompt)  # final chunk folded the sort
                self._handoff.add(si)
                if s.rec_node is not None:
                    # leaf capture of the A^3 sorted columns (the final
                    # chunk just folded the full-ring sort), then drop
                    # the recording pin
                    self._pc.record_final(self.cache, si, s.rec_node,
                                          len(s.prompt))
                    self._pc.unref(s.rec_node)
                    s.rec_node = None
        if self._handoff:
            self._first_tok = first_tok

    def _flush_stale_handoff(self):
        """Resolve leftover device-resident handoff tokens with one
        direct read. Only an injected mid-tick abort between the
        prefill dispatch and the decode harvest leaves any — in normal
        operation the same tick's ``_advance`` always consumes the
        handoff set, so this never fires (and never costs a sync).
        Pending ring harvests land first so ``generated`` is current
        before the finish check runs."""
        if not self._handoff:
            return
        self._drain_harvests()
        th = time.monotonic_ns()
        first = np.asarray(self._first_tok)
        self.stats["host_syncs"] += 1
        self.stats["handoff_syncs"] += 1
        for si in sorted(self._handoff):
            s = self.slots[si]
            if not s.decoding:
                continue               # released while the token was stale
            tok = int(first[si])
            if tok == decoder.POISON:
                self._release_slot(si, FAILED)
            else:
                s.generated.append(tok)
                if self._tm is not None:
                    self._tm.on_first_token(s.uid)
            # the lane's token never entered a decode block, so the
            # device carry has no valid entry for it: the next block
            # rebuilds its input from ``generated`` (cold path)
            self._carry_ok[si] = False
        self._handoff = set()
        self._first_tok = None
        self.stats["tick_ns_harvest"] += time.monotonic_ns() - th
        self._finish_done_slots()

    def _plan_decode(self, plan_p: Optional[Dict[str, Any]],
                     ctrl: np.ndarray) -> Optional[Dict[str, Any]]:
        """Plan this tick's decode block against the slot table AS IT
        WILL BE after the planned prefill dispatch lands: lanes on
        their final prompt chunk join the handoff set with
        ``pos = len(prompt)`` and one budget unit spent on the in-graph
        first token. The simulation is exact (the prefill bookkeeping
        applies the same ``takes``), which is what lets both dispatches
        issue off one packed upload with no sync between them. Writes
        the ``CTRL_D_*`` columns; returns None when no lane can
        advance (the caller then handles any direct handoff reads)."""
        handoff = set(self._handoff)
        state: Dict[int, Tuple[int, int]] = {}
        for si, s in enumerate(self.slots):
            if s.decoding:
                state[si] = (s.pos, s.budget)
            elif plan_p is not None and si in plan_p["takes"]:
                if s.cursor + plan_p["takes"][si] >= len(s.prompt):
                    # finishes its prompt this tick: decodes from
                    # pos = len(prompt) with the first token's budget
                    # unit already spent (sampled in-graph)
                    state[si] = (len(s.prompt), s.budget - 1)
                    handoff.add(si)
        active = [si for si in sorted(state)
                  if state[si][1] > 0 and state[si][0] < self.max_len - 1]
        # the handoff mask covers ALL handoff lanes — ride-along ones
        # included, so their first token reaches the host via the
        # harvest's input column even when they cannot advance
        for si in handoff:
            ctrl[si, CTRL_D_HMASK] = 1
        if not active:
            return None
        n = len(self.slots)
        steps_left = np.zeros((n,), np.int32)
        pos0 = {}
        for si in active:
            p, b = state[si]
            steps_left[si] = min(b, self.max_len - 1 - p)
            pos0[si] = p
            ctrl[si, CTRL_D_POS] = p
            ctrl[si, CTRL_D_STEPS] = steps_left[si]
            ctrl[si, CTRL_D_IDS] = self.slots[si].uid
        return {"active": active, "steps_left": steps_left, "pos0": pos0}

    def _advance(self, plan: Optional[Dict[str, Any]], ctrl_dev) -> None:
        handoff = self._handoff
        self._handoff = set()
        if plan is None:
            # nothing can advance: land anything still in flight, then
            # resolve handoff lanes with a direct read (rare — every
            # handoff lane finished with its prefill token, from
            # budget == 1 or a max_len-length prompt)
            self._drain_harvests()
            if handoff:
                th = time.monotonic_ns()
                first = np.asarray(self._first_tok)
                self.stats["host_syncs"] += 1
                self.stats["handoff_syncs"] += 1
                for si in sorted(handoff):
                    s = self.slots[si]
                    if not s.decoding:
                        continue
                    tok = int(first[si])
                    if tok == decoder.POISON:
                        # non-finite prompt logits: quarantine
                        self._release_slot(si, FAILED)
                    else:
                        s.generated.append(tok)
                        if self._tm is not None:
                            self._tm.on_first_token(s.uid)
                    self._carry_ok[si] = False
                self.stats["tick_ns_harvest"] += time.monotonic_ns() - th
            self._finish_done_slots()
            return
        # blocked ragged decode: every advanceable slot moves up to
        # ``decode_block`` tokens in ONE jitted dispatch — sampling,
        # token feedback, the handoff select, and the A^3 re-sort all
        # happen in-graph off the packed ctrl upload. Idle/prefilling
        # slots ride along at pos=-1 (dropped ring writes, masked
        # recurrent state); lanes that exhaust their budget or hit
        # max_len mid-block are masked off in-graph via ``steps_left``.
        n, t = len(self.slots), self.decode_block
        active, steps_left = plan["active"], plan["steps_left"]
        # pipelined drain point (depth >= 1): land the over-depth
        # OLDEST rings BEFORE this tick's dispatch, keeping up to
        # ``depth`` blocks in flight behind the device. Draining only
        # the excess is what keeps the pipe primed — the popped ring
        # was dispatched depth+1 ticks ago and is (almost always)
        # already computed, while the newer rings stay queued so the
        # device never goes idle waiting on host bookkeeping. Depth 0
        # instead drains synchronously after the dispatch below.
        if self.pipeline_depth > 0:
            self._drain_harvests(keep=self.pipeline_depth)
        # input tokens: the previous block's device-resident carry, by
        # construction the last emitted token of every lane that has
        # ever decoded (handoff lanes take ``first_tok`` in-graph
        # instead). Cold path — engine start, restore, or a lane whose
        # carry a direct read invalidated — rebuilds the vector from
        # host ``generated`` state, landing pending harvests first so
        # that state is current.
        if self._token_carry is None or \
                any(not self._carry_ok[si] for si in active
                    if si not in handoff):
            self._drain_harvests()
            tokens = np.zeros((n,), np.int32)
            for si in active:
                s = self.slots[si]
                if s.decoding and s.generated:
                    tokens[si] = s.generated[-1]
            token_dev = jnp.asarray(tokens)
        else:
            token_dev = self._token_carry
        first = self._first_tok if handoff else self._zero_tok
        args = (self.params, self.cache, token_dev, first, ctrl_dev)
        # A^3 telemetry sampling: every telemetry_every-th decode
        # dispatch routes through the probe jit — identical token ops
        # plus the in-graph quality accumulator, harvested on the same
        # deferred drain (zero extra syncs, bit-identical streams)
        probe_out = None
        fn = self._decode_block
        if self._decode_block_probe is not None and \
                self.stats["decode_dispatches"] % self.telemetry_every == 0:
            fn = self._decode_block_probe
        t_disp = time.monotonic_ns() if self._tm is not None else 0
        if self._sample_rng is not None:
            out = fn(*args, self._sample_rng)
        else:
            out = fn(*args)
        if fn is self._decode_block:
            full, carry, self.cache = out
        else:
            full, probe_out, carry, self.cache = out
        # decode_steps counts executed scan iterations (T per dispatch);
        # decode_steps_advanced counts sequential steps that advanced at
        # least one lane (the deepest lane's progress) — iterations past
        # it only push masked ride-along lanes
        self.stats["decode_steps"] += t
        self.stats["decode_steps_advanced"] += int(min(t, steps_left.max()))
        self.stats["decode_dispatches"] += 1
        self.stats["decode_blocks"] += 1
        # the carry is valid for every lane the block touched: active
        # lanes end on their last emitted token, handoff lanes pass
        # their first token through, every other previously-valid lane
        # passes its carry through unchanged
        self._token_carry = carry
        for si in active:
            self._carry_ok[si] = True
        for si in handoff:
            self._carry_ok[si] = True
        # enqueue the harvest with its bookkeeping frozen at dispatch
        # time, then advance pos/budget optimistically (``steps_left``
        # is deterministic in them — the device executes exactly this
        # schedule; only a poison release can cut a lane short, and
        # the uid guard drops that lane's stale entries). Depth 0
        # lands this block immediately (synchronous engine); depth d
        # leaves it in flight for the pre-dispatch drain above, so
        # finish/poison/deadline bookkeeping acts on the harvested
        # (delayed) view while the device runs ahead.
        entry = _PendingHarvest(
            full=full,
            handoff=[(si, self.slots[si].uid) for si in sorted(handoff)
                     if self.slots[si].decoding],
            lanes=[(si, self.slots[si].uid,
                    int(min(t, steps_left[si])), plan["pos0"][si])
                   for si in active if self.slots[si].decoding],
            refs={},
            ready_at=(time.monotonic() + self.virtual_device_latency_s
                      if self.virtual_device_latency_s > 0.0 else 0.0),
            probe=probe_out, t_dispatch=t_disp)
        for si, uid in entry.handoff:
            entry.refs[si] = uid
        for si, uid, nb, _pos0 in entry.lanes:
            entry.refs[si] = uid
            s = self.slots[si]
            s.pos += nb
            s.budget -= nb
        for si in entry.refs:
            self.slots[si].pending += 1
        self._pending.append(entry)
        if self.pipeline_depth == 0:
            self._drain_harvests()
        self._finish_done_slots()

    def _drain_harvests(self, keep: int = 0):
        """Land queued ring harvests oldest-first at ONE
        synchronization point, leaving up to ``keep`` of the newest in
        flight. The forced pops are the over-``keep`` excess — blocks
        dispatched long enough ago that the device has normally
        finished them — and any further blocks that already completed
        ride along for free, so a drain batches as wide as the device
        allows without ever waiting out work it just queued.
        ``host_syncs`` grows once per drain event, not once per block;
        ``host_sync_stalls`` counts drains where a forced block had
        not finished computing when the read issued (a depth-0 drain
        always stalls: it reads the block it just dispatched; a primed
        pipeline's pre-dispatch drain mostly finds the data ready)."""
        if len(self._pending) <= keep:
            return
        th = time.monotonic_ns()
        now = time.monotonic()
        entries = [self._pending.popleft()
                   for _ in range(len(self._pending) - keep)]
        if any(not _block_done(e.full) or e.ready_at > now
               for e in entries):
            self.stats["host_sync_stalls"] += 1
            if self._tm is not None:
                # the stall shows on the timeline as the gap between
                # this instant and the stalled blocks' span ends
                self._tm.event("host_sync_stall",
                               forced_blocks=len(entries),
                               in_flight=len(self._pending))
        # opportunistic sweep: newer blocks that have already landed
        # on-device cost nothing to read now and widen the gap to the
        # next forced drain
        while self._pending and _block_done(self._pending[0].full) \
                and self._pending[0].ready_at <= now:
            entries.append(self._pending.popleft())
        self.stats["host_syncs"] += 1
        for e in entries:
            # virtual-device emulation: a block is unreadable before
            # its emulated completion; the sleep releases the GIL, so
            # real XLA compute (and nothing else, on the synchronous
            # path) proceeds underneath it
            wait = e.ready_at - time.monotonic()
            if wait > 0.0:
                time.sleep(wait)
            self._apply_harvest(e, np.asarray(e.full))
        self.stats["tick_ns_harvest"] += time.monotonic_ns() - th

    def _apply_harvest(self, e: _PendingHarvest, h: np.ndarray):
        """Run one block's deferred host bookkeeping against its
        harvested rows: handoff first tokens off column 0, generated
        extends + the A^3 watermark mirror off the ring columns, and
        poison quarantine for lanes whose rows carry the sentinel.
        Every row is uid-guarded — a lane released while the harvest
        was in flight contributes nothing to its slot's successor."""
        tm = self._tm
        if tm is not None:
            now = time.monotonic_ns()
            tm.on_decode_block(
                [(si, uid) for si, uid, _nb, _p0 in e.lanes],
                ts_ns=e.t_dispatch or now,
                dur_ns=now - e.t_dispatch if e.t_dispatch else 0,
                steps=max((nb for _si, _u, nb, _p0 in e.lanes),
                          default=0),
                deferred=self.pipeline_depth > 0)
            if e.probe is not None:
                # the probe array computed in the same dispatch as the
                # ring: np.asarray here is part of the same drain
                # event, so ``host_syncs`` does not grow
                tm.on_a3_probe(np.asarray(e.probe))
        for si, uid in e.handoff:
            s = self.slots[si]
            if s.uid != uid or not s.decoding:
                continue               # released while the block flew
            tok = int(h[si, 0])
            if tok == decoder.POISON:
                # non-finite prompt logits poisoned the handoff token:
                # quarantine off the harvest the block already paid for
                self._release_slot(si, FAILED)
            else:
                s.generated.append(tok)
                if tm is not None:
                    tm.on_first_token(s.uid)
        for si, uid, nb, pos0 in e.lanes:
            s = self.slots[si]
            if s.uid != uid or not s.decoding:
                continue               # failed via its handoff token,
                                       # or released while the block flew
            row = h[si, 1:1 + nb]
            if (row == decoder.POISON).any():
                # the lane's logits went non-finite mid-block (POISON
                # rode the existing harvest — no extra sync): FAIL the
                # request and reclaim the slot; every other lane's
                # tokens and cache state are bit-identical (the poison
                # select is lane-local, and a poisoned carry re-poisons
                # any block the lane rode before this harvest landed)
                self._release_slot(si, FAILED)
                continue
            s.generated.extend(int(tok) for tok in row)
            if tm is not None and nb > 0:
                tm.on_decode_steps(s.uid, nb)
            if self._use_a3:
                # mirror the in-graph watermark (checked before each
                # step's ring write, exactly as resort_sorted_keys
                # does) from the position the lane held at dispatch
                for p in range(pos0, pos0 + nb):
                    if p - s.sorted_upto >= self.resort_every:
                        s.sorted_upto = p
                        self.stats["resorts"] += self._n_a3_segs
        for si, uid in e.refs.items():
            s = self.slots[si]
            if s.uid == uid:
                s.pending = max(0, s.pending - 1)

    def _finish_done_slots(self):
        for si, s in enumerate(self.slots):
            if s.decoding and s.pending == 0 \
                    and (s.budget <= 0 or s.pos >= self.max_len - 1):
                self._finish(si)

    def _finish(self, si: int):
        slot = self.slots[si]
        self._done[slot.uid] = slot.generated
        self._terminal(slot.uid, FINISHED)
        self._carry_ok[si] = False
        self.slots[si] = SlotState()


def _block_done(arr) -> bool:
    """True when a dispatched block's output has finished computing
    (so reading it back will not stall the host). Conservative: a
    runtime without ``is_ready`` reports False (counts as a stall)."""
    try:
        return bool(arr.is_ready())
    except AttributeError:             # pragma: no cover - runtime-dependent
        return False
